"""Serve a DFXP-quantized model with batched requests (prefill + decode).

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    serve_main(["--arch", "llama3_8b", "--smoke", "--arithmetic", "dfxp",
                "--num-requests", "4", "--prompt-len", "32",
                "--max-new", "16"])
