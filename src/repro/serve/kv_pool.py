"""Slot-pooled KV cache with DFXP-packed storage (paper §5/§6, serve-side).

The decode KV cache is the one large runtime tensor the paper's thesis had
not touched: training holds every tensor group in dynamic fixed point with
the §5 overflow-rate controller, and Gupta et al. (2015) show narrow
storage survives long accumulation chains under careful rounding.  The
:class:`PackedKVCodec` applies exactly that recipe to serving: K/V live as
int8/int16 **mantissas** plus a per-layer/per-slot log2-step, quantized on
append and dequantized in the tile of ``attention_decode``.  At 8 bits the
cache is a quarter of float32 — which multiplies how many concurrent
sequences fit in HBM, the whole point of a continuous-batching pool.

Scale management reuses the core controller verbatim:

* on **admit** (a freed slot is filled from a fresh prefill), exponents are
  calibrated from the prompt K/V max-magnitude (``core.scale.calibrate_exp``
  with a margin bit), accumulators reset;
* on **append**, per-slot overflow statistics accumulate, and every
  ``update_interval`` appends ``core.scale.controller_step`` applies the
  paper's ×2/÷2 rule per slot; stored mantissas are rescaled in place when
  an exponent moves.

The codec implements the :class:`repro.models.layers.RawKVCodec` protocol,
so the model layer is storage-agnostic; a pool built with ``codec=None``
is bit-identical to today's float32 ring buffers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

import math

from repro.core.packed import (_overflow_counts, container_dtype, pack,
                               pack_rows, qrange)
from repro.core.quant import exact_pow2
from repro.core.scale import ScaleState, calibrate_exp, controller_step
from repro.models import transformer as T

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CacheQuantConfig:
    """How the packed KV pool stores and re-scales its mantissas."""

    width: int = 8                   # mantissa bits: 8 → int8, 16 → int16
    update_interval: int = 16        # appends between controller applications
    max_overflow_rate: float = 1e-4  # paper §5 threshold
    margin_bits: int = 1             # calibration headroom on admit
    stochastic: bool = False         # stochastic-rounded appends (Gupta 2015)

    def __post_init__(self):
        if not 2 <= self.width <= 16:
            raise ValueError(f"cache width {self.width} outside [2, 16]")


def is_attn_entry(entry: dict) -> bool:
    """True for decode-attention cache entries (raw or packed)."""
    return ("k" in entry or "k_m" in entry) and "pos" in entry


def _rescale(m: Array, de: Array, width: int) -> Array:
    """Re-grid a mantissa buffer after its exponent moved by ``de`` [B].

    ``value = m * 2**e`` is preserved up to one LSB: ``m' = round(m *
    2**-de)``. ``de == 0`` rows are exact (integer × 1.0).
    """
    qmax, qmin = qrange(width)
    f = exact_pow2(-de).reshape(de.shape + (1,) * (m.ndim - de.ndim))
    mf = jnp.round(m.astype(jnp.float32) * f)
    return jnp.clip(mf, qmin, qmax).astype(m.dtype)


def _pack_chunk(x: Array, width: int, e: Array, keep: Array, key=None,
                det=None):
    """Quantize a chunk ``[B, C, ...]`` against per-row exponents ``e[B]``.

    ``keep`` [B, C] marks the rows that will actually be written; overflow
    statistics count those rows only.  ``key`` [B, 2] enables stochastic
    rounding with one draw stream per slot; ``det`` [B] forces
    deterministic rounding per row (the admission chunk, matching
    ``pack_entry``).  Returns ``(mantissa int[B, C, ...], stats f32[B, 3])``.
    """
    qmax, qmin = qrange(width)
    e = jnp.asarray(e, jnp.float32)
    step = exact_pow2(e).reshape(e.shape + (1,) * (x.ndim - 1))
    m = x.astype(jnp.float32) / step
    if key is not None:
        u = jax.vmap(lambda k: jax.random.uniform(k, m.shape[1:]))(key)
        m = jnp.where(det.reshape((-1,) + (1,) * (x.ndim - 1)),
                      jnp.round(m), jnp.floor(m + u))
    else:
        m = jnp.round(m)
    kexp = keep.reshape(keep.shape + (1,) * (x.ndim - 2))
    axes = tuple(range(1, x.ndim))
    ovf, ovfh = _overflow_counts(m, width, axes=axes, mask=kexp)
    row_sz = float(math.prod(x.shape[2:]))
    total = jnp.sum(keep, axis=1).astype(jnp.float32) * row_sz
    stats = jnp.stack([ovf, ovfh, total], axis=-1)
    m = jnp.clip(m, qmin, qmax).astype(container_dtype(width))
    return m, stats


class PackedKVCodec:
    """KV-cache codec storing int mantissas + per-layer/per-slot exponents.

    Entry layout (leading layer dim ``n`` stripped inside the layer scan)::

        k_m, v_m : int8/int16 [n, B, W, K, hd]   mantissas
        k_e, v_e : f32 [n, B]                    log2-steps (integer-valued)
        pos      : int32 [n, B, W]               ring positions (-1 = empty)
        acc_k/v  : f32 [n, B, 3]                 controller window stats
        tot_k/v  : f32 [n, B, 3]                 cumulative stats (metrics)
        n_app    : f32 [n, B]                    appends since admit
        key      : uint32 [n, B, 2]              (stochastic mode only)
    """

    def __init__(self, config: CacheQuantConfig,
                 fused_decode: Optional[bool] = None, *,
                 tp_axis: Optional[str] = None):
        self.cfg = config
        # capability flag attention_decode keys on: with it set, decode
        # attention runs the fused Pallas flash-decode kernel on the int
        # mantissas (dequant in the tile loads) and ``load`` — the f32
        # K/V materialization below — never executes on the hot path.
        # The flag is read-only and owned by :func:`make_kv_pool`; the
        # legacy ``fused_decode=`` ctor arg warns for one release.
        if fused_decode is not None:
            import warnings
            warnings.warn(
                "PackedKVCodec(fused_decode=...) is deprecated; build "
                "pools through repro.serve.kv_pool.make_kv_pool, which "
                "owns the decode-path choice", DeprecationWarning,
                stacklevel=2)
        self._fused_decode = bool(fused_decode)
        # serving-TP axis the pool's kv-head dim is sharded over; the
        # fused kernels shard_map themselves over it (see kernels/attn/ops)
        self.tp_axis = tp_axis

    @property
    def fused_decode(self) -> bool:
        """Whether decode/prefill attention runs the fused Pallas kernels
        on the packed mantissas (set by the pool factory)."""
        return self._fused_decode

    # -- model-layer protocol (called per layer inside lax.scan) ----------
    def load(self, entry: dict):
        k = entry["k_m"].astype(jnp.float32) * \
            exact_pow2(entry["k_e"])[:, None, None, None]
        v = entry["v_m"].astype(jnp.float32) * \
            exact_pow2(entry["v_e"])[:, None, None, None]
        return k, v, entry["pos"]

    def fused_attention(self, entry: dict, qg: Array, q_pos: Array, *,
                        scale: float, window=None, causal: bool = True):
        """Flash-decode directly on the packed mantissas (no ``load``).

        ``qg``: [B, K, G, hd] kv-head-major query groups; the kernel
        dequantizes int8/int16 K/V tiles in-register against the per-slot
        exponents.  Returns f32 [B, K, G, hd].
        """
        from repro.kernels.attn.ops import flash_decode
        return flash_decode(qg, entry["k_m"], entry["v_m"], entry["pos"],
                            q_pos, entry["k_e"], entry["v_e"],
                            width=self.cfg.width, scale=scale, window=window,
                            causal=causal, tp_axis=self.tp_axis)

    def append(self, entry: dict, k_new: Array, v_new: Array,
               pos: Array, mask: Optional[Array] = None) -> dict:
        """Append one token's K/V per slot (quantize, count, control).

        ``mask`` (bool [B], optional) suppresses the append for masked-off
        rows *completely* — no mantissa/pos write, no statistics, no
        counter advance, no controller application, no PRNG-chain move.
        The continuous-batching engine decodes every slot each step; rows
        mid-chunked-prefill must stay byte-identical to a solo run, and a
        garbage append would move their exponents.  ``mask=None`` keeps
        today's unconditional path, bit-for-bit.
        """
        cfg = self.cfg
        W = entry["k_m"].shape[1]
        slot = (pos % W).astype(jnp.int32)
        bidx = jnp.arange(pos.shape[0])

        out = dict(entry)
        key_k = key_v = None
        if cfg.stochastic:
            ks = jax.vmap(lambda k: jax.random.split(k, 3))(entry["key"])
            key_k, key_v = ks[:, 0], ks[:, 1]
            out["key"] = (ks[:, 2] if mask is None else
                          jnp.where(mask[:, None], ks[:, 2], entry["key"]))

        k_m, st_k = pack_rows(k_new, cfg.width, entry["k_e"],
                              stochastic_keys=key_k)
        v_m, st_v = pack_rows(v_new, cfg.width, entry["v_e"],
                              stochastic_keys=key_v)
        if mask is None:
            k_buf = entry["k_m"].at[bidx, slot].set(k_m)
            v_buf = entry["v_m"].at[bidx, slot].set(v_m)
            out["pos"] = entry["pos"].at[bidx, slot].set(
                pos.astype(jnp.int32))
            napp = 1.0
        else:
            mf = mask.astype(jnp.float32)
            st_k = st_k * mf[:, None]
            st_v = st_v * mf[:, None]
            wslot = jnp.where(mask, slot, W)   # OOB rows dropped
            k_buf = entry["k_m"].at[bidx, wslot].set(k_m, mode="drop")
            v_buf = entry["v_m"].at[bidx, wslot].set(v_m, mode="drop")
            out["pos"] = entry["pos"].at[bidx, wslot].set(
                pos.astype(jnp.int32), mode="drop")
            napp = mf
        acc_k = entry["acc_k"] + st_k
        acc_v = entry["acc_v"] + st_v
        out["tot_k"] = entry["tot_k"] + st_k
        out["tot_v"] = entry["tot_v"] + st_v
        out["n_app"] = entry["n_app"] + napp

        # §5 controller, per slot, every update_interval appends.
        apply = jnp.mod(out["n_app"], float(cfg.update_interval)) == 0.0
        if mask is not None:
            apply = apply & mask
        st = controller_step(
            ScaleState(exps={"k": entry["k_e"], "v": entry["v_e"]},
                       acc={"k": acc_k, "v": acc_v}),
            max_overflow_rate=cfg.max_overflow_rate, apply=apply)
        out["k_e"], out["v_e"] = st.exps["k"], st.exps["v"]
        out["acc_k"], out["acc_v"] = st.acc["k"], st.acc["v"]
        de_k = out["k_e"] - entry["k_e"]
        de_v = out["v_e"] - entry["v_e"]
        # exponents move at most every update_interval appends: skip the
        # full-buffer re-grid (an extra cache read-modify-write per token)
        # on the steps where nothing changed
        out["k_m"], out["v_m"] = jax.lax.cond(
            jnp.any(de_k != 0.0) | jnp.any(de_v != 0.0),
            lambda a: (_rescale(a[0], de_k, cfg.width),
                       _rescale(a[1], de_v, cfg.width)),
            lambda a: a, (k_buf, v_buf))
        return out

    def append_chunk(self, entry: dict, k_new: Array, v_new: Array,
                     p0: Array, n_valid: Array) -> dict:
        """Quantize-on-write for one prefill chunk (positions ``p0+i``).

        The chunk's fresh f32 K/V ``[B, C, K, hd]`` is packed straight to
        int mantissas against the slot's exponents — the pool never holds
        f32.  ``p0 == 0`` marks the **admission** chunk, which behaves
        like :meth:`pack_entry` for its slot: stale ring positions reset
        to -1, exponents calibrate from this chunk's max-magnitude (with
        the margin bit), statistics and the append counter reset, and the
        chunk's own quantization is not counted as appends.  Later chunks
        count their valid rows as appends and run the §5 controller on
        every ``update_interval`` crossing, rescaling stored mantissas in
        place when an exponent moves — exactly the per-token
        :meth:`append` discipline, batched.  Rows ``>= n_valid`` (ragged
        final chunk) and rows evicted within the same chunk (``C`` larger
        than a windowed cap) are dropped from both writes and statistics.
        """
        cfg = self.cfg
        W = entry["k_m"].shape[1]
        B, C = k_new.shape[:2]
        idx = jnp.arange(C, dtype=jnp.int32)
        pos = p0[:, None] + idx[None, :]                         # [B, C]
        keep = (idx[None, :] < n_valid[:, None]) & \
            (pos >= p0[:, None] + n_valid[:, None] - W)
        first = p0 == 0                                          # [B]

        def _cal(x):
            ax = jnp.max(jnp.abs(x.astype(jnp.float32))
                         * keep[..., None, None], axis=(1, 2, 3))
            return calibrate_exp(ax, cfg.width, cfg.margin_bits)

        k_e = jnp.where(first, _cal(k_new), entry["k_e"])
        v_e = jnp.where(first, _cal(v_new), entry["v_e"])

        out = dict(entry)
        key_k = key_v = det = None
        if cfg.stochastic:
            ks = jax.vmap(lambda k: jax.random.split(k, 3))(entry["key"])
            key_k, key_v, out["key"] = ks[:, 0], ks[:, 1], ks[:, 2]
            det = first    # admission rounds deterministically (pack_entry)
        k_m, st_k = _pack_chunk(k_new, cfg.width, k_e, keep, key_k, det)
        v_m, st_v = _pack_chunk(v_new, cfg.width, v_e, keep, key_v, det)
        slot = jnp.where(keep, pos % W, W).astype(jnp.int32)
        bidx = jnp.arange(B)[:, None]
        k_buf = entry["k_m"].at[bidx, slot].set(k_m, mode="drop")
        v_buf = entry["v_m"].at[bidx, slot].set(v_m, mode="drop")
        pos_buf = jnp.where(first[:, None], -1, entry["pos"])
        out["pos"] = pos_buf.at[bidx, slot].set(pos.astype(jnp.int32),
                                                mode="drop")

        zero3 = jnp.zeros((B, 3), jnp.float32)
        f1 = first[:, None]
        acc_k = jnp.where(f1, zero3, entry["acc_k"] + st_k)
        acc_v = jnp.where(f1, zero3, entry["acc_v"] + st_v)
        out["tot_k"] = jnp.where(f1, zero3, entry["tot_k"] + st_k)
        out["tot_v"] = jnp.where(f1, zero3, entry["tot_v"] + st_v)
        cnt = jnp.sum(keep, axis=1).astype(jnp.float32)
        n_prev = jnp.where(first, 0.0, entry["n_app"])
        n_new = jnp.where(first, 0.0, entry["n_app"] + cnt)
        out["n_app"] = n_new

        interval = float(cfg.update_interval)
        apply = jnp.floor(n_new / interval) > jnp.floor(n_prev / interval)
        st = controller_step(
            ScaleState(exps={"k": k_e, "v": v_e},
                       acc={"k": acc_k, "v": acc_v}),
            max_overflow_rate=cfg.max_overflow_rate, apply=apply)
        out["k_e"], out["v_e"] = st.exps["k"], st.exps["v"]
        out["acc_k"], out["acc_v"] = st.acc["k"], st.acc["v"]
        de_k = out["k_e"] - k_e
        de_v = out["v_e"] - v_e
        out["k_m"], out["v_m"] = jax.lax.cond(
            jnp.any(de_k != 0.0) | jnp.any(de_v != 0.0),
            lambda a: (_rescale(a[0], de_k, cfg.width),
                       _rescale(a[1], de_v, cfg.width)),
            lambda a: a, (k_buf, v_buf))
        return out

    def fused_prefill(self, entry: dict, qg: Array, k_new: Array,
                      v_new: Array, p0: Array, n_valid: Array, *,
                      scale: float, window=None, causal: bool = True):
        """Flash-prefill directly on the packed mantissas (no ``load``).

        ``qg``: [B, C, K, G, hd] chunk query groups; the kernel
        dequantizes int8/int16 history tiles in-register against the
        per-slot exponents and attends the chunk's own ``k_new``/``v_new``
        from f32.  Returns f32 [B, C, K, G, hd].
        """
        from repro.kernels.attn.ops import flash_prefill
        return flash_prefill(qg, k_new, v_new, entry["k_m"], entry["v_m"],
                             entry["pos"], p0, n_valid, entry["k_e"],
                             entry["v_e"], width=self.cfg.width, scale=scale,
                             window=window, causal=causal,
                             tp_axis=self.tp_axis)

    # -- pool management (full [n, B, ...] shapes, outside the scan) ------
    def init_like(self, raw: dict) -> dict:
        """Packed zero-entry matching a raw ``{"k","v","pos"}`` entry."""
        n, B, W = raw["pos"].shape
        idtype = container_dtype(self.cfg.width)
        entry = {
            "k_m": jnp.zeros(raw["k"].shape, idtype),
            "v_m": jnp.zeros(raw["v"].shape, idtype),
            "k_e": jnp.zeros((n, B), jnp.float32),
            "v_e": jnp.zeros((n, B), jnp.float32),
            "pos": jnp.full((n, B, W), -1, jnp.int32),
            "acc_k": jnp.zeros((n, B, 3), jnp.float32),
            "acc_v": jnp.zeros((n, B, 3), jnp.float32),
            "tot_k": jnp.zeros((n, B, 3), jnp.float32),
            "tot_v": jnp.zeros((n, B, 3), jnp.float32),
            "n_app": jnp.zeros((n, B), jnp.float32),
        }
        if self.cfg.stochastic:
            entry["key"] = jnp.zeros((n, B, 2), jnp.uint32)
        return entry

    def pack_entry(self, raw: dict, slot_keys: Optional[Array] = None) -> dict:
        """Quantize a fresh prefill entry ``[n, g, ...]`` for pool insertion.

        Exponents are calibrated per layer/slot from the prompt K/V
        max-magnitude (empty ring slots, ``pos < 0``, are excluded);
        accumulators start at zero. ``slot_keys`` [g, 2] seeds the
        per-slot PRNG chains in stochastic mode.
        """
        cfg = self.cfg
        n, g, W = raw["pos"].shape
        valid = (raw["pos"] >= 0)[..., None, None]

        def _cal(x):
            ax = jnp.max(jnp.abs(x.astype(jnp.float32)) * valid,
                         axis=(2, 3, 4))
            return calibrate_exp(ax, cfg.width, cfg.margin_bits)

        k_e, v_e = _cal(raw["k"]), _cal(raw["v"])
        exp = (..., None, None, None)
        entry = {
            "k_m": pack(raw["k"], cfg.width, k_e[exp]).mantissa,
            "v_m": pack(raw["v"], cfg.width, v_e[exp]).mantissa,
            "k_e": k_e,
            "v_e": v_e,
            "pos": raw["pos"],
            "acc_k": jnp.zeros((n, g, 3), jnp.float32),
            "acc_v": jnp.zeros((n, g, 3), jnp.float32),
            "tot_k": jnp.zeros((n, g, 3), jnp.float32),
            "tot_v": jnp.zeros((n, g, 3), jnp.float32),
            "n_app": jnp.zeros((n, g), jnp.float32),
        }
        if cfg.stochastic:
            if slot_keys is None:
                raise ValueError("stochastic cache needs per-slot keys")
            # domain-tag the cache chain: the same per-request root also
            # seeds the sampler stream (folded by absolute position), and
            # positions never reach 2**31 - 1
            roots = jax.vmap(jax.random.fold_in, (0, None))(
                slot_keys, 2 ** 31 - 1)
            entry["key"] = jax.vmap(
                lambda i: jax.vmap(jax.random.fold_in, (0, None))(
                    roots, i))(jnp.arange(n))
        return entry


def make_pool(cfg: T.ModelConfig, max_slots: int, max_len: int,
              codec: Optional[PackedKVCodec] = None) -> dict:
    """Zero slot pool: ``init_cache`` with attn entries optionally packed."""
    raw = T.init_cache(cfg, max_slots, max_len)
    if codec is None:
        return raw
    return {sname: {bkey: codec.init_like(e) if is_attn_entry(e) else e
                    for bkey, e in sc.items()}
            for sname, sc in raw.items()}


@dataclasses.dataclass
class KVPool:
    """A constructed serve KV pool: device pytree + codec + layout facts.

    What :func:`make_kv_pool` returns — the engine consumes it wholesale
    instead of re-deriving the raw/slot-major/paged branching inline.

    ``codec`` is ``None`` for the plain f32 ring pool (the model layer
    falls back to ``RAW_KV_CODEC``), else the codec whose ``init_like``
    produced ``pool``.  ``shardings`` is the ``NamedSharding`` tree the
    pool was placed with (mesh runs only); the engine re-constrains the
    donated pool to it after every jit so GSPMD cannot drift the layout.
    """

    pool: dict
    codec: object
    cache_cfg: Optional[CacheQuantConfig]
    page_size: int                    # 0 = slot-major
    total_pages: int                  # incl. the null page; 0 if slot-major
    nblocks: int                      # block-table width; 0 if slot-major
    shardings: Optional[dict] = None

    @property
    def packed(self) -> bool:
        return self.cache_cfg is not None

    @property
    def paged(self) -> bool:
        return bool(self.page_size)


def make_kv_pool(cfg: T.ModelConfig, policy, dist=None, *, max_slots: int,
                 max_len: int, cache_bits: int = 0,
                 cache_cfg: Optional[CacheQuantConfig] = None,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None, mesh=None,
                 fused_decode: Optional[bool] = None) -> KVPool:
    """Build the serve KV pool — the one place that owns the layout choice.

    Resolves the raw / slot-major-packed / paged decision (``cache_bits``,
    ``page_size``, with ``policy`` supplying defaults), constructs the
    matching codec with its capabilities (``fused_decode`` — explicit
    argument, else ``policy.fused_decode`` — and the serving-TP axis),
    and zero-initializes the pool.  With an active ``dist`` + ``mesh``
    the pool is placed sharded per
    :meth:`repro.dist.sharding.ShardingRules.pool_shardings`: kv heads
    over ``model`` (TP), and — for slot-major pools under ``cp_decode``
    — the ring window over ``data`` (CP).

    Incoherent parallelism requests raise
    :class:`repro.dist.MeshConfigError` here, at construction, instead
    of a late jit/GSPMD failure: an active context without its mesh, CP
    over a paged arena (pages tile the very axis CP would shard), a KV
    window the CP degree does not divide.
    """
    from repro.dist import DistCtx, MeshConfigError
    from repro.models import layers as L
    from . import paged as paged_mod

    dist = dist or DistCtx()
    if dist.active and mesh is None:
        raise MeshConfigError(
            "an active DistCtx needs the mesh it names; pass "
            "mesh=launch.mesh.make_serve_mesh(...)")
    if dist.active:
        missing = [a for a in dist.all_axes if a not in mesh.shape]
        if missing:
            raise MeshConfigError(
                f"DistCtx names mesh axes {missing} absent from the mesh "
                f"{dict(mesh.shape)}")

    fused = bool(getattr(policy, "fused_decode", False)) \
        if fused_decode is None else bool(fused_decode)
    psize = page_size if page_size is not None else \
        int(getattr(policy, "page_size", 0))
    psize = int(psize) if psize else 0
    tp_axis = "model" if (dist.active and "model" in dist.all_axes) else None
    cp = bool(dist.active and dist.cp_decode and dist.cp_axis)
    if cp and psize:
        raise MeshConfigError(
            "context parallelism cannot shard a paged arena: pages tile "
            "the window axis CP would shard — use the slot-major pool "
            "(page_size=0) with cp, or drop cp for paged serving")
    if cp:
        cp_size = int(mesh.shape.get(dist.cp_axis, 1))
        if cp_size > 1 and max_len % cp_size:
            raise MeshConfigError(
                f"max_len {max_len} is not divisible by the CP degree "
                f"{cp_size}: the KV window must shard evenly")

    if cache_bits:
        ccfg = cache_cfg or CacheQuantConfig(width=cache_bits)
        if ccfg.width != cache_bits:
            raise ValueError("cache_bits and cache_cfg.width disagree")
    else:
        ccfg = None    # a cache_cfg without cache_bits is ignored (f32)

    if psize:
        if cfg.family != "dense" or cfg.num_experts or cfg.encoder_layers:
            raise ValueError(
                "paged KV pool requires the dense attention family "
                "(chunked prefill writes pages incrementally)")
        codec = paged_mod.PagedKVCodec(psize, ccfg, tp_axis=tp_axis)
        codec._fused_decode = fused
        pool = paged_mod.make_paged_pool(cfg, max_slots, max_len, codec,
                                         n_pages=n_pages)
        nblocks = -(-max_len // psize)
        total_pages = n_pages if n_pages is not None else \
            1 + max_slots * nblocks
    else:
        nblocks, total_pages = 0, 0
        if ccfg is not None:
            codec = PackedKVCodec(ccfg, tp_axis=tp_axis)
            codec._fused_decode = fused
        elif fused:
            # f32 pool, fused decode: the raw codec routes attention
            # through the flash kernels (width=None)
            codec = L.RawKVCodec(tp_axis=tp_axis)
            codec._fused_decode = True
        else:
            codec = None
        pool = make_pool(cfg, max_slots, max_len,
                         codec if ccfg is not None else None)

    shardings = None
    if dist.active:
        from repro.dist.sharding import ShardingRules
        rules = ShardingRules(mesh, shard_batch=False, seq_shard_cache=cp)
        shardings = rules.pool_shardings(pool)
        pool = jax.device_put(pool, shardings)
    return KVPool(pool=pool, codec=codec, cache_cfg=ccfg, page_size=psize,
                  total_pages=total_pages, nblocks=nblocks,
                  shardings=shardings)


def insert(pool: dict, raw_entry: dict, slots: Array,
           codec: Optional[PackedKVCodec] = None,
           slot_keys: Optional[Array] = None) -> dict:
    """Write a fresh prefill cache (group size g) into pool rows ``slots``.

    ``raw_entry`` is what ``transformer.prefill`` returns (float K/V ring
    buffers); in packed mode each attn entry is quantized via
    ``codec.pack_entry`` first. Jit-safe (``slots`` may be traced).
    """
    new_pool = {}
    for sname, sc in pool.items():
        new_sc = {}
        for bkey, pe in sc.items():
            src = raw_entry[sname][bkey]
            if codec is not None and "k_m" in pe:
                src = codec.pack_entry(src, slot_keys)
            new_sc[bkey] = jax.tree_util.tree_map(
                lambda dst, s: dst.at[:, slots].set(s), pe, src)
        new_pool[sname] = new_sc
    return new_pool


def seed_slot_keys(pool: dict, slot, key: Array) -> dict:
    """Seed one slot's stochastic-rounding chains before chunked admission.

    Mirrors :meth:`PackedKVCodec.pack_entry`'s derivation — a
    domain-tagged per-request root folded by layer index — so a request's
    cache stream is the same whichever admission path seeds it.
    ``slot`` may be traced (jit-safe); no-op for pools without ``key``
    fields (deterministic rounding).
    """
    root = jax.random.fold_in(key, 2 ** 31 - 1)
    new_pool = {}
    for sname, sc in pool.items():
        new_sc = {}
        for bkey, e in sc.items():
            if isinstance(e, dict) and "key" in e:
                n = e["key"].shape[0]
                layer_keys = jax.vmap(jax.random.fold_in, (None, 0))(
                    root, jnp.arange(n))
                e = dict(e)
                e["key"] = e["key"].at[:, slot].set(layer_keys)
            new_sc[bkey] = e
        new_pool[sname] = new_sc
    return new_pool


def overflow_summary(pool: dict, active=None) -> dict:
    """Cumulative append overflow rates of the packed pool (metrics hook).

    ``active``: optional bool [B] mask restricting the summary to occupied
    slots (freed slots keep decoding garbage into their own rows).
    Returns zeros for float32 pools (slot-major or paged).

    Paged pools keep statistics per PAGE, not per slot: the summary walks
    the active slots' block tables and counts each referenced page ONCE,
    however many requests share it (a shared prefix page's appends
    happened once, on first write).  With ``active=None`` every non-null
    page counts, including residue on freed-but-unreused pages.
    """
    ovf = tot = 0.0
    for sc in pool.values():
        for e in sc.values():
            if "k_m" not in e or "tot_k" not in e:
                continue
            if "bt" in e:                 # paged: per-page statistics
                n, n_pages = e["tot_k"].shape[:2]
                if active is None:
                    used = jnp.ones((n, n_pages), bool)
                else:
                    act = jnp.asarray(active, bool)
                    sel = jnp.where(act[None, :, None], e["bt"], 0)
                    off = jnp.arange(n)[:, None, None] * n_pages
                    used = jnp.zeros((n * n_pages,), bool).at[
                        (sel + off).reshape(-1)].set(True)
                    used = used.reshape(n, n_pages)
                used = used.at[:, 0].set(False)   # null page never counts
                m = used.astype(jnp.float32)[..., None]
                for t in (e["tot_k"], e["tot_v"]):
                    ovf = ovf + float(jnp.sum((t * m)[..., 0]))
                    tot = tot + float(jnp.sum((t * m)[..., 2]))
                continue
            for t in (e["tot_k"], e["tot_v"]):
                t = t if active is None else t * jnp.asarray(
                    active, jnp.float32)[None, :, None]
                ovf = ovf + float(jnp.sum(t[..., 0]))
                tot = tot + float(jnp.sum(t[..., 2]))
    return {"cache_overflow_rate": ovf / tot if tot else 0.0,
            "cache_appends_quantized": tot}


def slot_overflow_rates(pool: dict, n_slots: int) -> Array:
    """Per-slot cumulative §5 overflow rate, jit-safe — the runaway sentinel.

    Returns f32 [n_slots]: overflowed elements / quantized elements of
    each slot's appends since admission, summed over layers and K/V.
    Slot-major packed pools read their per-slot counters directly; paged
    pools gather per-page counters through each slot's block table (the
    null page carries zeros).  Float32 pools (no counters) return zeros.

    The engine evaluates this inside the decode jit and harvests it with
    the sampled tokens: a slot whose §5 controller has lost the overflow
    race (rate above the engine's ``runaway_ovf`` threshold) is
    quarantined as FAILED instead of silently poisoning the batch.
    """
    ovf = jnp.zeros((n_slots,), jnp.float32)
    tot = jnp.zeros((n_slots,), jnp.float32)
    for sc in pool.values():
        for e in sc.values():
            if "k_m" not in e or "tot_k" not in e:
                continue
            if "bt" in e:                 # paged: gather via block table
                for t in (e["tot_k"], e["tot_v"]):
                    g = jax.vmap(lambda tl, btl: tl[btl])(t, e["bt"])
                    ovf = ovf + jnp.sum(g[..., 0], axis=(0, 2))
                    tot = tot + jnp.sum(g[..., 2], axis=(0, 2))
                continue
            for t in (e["tot_k"], e["tot_v"]):
                ovf = ovf + jnp.sum(t[..., 0], axis=0)
                tot = tot + jnp.sum(t[..., 2], axis=0)
    return ovf / jnp.maximum(tot, 1.0)


def numerics_snapshot(pool: dict, n_slots: int) -> dict:
    """Per-layer/per-slot §5 exponents + overflow counters, jit-safe.

    The serve-side numeric-health sample (:mod:`repro.obs.numerics`): for
    every packed attention entry, f32 ``[n_layers, n_slots]`` arrays

    * ``k_e`` / ``v_e`` — the controller-managed shared exponents.  Paged
      pools store exponents per PAGE; each slot reports its *newest*
      mapped page's exponent (the one current appends quantize against —
      where the controller is acting);
    * ``ovf`` / ``half`` / ``tot`` — cumulative append counters
      (overflowed, would-overflow-at-half-range, quantized) summed over
      K+V, gathered through the block table for paged pools.

    Keyed ``"sname/bkey"`` per entry; empty dict for float32 pools.  The
    engine jits this once and fetches one sample per controller interval
    — a single batched device sync, nothing added per step.
    """
    out: Dict[str, dict] = {}
    for sname, sc in pool.items():
        for bkey, e in sc.items():
            if not isinstance(e, dict) or "k_m" not in e or "tot_k" not in e:
                continue
            if "bt" in e:                 # paged: gather via block table
                bt = e["bt"]                              # [n, B, nblocks]
                # newest mapped page per slot (page 0 is the null page)
                last = jnp.maximum(jnp.sum(bt != 0, axis=-1) - 1, 0)
                newest = jnp.take_along_axis(bt, last[..., None],
                                             axis=-1)[..., 0]   # [n, B]
                k_e = jnp.take_along_axis(e["k_e"], newest, axis=1)
                v_e = jnp.take_along_axis(e["v_e"], newest, axis=1)
                g = jax.vmap(lambda tl, btl: tl[btl])(
                    e["tot_k"], bt) + jax.vmap(lambda tl, btl: tl[btl])(
                    e["tot_v"], bt)                       # [n, B, nblk, 3]
                cnt = jnp.sum(g, axis=2)                  # [n, B, 3]
            else:                         # slot-major: direct per-slot
                k_e, v_e = e["k_e"], e["v_e"]             # [n, B]
                cnt = e["tot_k"] + e["tot_v"]             # [n, B, 3]
            out[f"{sname}/{bkey}"] = {
                "k_e": k_e[:, :n_slots], "v_e": v_e[:, :n_slots],
                "ovf": cnt[:, :n_slots, 0], "half": cnt[:, :n_slots, 1],
                "tot": cnt[:, :n_slots, 2]}
    return out


def slot_totals(pool: dict, slot) -> Array:
    """One slot's cumulative ``(ovf, ovf_half, total)`` over all layers.

    Admission (``pack_entry``) zeroes the slot's counters, so between admit
    and finish this is exactly the occupying request's append statistics —
    the engine harvests it when the request completes.

    Paged pools: gathers the per-page counters of every page on the
    slot's block table (the null page carries zeros).  Pages a request
    inherited from a shared prefix count toward each request that maps
    them — totals are per-request by design, mirroring the slot-major
    semantics where each request re-appends its own prefix.
    """
    out = jnp.zeros((3,), jnp.float32)
    for sc in pool.values():
        for e in sc.values():
            if "k_m" not in e or "tot_k" not in e:
                continue
            if "bt" in e:                 # paged: walk the block table
                idx = e["bt"][:, slot][..., None]       # [n, nblocks, 1]
                for t in (e["tot_k"], e["tot_v"]):
                    g = jnp.take_along_axis(t, jnp.broadcast_to(
                        idx, idx.shape[:2] + (3,)), axis=1)
                    out = out + jnp.sum(g, axis=(0, 1))
                continue
            out = out + jnp.sum(e["tot_k"][:, slot], axis=0)
            out = out + jnp.sum(e["tot_v"][:, slot], axis=0)
    return out
