"""End-to-end driver: train a ~100M-param transformer LM with DFXP 10/12 for
a few hundred steps on synthetic data, with calibration, checkpointing, and
resume — the complete production path at CPU scale.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch.train import main as train_main
from repro.models.transformer import ModelConfig

# a ~100M dense transformer (defined inline: this is the end-to-end example,
# independent of the 10 assigned configs)
LM_100M = ModelConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
    tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    import repro.configs as configs
    # register the inline config under a temp name
    import types
    mod = types.SimpleNamespace(CONFIG=LM_100M, SMOKE=LM_100M,
                                CELLS=("train_4k",))
    sys.modules["repro.configs.lm_100m"] = mod

    n_params = (LM_100M.num_layers * (
        LM_100M.d_model * (LM_100M.num_heads + 2 * LM_100M.num_kv_heads
                           + LM_100M.num_heads) * LM_100M.head_dim
        + 3 * LM_100M.d_model * LM_100M.d_ff)
        + LM_100M.vocab_size * LM_100M.d_model)
    print(f"~{n_params/1e6:.0f}M params")

    train_main([
        "--arch", "lm_100m", "--steps", str(args.steps),
        "--global-batch", "16", "--seq-len", "128",
        "--arithmetic", "dfxp", "--comp-width", "10", "--update-width", "12",
        "--update-interval", "20", "--calibrate-steps", "5",
        "--optimizer", "adamw", "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
