"""Structured tracing: span/instant/counter events → Chrome-trace JSON.

A :class:`Tracer` is a process-local, dependency-free event recorder the
serve engine (and anything else) threads its step phases through:

* **spans** — ``with tracer.span("decode_step", n_active=3): ...`` (or the
  explicit :meth:`begin`/:meth:`end` pair) record a named duration on one
  track.  Spans nest per track; export writes them as Chrome-trace
  complete events (``ph: "X"``) whose ``ts``/``dur`` containment encodes
  the nesting, which both ``chrome://tracing`` and Perfetto render as
  stacked slices.
* **instants** — point events (``submit``, ``finish``, ``preempt``,
  fault-harness injections) rendered as markers.
* **counters** — named numeric series (queue depth, active slots, §5
  overflow rates, dispatch-profile tallies) rendered as stacked area
  charts.

Everything is host-side and allocation-light (one small dict per event);
nothing here ever touches a device array.  The zero-cost-when-disabled
contract lives at the call sites: code holds ``tracer = None`` and guards
every hook with ``if tracer is not None`` — this module is simply never
imported on the hot path of an unobserved run.

:func:`export` / :func:`to_chrome` produce the Chrome trace event format
(``{"traceEvents": [...]}``) sorted so parents precede children —
loadable directly in ``chrome://tracing`` or https://ui.perfetto.dev.
:func:`validate_trace` is the schema check CI runs against the artifact.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

# Chrome trace event phases this module emits (and the validator accepts).
_PHASES = {"X", "i", "C", "M"}


class _SpanCtx:
    """Context manager closing one span on one track."""

    __slots__ = ("_tracer", "_tid")

    def __init__(self, tracer: "Tracer", tid: str):
        self._tracer = tracer
        self._tid = tid

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._tracer.end(tid=self._tid)
        return False


class Tracer:
    """Process-local trace-event recorder (Chrome trace event format).

    ``tid`` names the track an event lands on (one per logical timeline:
    ``"engine"`` for step phases, ``"requests"`` for lifecycle instants,
    ``"numerics"`` for controller samples...).  Spans must nest per
    track — :meth:`end` closes the innermost open span of its track.

    ``clock`` defaults to ``time.perf_counter`` (monotonic); timestamps
    are microseconds since the tracer was created, which is what the
    Chrome trace viewer expects in the ``ts`` field.
    """

    def __init__(self, clock=None, pid: int = 0):
        self._clock = clock or time.perf_counter
        self._t0 = self._clock()
        self.pid = pid
        self.events: List[dict] = []
        self._open: Dict[str, List[dict]] = {}   # tid -> open-span stack

    # -- clock ------------------------------------------------------------
    def now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    # -- event emitters ---------------------------------------------------
    def begin(self, name: str, tid: str = "engine", **args) -> None:
        """Open a span on ``tid``; close it with :meth:`end`."""
        self._open.setdefault(tid, []).append(
            {"name": name, "ts": self.now_us(), "args": args})

    def end(self, tid: str = "engine", **args) -> None:
        """Close the innermost open span on ``tid``."""
        stack = self._open.get(tid)
        if not stack:
            raise RuntimeError(f"Tracer.end on track {tid!r} with no open span")
        sp = stack.pop()
        if args:
            sp["args"].update(args)
        ev = {"name": sp["name"], "ph": "X", "ts": sp["ts"],
              "dur": self.now_us() - sp["ts"], "pid": self.pid, "tid": tid}
        if sp["args"]:
            ev["args"] = sp["args"]
        self.events.append(ev)

    def span(self, name: str, tid: str = "engine", **args) -> _SpanCtx:
        """``with tracer.span("phase"): ...`` — begin/end as a context."""
        self.begin(name, tid=tid, **args)
        return _SpanCtx(self, tid)

    def instant(self, name: str, tid: str = "engine", **args) -> None:
        ev = {"name": name, "ph": "i", "ts": self.now_us(), "pid": self.pid,
              "tid": tid, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, values: Dict[str, float],
                tid: str = "counters") -> None:
        """One sample of a multi-series counter (rendered as stacked area)."""
        self.events.append(
            {"name": name, "ph": "C", "ts": self.now_us(), "pid": self.pid,
             "tid": tid, "args": {k: float(v) for k, v in values.items()}})

    # -- export -----------------------------------------------------------
    def to_chrome(self, process_name: str = "repro") -> dict:
        """Chrome trace object: open spans are closed at 'now', events are
        sorted so a parent span precedes its children (Perfetto builds the
        slice stack from ``ts`` order + ``ts+dur`` containment)."""
        for tid in list(self._open):
            while self._open[tid]:
                self.end(tid=tid, unclosed_at_export=True)
        meta = [{"name": "process_name", "ph": "M", "pid": self.pid, "tid": t,
                 "ts": 0.0, "args": {"name": process_name}}
                for t in ("engine",)]
        meta += [{"name": "thread_name", "ph": "M", "pid": self.pid,
                  "ts": 0.0, "tid": tid, "args": {"name": tid}}
                 for tid in sorted({e["tid"] for e in self.events})]
        evs = sorted(self.events, key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        return {"traceEvents": meta + evs, "displayTimeUnit": "ms"}

    def export(self, path: str, process_name: str = "repro") -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(process_name), f)
        return path

    # -- introspection (tests / assertions) -------------------------------
    def span_names(self) -> List[str]:
        return [e["name"] for e in self.events if e["ph"] == "X"]

    def find(self, name: str, ph: Optional[str] = None) -> List[dict]:
        return [e for e in self.events
                if e["name"] == name and (ph is None or e["ph"] == ph)]


def validate_trace(obj: dict) -> None:
    """Raise ``ValueError`` unless ``obj`` is a loadable Chrome trace.

    Checks the schema CI asserts on the ``--trace-out`` artifact:

    * top level: dict with a ``traceEvents`` list;
    * every event: ``name`` (str), ``ph`` in {X, i, C, M}, numeric
      ``ts >= 0``, ``pid``/``tid`` present;
    * complete events: numeric ``dur >= 0``;
    * counter events: an ``args`` dict of numbers;
    * ordering: non-meta events sorted by ``ts``, and per track every pair
      of spans either nests or is disjoint (Perfetto's slice-stack
      precondition — overlapping non-nested spans on one track are the
      classic way a trace loads blank).
    """
    if not isinstance(obj, dict) or not isinstance(
            obj.get("traceEvents"), list):
        raise ValueError("trace must be a dict with a traceEvents list")
    spans_by_track: Dict[tuple, List[tuple]] = {}
    last_ts = None
    for i, e in enumerate(obj["traceEvents"]):
        if not isinstance(e, dict):
            raise ValueError(f"event {i} is not a dict")
        if not isinstance(e.get("name"), str):
            raise ValueError(f"event {i} has no name")
        ph = e.get("ph")
        if ph not in _PHASES:
            raise ValueError(f"event {i} ({e['name']}) has bad ph {ph!r}")
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i} ({e['name']}) has bad ts {ts!r}")
        if "pid" not in e or "tid" not in e:
            raise ValueError(f"event {i} ({e['name']}) missing pid/tid")
        if last_ts is not None and ts < last_ts:
            raise ValueError(f"event {i} ({e['name']}) out of ts order")
        last_ts = ts
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"span {i} ({e['name']}) has bad dur {dur!r}")
            spans_by_track.setdefault((e["pid"], e["tid"]), []).append(
                (ts, ts + dur, e["name"]))
        elif ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                raise ValueError(
                    f"counter {i} ({e['name']}) needs numeric args")
    for track, spans in spans_by_track.items():
        open_ends: List[float] = []      # enclosing spans' end times
        for ts, te, name in spans:       # already ts-sorted
            while open_ends and ts >= open_ends[-1] - 1e-9:
                open_ends.pop()
            if open_ends and te > open_ends[-1] + 1e-9:
                raise ValueError(
                    f"span {name!r} on track {track} overlaps its "
                    "enclosing span without nesting")
            open_ends.append(te)


__all__ = ["Tracer", "validate_trace"]
