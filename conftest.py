# Root conftest: puts the repo root on sys.path (for `import benchmarks`)
# under bare `pytest` invocations. Deliberately does NOT touch XLA_FLAGS —
# tests must see 1 CPU device; multi-device tests spawn subprocesses
# (see tests/test_dist.py), and only launch/dryrun.py forces 512 devices.
