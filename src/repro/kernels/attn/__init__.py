"""Fused Pallas decode-attention over the packed KV pool (flash-decode)."""
from .ops import flash_decode  # noqa: F401
from .ref import decode_attention_ref  # noqa: F401
