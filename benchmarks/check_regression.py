"""CI bench-regression gate: diff a fresh bench run against the committed
``BENCH_kernels.json`` / ``BENCH_serve.json``.

Two failure classes:

* **missing rows** — every row name in the committed baseline must appear
  in the fresh run.  A suite that silently drops a row pair (e.g. a fused
  variant stops executing) reads as "measured, no regression" otherwise.
* **per-row regression** — CI machines are not a perf reference, so raw
  times are never compared across machines.  Instead each shared row's
  ``fresh/committed`` time ratio is normalized by the **median** ratio
  over all shared rows (the median cancels uniform machine/backend speed
  differences), and a row whose normalized time grows beyond
  ``1 + tolerance`` fails: *that row* got slower relative to the rest of
  the suite — exactly what a hot-path regression looks like.

Both files must be recorded at the same shapes (``meta.tiny`` must
match) — the committed baselines are recorded with ``--tiny``, the CI
shapes, precisely so this gate has teeth; the nightly lane records the
full-shape rows as artifacts without gating.  A commit whose message
carries the ``[bench-waiver]`` tag skips the gate (the workflow checks
the tag before invoking this script).

Tolerance calibration (measured on idle cross-runs of the tiny suites):
serve rows are whole-wave aggregates that agree within ~1.3x between
benign runs, but shared-VM throttling occasionally inflates a whole row
3x for one run — which is why both sides of the gate use per-row
**minimums**: the committed baselines are min-merged over several
recording runs (``--merge-out``), and the workflow's retry min-merges
its two fresh runs, so one-sided throttle spikes cancel while real
regressions (present in every run) survive the 25% band.  Kernel
micro-rows are sub-ms minimums that spread several-x regardless, so the
workflow gates them at a wide 4.0 band — catching recompile-per-call
and accidentally-quadratic regressions while row *presence* stays
strict.

Usage::

    python -m benchmarks.check_regression \
        --committed BENCH_serve.json --fresh /tmp/BENCH_serve.json \
        --tolerance 0.25
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from typing import List


def compare(committed: dict, fresh: dict, tolerance: float = 0.25,
            metric: str = "us_per_call",
            mem_tolerance: float = 0.25) -> List[str]:
    """Return the list of gate violations (empty = pass).

    Rows tagged ``"kind": "mem"`` hold pool HBM **bytes** per request —
    deterministic at fixed shapes, so they are diffed as direct
    ``fresh/committed`` ratios against ``mem_tolerance`` and excluded
    from the time rows' median normalization (a byte count's ~1.0 ratio
    would drag the median away from the timing noise it must cancel).
    """
    problems: List[str] = []
    kinds = {r["name"]: r.get("kind", "time")
             for r in committed.get("rows", [])}
    base = {r["name"]: float(r[metric]) for r in committed.get("rows", [])}
    new = {r["name"]: float(r[metric]) for r in fresh.get("rows", [])}
    if not base:
        return ["committed baseline has no rows"]

    missing = sorted(set(base) - set(new))
    problems += [f"missing row: {n}" for n in missing]

    c_tiny = committed.get("meta", {}).get("tiny")
    f_tiny = fresh.get("meta", {}).get("tiny")
    if c_tiny != f_tiny:
        # different shapes make per-row ratios meaningless — this is a
        # recording-protocol error, not a perf signal
        problems.append(
            f"shape mismatch: committed tiny={c_tiny} vs fresh "
            f"tiny={f_tiny} — re-record the baseline at CI shapes")
        return problems

    for n in sorted(base):
        if kinds[n] != "mem" or n not in new or base[n] <= 0:
            continue
        ratio = new[n] / base[n]
        if ratio > 1.0 + mem_tolerance:
            problems.append(
                f"memory regression: {n} is {ratio:.2f}x the committed "
                f"bytes/request (committed {base[n]:.0f}B -> fresh "
                f"{new[n]:.0f}B, tolerance {1.0 + mem_tolerance:.2f}x)")

    shared = [n for n in base
              if n in new and base[n] > 0 and kinds[n] != "mem"]
    if not shared:
        return problems
    ratios = {n: new[n] / base[n] for n in shared}
    med = statistics.median(ratios.values())
    if med <= 0:
        return problems + ["non-positive median ratio (corrupt timings?)"]
    for n in sorted(shared):
        norm = ratios[n] / med
        if norm > 1.0 + tolerance:
            problems.append(
                f"regression: {n} is {norm:.2f}x the suite median "
                f"(committed {base[n]:.1f}us -> fresh {new[n]:.1f}us, "
                f"tolerance {1.0 + tolerance:.2f}x)")
    return problems


def merge_min(paths: List[str]) -> dict:
    """Per-row minimum across several runs of the same suite.

    Shared-VM throttling inflates whole rows for seconds at a time; the
    min across independent runs is the machine's actual floor, which is
    what both sides of the gate should compare.  Rows must exist in the
    first file; extra rows in later files are ignored, missing ones keep
    the best value seen so far.  ``meta`` is taken from the first file.
    """
    merged = json.load(open(paths[0]))
    best = {r["name"]: r for r in merged["rows"]}
    for p in paths[1:]:
        for r in json.load(open(p)).get("rows", []):
            cur = best.get(r["name"])
            if cur is not None and r["us_per_call"] < cur["us_per_call"]:
                best[r["name"]] = r
    merged["rows"] = [best[r["name"]] for r in merged["rows"]]
    return merged


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--committed",
                    help="baseline JSON committed in the repo")
    ap.add_argument("--fresh", nargs="+", default=[],
                    help="JSON(s) produced by this CI run; several files "
                         "are min-merged per row before comparing")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed median-normalized slowdown per row")
    ap.add_argument("--mem-tolerance", type=float, default=0.25,
                    help="allowed direct-ratio growth for kind=mem rows "
                         "(pool bytes/request; deterministic at fixed "
                         "shapes, no median normalization)")
    ap.add_argument("--merge-out",
                    help="write the min-merge of --fresh here and exit 0 "
                         "(baseline (re-)recording helper; no gating)")
    args = ap.parse_args(argv)
    if args.merge_out:
        with open(args.merge_out, "w") as f:
            json.dump(merge_min(args.fresh), f, indent=1)
        print(f"wrote per-row min of {len(args.fresh)} run(s) -> "
              f"{args.merge_out}")
        return 0
    if not args.committed or not args.fresh:
        ap.error("--committed and --fresh are required for gating")
    with open(args.committed) as f:
        committed = json.load(f)
    fresh = merge_min(args.fresh)
    problems = compare(committed, fresh, tolerance=args.tolerance,
                       mem_tolerance=args.mem_tolerance)
    if problems:
        for p in problems:
            print(f"BENCH GATE: {p}", file=sys.stderr)
        print(f"bench gate FAILED ({len(problems)} problem(s)); a "
              f"deliberate perf trade-off can be waived with a "
              f"[bench-waiver] commit-message tag", file=sys.stderr)
        return 1
    n = len(committed.get("rows", []))
    print(f"bench gate OK: {n} baseline rows present, none regressed "
          f"beyond {args.tolerance:.0%} of the suite median")
    return 0


if __name__ == "__main__":
    sys.exit(main())
