"""Multi-device serving: sharded bit-identity + the construction API.

Two halves:

* single-process tests for the redesigned construction surface —
  ``EngineOptions`` (and the one-release loose-kwarg shim), the
  ``make_kv_pool`` factory's codec/layout ownership, the codecs'
  deprecated ``fused_decode=`` constructor argument, and the typed
  ``MeshConfigError`` construction failures that need no real mesh;

* ``multidevice``-marked subprocess tests (the ``test_dist.py`` idiom:
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set before jax
  imports) pinning the load-bearing acceptance property — sharded
  engines produce greedy token streams **bit-identical** to the
  single-device engine: 2- and 4-way TP across f32/int8, fused and
  unfused pools, and a CP window-sharded long-context slot.
"""
import dataclasses
import subprocess
import sys
import textwrap
import warnings

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.dist import DistCtx, MeshConfigError, serve_pod_ctx
from repro.launch.mesh import make_serve_mesh
from repro.models import transformer as T
from repro.models.layers import RawKVCodec
from repro.serve import (
    CacheQuantConfig,
    EngineOptions,
    PackedKVCodec,
    PagedKVCodec,
    ServeEngine,
    make_kv_pool,
)

POL = PrecisionPolicy("float32")


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# EngineOptions + the one-release loose-kwarg shim
# ---------------------------------------------------------------------------

def test_legacy_kwargs_warn_and_build_identical_options(model):
    """Loose kwargs still work for one release: DeprecationWarning, and
    the resulting engine carries exactly the EngineOptions an options=
    caller would have passed."""
    cfg, params = model
    with pytest.warns(DeprecationWarning, match="options=EngineOptions"):
        legacy = ServeEngine(cfg, POL, params, max_slots=2, max_len=24,
                             cache_bits=8, seed=3, queue_cap=5)
    new = ServeEngine(cfg, POL, params, max_slots=2, max_len=24,
                      options=EngineOptions(cache_bits=8, seed=3,
                                            queue_cap=5))
    assert legacy.options == new.options
    assert legacy.seed == 3 and legacy.queue_cap == 5
    assert legacy.cache_cfg.width == new.cache_cfg.width == 8


def test_legacy_kwargs_overlay_explicit_options(model):
    """options= plus loose kwargs: the kwargs overlay field-by-field (and
    still warn) — a mixed caller mid-migration keeps working."""
    cfg, params = model
    with pytest.warns(DeprecationWarning):
        eng = ServeEngine(cfg, POL, params, max_slots=1, max_len=16,
                          options=EngineOptions(cache_bits=8), seed=7)
    assert eng.options == EngineOptions(cache_bits=8, seed=7)


def test_unknown_kwarg_raises_typeerror(model):
    cfg, params = model
    with pytest.raises(TypeError, match="cache_bitz"):
        ServeEngine(cfg, POL, params, max_slots=1, max_len=16,
                    cache_bitz=8)


def test_options_default_engine_has_no_warning(model):
    """The blessed path is warning-free."""
    cfg, params = model
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng = ServeEngine(cfg, POL, params, max_slots=1, max_len=16,
                          options=EngineOptions())
    assert eng.options == EngineOptions()
    assert eng.codec is None and not eng.dist.active


# ---------------------------------------------------------------------------
# codec fused_decode= deprecation + make_kv_pool factory ownership
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ctor", [
    lambda: RawKVCodec(True),
    lambda: PackedKVCodec(CacheQuantConfig(width=8), True),
    lambda: PagedKVCodec(8, None, False),
], ids=["raw", "packed", "paged"])
def test_codec_fused_decode_ctor_deprecated(ctor):
    with pytest.warns(DeprecationWarning, match="make_kv_pool"):
        codec = ctor()
    # the property survives as read-only capability metadata
    with pytest.raises(AttributeError):
        codec.fused_decode = True


def test_factory_owns_layout_and_fused_choice(model):
    """make_kv_pool resolves raw/slot-major/paged + fused from policy,
    without tripping the ctor deprecation (it is the blessed owner)."""
    cfg, _ = model
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        plain = make_kv_pool(cfg, POL, max_slots=2, max_len=16)
        fused = make_kv_pool(
            cfg, PrecisionPolicy("float32", fused_decode=True),
            max_slots=2, max_len=16)
        packed = make_kv_pool(cfg, POL, max_slots=2, max_len=16,
                              cache_bits=8, fused_decode=True)
        paged = make_kv_pool(cfg, POL, max_slots=2, max_len=16,
                             page_size=8)
    assert plain.codec is None and not plain.packed and not plain.paged
    assert isinstance(fused.codec, RawKVCodec) and fused.codec.fused_decode
    assert isinstance(packed.codec, PackedKVCodec)
    assert packed.codec.fused_decode and packed.cache_cfg.width == 8
    assert isinstance(paged.codec, PagedKVCodec) and paged.paged
    assert paged.page_size == 8 and paged.nblocks == 2
    assert paged.total_pages == 1 + 2 * 2   # null page + full residency
    # explicit fused_decode= overrides the policy default
    assert not make_kv_pool(
        cfg, PrecisionPolicy("float32", fused_decode=True),
        max_slots=2, max_len=16, fused_decode=False).codec


def test_factory_width_disagreement_raises(model):
    cfg, _ = model
    with pytest.raises(ValueError, match="disagree"):
        make_kv_pool(cfg, POL, max_slots=2, max_len=16, cache_bits=8,
                     cache_cfg=CacheQuantConfig(width=16))


# ---------------------------------------------------------------------------
# typed construction failures (no real mesh needed)
# ---------------------------------------------------------------------------

def test_active_dist_without_mesh_raises(model):
    cfg, params = model
    dist = DistCtx(ep_axis="model", all_axes=("model",))
    with pytest.raises(MeshConfigError, match="needs the mesh"):
        ServeEngine(cfg, POL, params, max_slots=1, max_len=16, dist=dist)
    with pytest.raises(MeshConfigError, match="needs the mesh"):
        make_kv_pool(cfg, POL, dist, max_slots=1, max_len=16)


def test_cp_over_paged_arena_raises(model):
    """CP + paged is incoherent (pages tile the axis CP would shard) and
    must fail typed at construction, not as a late GSPMD error."""
    cfg, _ = model
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(MeshConfigError, match="paged"):
        make_kv_pool(cfg, POL, serve_pod_ctx(cp=2), max_slots=1,
                     max_len=16, page_size=8, mesh=mesh)


def test_mesh_oversubscription_raises():
    with pytest.raises(MeshConfigError, match="device"):
        make_serve_mesh(tp=jax.device_count() * 2)


def test_pod_ctx_rejects_nonpositive_degrees():
    with pytest.raises(MeshConfigError):
        serve_pod_ctx(tp=0)
    with pytest.raises(MeshConfigError):
        serve_pod_ctx(cp=-1)


# ---------------------------------------------------------------------------
# multidevice: sharded-vs-single-device greedy bit-identity
# ---------------------------------------------------------------------------

def _run_subprocess(body: str, prelude: str = "") -> str:
    """Run ``prelude + dedent(body)`` in a fresh interpreter with 8
    forced host devices.

    The flag must be set before jax imports, which is why these tests
    cannot run in-process (the parent already initialized 1 device).
    ``body`` is dedented *before* the column-0 prelude is prepended —
    dedenting the concatenation would be a no-op and leave the body
    nested inside the prelude's last ``def``.
    """
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = "
              "'--xla_force_host_platform_device_count=8'\n"
              + prelude + textwrap.dedent(body))
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, \
        f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


_SHARDED_PRELUDE = """
import dataclasses
import numpy as np
import jax
from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.dist import serve_pod_ctx
from repro.launch.mesh import make_serve_mesh
from repro.models import transformer as T
from repro.serve import EngineOptions, ServeEngine

def wave(eng, prompts, max_new):
    uids = [eng.submit(p, max_new=max_new) for p in prompts]
    out = eng.run()
    return [np.asarray(out[u]) for u in uids]

def check(tag, cfg, policy, params, opts, prompts, max_new, max_len,
          tp=1, cp=1):
    ref = ServeEngine(cfg, policy, params, max_slots=2, max_len=max_len,
                      options=opts)
    want = wave(ref, prompts, max_new)
    eng = ServeEngine(cfg, policy, params, max_slots=2, max_len=max_len,
                      options=opts, dist=serve_pod_ctx(tp=tp, cp=cp),
                      mesh=make_serve_mesh(tp=tp, cp=cp))
    got = wave(eng, prompts, max_new)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g), tag
    print(tag, 'IDENTICAL')
"""


@pytest.mark.multidevice
def test_tp_sharded_greedy_bit_identity():
    """2- and 4-way TP == single-device, bit-for-bit, across f32/int8
    pools, fused and unfused decode (tp4 widens the smoke model to 4 kv
    heads so the head axis shards 1-per-device)."""
    out = _run_subprocess("""
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size))
    pol = PrecisionPolicy("float32")
    pol_f = PrecisionPolicy("float32", fused_decode=True)
    check('tp2_f32', cfg, pol, params, EngineOptions(),
          prompts, 8, 24, tp=2)
    check('tp2_f32_fused', cfg, pol_f, params, EngineOptions(),
          prompts, 8, 24, tp=2)
    check('tp2_int8', cfg, pol, params, EngineOptions(cache_bits=8),
          prompts, 8, 24, tp=2)
    check('tp2_int8_fused', cfg, pol_f, params,
          EngineOptions(cache_bits=8), prompts, 8, 24, tp=2)

    cfg4 = dataclasses.replace(cfg, num_kv_heads=4)
    params4 = T.init_params(cfg4, jax.random.PRNGKey(0))
    check('tp4_f32', cfg4, pol, params4, EngineOptions(),
          prompts, 8, 24, tp=4)
    check('tp4_int8_fused', cfg4, pol_f, params4,
          EngineOptions(cache_bits=8), prompts, 8, 24, tp=4)
    """, prelude=_SHARDED_PRELUDE)
    assert out.count("IDENTICAL") == 6


@pytest.mark.multidevice
def test_tp_sharded_paged_bit_identity():
    """TP over the paged arena (pages keep full windows; kv heads shard
    within each page) matches single-device paged serving exactly."""
    out = _run_subprocess("""
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size))
    pol_f = PrecisionPolicy("float32", fused_decode=True)
    check('tp2_int8_paged', cfg, pol_f, params,
          EngineOptions(cache_bits=8, page_size=8), prompts, 8, 24, tp=2)
    """, prelude=_SHARDED_PRELUDE)
    assert out.count("IDENTICAL") == 1


@pytest.mark.multidevice
def test_cp_sharded_long_context_bit_identity():
    """CP window-sharding (exact log-sum-exp merge) on long-context
    slots: token streams match single-device for f32 and a chunked-
    prefill int8 pool, at cp=2 and cp=4."""
    out = _run_subprocess("""
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (2, 40), 0, cfg.vocab_size))
    pol = PrecisionPolicy("float32")
    check('cp2_f32', cfg, pol, params, EngineOptions(),
          prompts, 8, 64, cp=2)
    check('cp2_int8_chunked', cfg, pol, params,
          EngineOptions(cache_bits=8, prefill_chunk=16),
          prompts, 8, 64, cp=2)
    check('cp4_f32', cfg, pol, params, EngineOptions(),
          prompts, 8, 64, cp=4)
    """, prelude=_SHARDED_PRELUDE)
    assert out.count("IDENTICAL") == 3


@pytest.mark.multidevice
def test_cp_window_divisibility_enforced():
    """A max_len the CP degree does not divide fails typed, at
    construction (needs a real cp=2 mesh, hence the subprocess)."""
    _run_subprocess("""
    import jax
    from repro import configs
    from repro.core.policy import PrecisionPolicy
    from repro.dist import MeshConfigError, serve_pod_ctx
    from repro.launch.mesh import make_serve_mesh
    from repro.models import transformer as T
    from repro.serve import ServeEngine

    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    try:
        ServeEngine(cfg, PrecisionPolicy("float32"), params,
                    max_slots=1, max_len=63,
                    dist=serve_pod_ctx(cp=2), mesh=make_serve_mesh(cp=2))
    except MeshConfigError as e:
        assert "divisible" in str(e), e
    else:
        raise AssertionError("indivisible max_len did not raise")
    print("OK")
    """)


@pytest.mark.multidevice
def test_engine_derives_dist_from_mesh():
    """mesh= alone is enough: the engine derives the serving context
    from the mesh's axis sizes and still matches single-device."""
    out = _run_subprocess("""
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size))
    pol = PrecisionPolicy("float32")
    ref = ServeEngine(cfg, pol, params, max_slots=2, max_len=24)
    want = wave(ref, prompts, 6)
    eng = ServeEngine(cfg, pol, params, max_slots=2, max_len=24,
                      mesh=make_serve_mesh(tp=2))
    assert eng.dist.active and "model" in eng.dist.all_axes
    got = wave(eng, prompts, 6)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    print("IDENTICAL")
    """, prelude=_SHARDED_PRELUDE)
    assert "IDENTICAL" in out
