"""Checkpoint manager: roundtrip, atomicity, integrity, retention."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointError, CheckpointManager,
                              CheckpointWriteError, LeafCorruptError,
                              LeafMismatchError, restore_tree, save_tree)
from repro.core.packed import pack, unpack


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (16, 8)),
            "nested": {"b": jax.random.normal(k2, (4,)),
                       "step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_tree(t, str(tmp_path / "ck"))
    r = restore_tree(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape,
                                                                 x.dtype), t),
                     str(tmp_path / "ck"))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_arrays_roundtrip(tmp_path):
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    t = {"w": pack(x, 12, jnp.float32(-8))}
    save_tree(t, str(tmp_path / "ck"))
    r = restore_tree(t, str(tmp_path / "ck"))
    np.testing.assert_array_equal(np.asarray(unpack(t["w"])),
                                  np.asarray(unpack(r["w"])))
    assert r["w"].mantissa.dtype == jnp.int16


def test_manager_latest_retention_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(jax.random.PRNGKey(2))
    for s in (10, 20, 30):
        mgr.save(s, jax.tree.map(lambda x: x + s, t))
    assert mgr.latest() == 30
    assert mgr.all_steps() == [20, 30]  # retention pruned step 10
    r = mgr.restore(t)
    np.testing.assert_allclose(np.asarray(r["a"]),
                               np.asarray(t["a"] + 30), rtol=1e-6)


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(jax.random.PRNGKey(3))
    mgr.save(10, t)
    # simulate a torn save: directory without _COMMITTED
    os.makedirs(tmp_path / "step_00000020")
    assert mgr.latest() == 10


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(jax.random.PRNGKey(4))
    mgr.save_async(5, t)
    mgr.wait()
    assert mgr.latest() == 5


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore({"a": jnp.zeros(3)})


# ----------------------------------------------------------- typed errors


def _template(t):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                                       jnp.result_type(x)), t)


def test_leaf_count_mismatch_names_counts(tmp_path):
    t = _tree(jax.random.PRNGKey(5))
    save_tree(t, str(tmp_path / "ck"))
    with pytest.raises(LeafMismatchError, match="3 leaves"):
        restore_tree({"a": jnp.zeros((16, 8))}, str(tmp_path / "ck"))


def test_shape_and_dtype_mismatch_name_the_leaf(tmp_path):
    t = _tree(jax.random.PRNGKey(6))
    save_tree(t, str(tmp_path / "ck"))
    bad_shape = dict(t, a=jnp.zeros((2, 2)))
    with pytest.raises(LeafMismatchError, match="'a'.*shape"):
        restore_tree(_template(bad_shape), str(tmp_path / "ck"))
    bad_dtype = {"a": t["a"], "nested": dict(t["nested"],
                                             step=jnp.float32(0))}
    with pytest.raises(LeafMismatchError, match="'nested/step'.*dtype"):
        restore_tree(_template(bad_dtype), str(tmp_path / "ck"))


def _corrupt_one_leaf(ckpt_dir):
    leaf = sorted(f for f in os.listdir(ckpt_dir)
                  if f.endswith(".npy"))[0]
    p = os.path.join(ckpt_dir, leaf)
    with open(p, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        b = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([b[0] ^ 0xFF]))
    return leaf


def test_crc_corruption_is_a_typed_error_naming_the_leaf(tmp_path):
    t = _tree(jax.random.PRNGKey(7))
    save_tree(t, str(tmp_path / "ck"))
    _corrupt_one_leaf(str(tmp_path / "ck"))
    with pytest.raises(LeafCorruptError, match="CRC32"):
        restore_tree(_template(t), str(tmp_path / "ck"))
    with open(tmp_path / "ck" / "manifest.json") as f:
        names = [leaf["name"] for leaf in json.load(f)["leaves"]]
    with pytest.raises(LeafCorruptError, match=names[0].split("/")[-1]):
        restore_tree(_template(t), str(tmp_path / "ck"))


# ------------------------------------------------------ fallback on tears


def test_crc_corrupted_newest_falls_back_to_previous(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(jax.random.PRNGKey(8))
    mgr.save(10, t)
    mgr.save(20, jax.tree.map(lambda x: x + 1, t))
    _corrupt_one_leaf(str(tmp_path / "step_00000020"))
    tree, step = mgr.restore_latest(_template(t))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.asarray(t["a"]))
    # the corrupt dir was quarantined, not retried
    assert mgr.all_steps() == [10]
    assert any(d.startswith("corrupt_") for d in os.listdir(tmp_path))


def test_stripped_committed_falls_back_to_previous(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(jax.random.PRNGKey(9))
    mgr.save(10, t)
    mgr.save(20, jax.tree.map(lambda x: x + 1, t))
    os.remove(tmp_path / "step_00000020" / "_COMMITTED")
    tree, step = mgr.restore_latest(_template(t))
    assert step == 10


def test_all_corrupt_raises_checkpoint_error(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(jax.random.PRNGKey(10))
    mgr.save(10, t)
    _corrupt_one_leaf(str(tmp_path / "step_00000010"))
    with pytest.raises(CheckpointError, match="failed verification"):
        mgr.restore_latest(_template(t))


# --------------------------------------------------- async + retry + GC


def test_async_error_surfaces_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retries=0, backoff_s=0.0)
    t = _tree(jax.random.PRNGKey(11))
    mgr.inject_failure()
    mgr.save_async(5, t)
    with pytest.raises(CheckpointWriteError, match="injected"):
        mgr.wait()
    # the error is consumed: the next save goes through clean
    mgr.save_async(6, t)
    mgr.wait()
    assert mgr.latest() == 6


def test_async_error_surfaces_on_next_save_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retries=0, backoff_s=0.0)
    t = _tree(jax.random.PRNGKey(12))
    mgr.inject_failure()
    mgr.save_async(5, t)
    import time
    time.sleep(0.2)
    with pytest.raises(CheckpointWriteError):
        mgr.save_async(6, t)


def test_save_retry_survives_transient_failure(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retries=2, backoff_s=0.0)
    t = _tree(jax.random.PRNGKey(13))
    mgr.inject_failure(count=1)         # first attempt dies, retry wins
    mgr.save(5, t)
    assert mgr.latest() == 5
    assert not os.path.exists(tmp_path / "step_00000005.tmp")


def test_retention_never_deletes_newest_committed_mid_save(tmp_path):
    """keep=1 with the successor's save dying mid-write: the newest
    committed dir must survive as the restore anchor."""
    mgr = CheckpointManager(str(tmp_path), keep=1, retries=0, backoff_s=0.0)
    t = _tree(jax.random.PRNGKey(14))
    mgr.save(10, t)
    mgr.inject_failure()
    with pytest.raises(CheckpointWriteError):
        mgr.save(20, t)
    assert mgr.latest() == 10           # anchor intact
    tree, step = mgr.restore_latest(_template(t))
    assert step == 10


def test_weird_dir_names_do_not_crash_all_steps(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(jax.random.PRNGKey(15))
    mgr.save(10, t)
    # a torn dir with a non-integer suffix (e.g. interrupted tmp rename)
    os.makedirs(tmp_path / "step_00000020.tmp")
    open(tmp_path / "step_00000020.tmp" / "_COMMITTED", "w").close()
    os.makedirs(tmp_path / "step_junk")
    assert mgr.all_steps() == [10]
