"""TrainState: parameters (+optional packed storage), optimizer, DFXP scales.

Parameter-storage quantization groups (paper §6's "Up." bit-width) are
derived from the parameter pytree itself:
  * ``p:<path>``  — parameter storage scale (update width),
  * ``pg:<path>`` — weight-gradient scale (computation width),
  * ``pm:<path>`` — momentum/optimizer-state scale (update width).
Stacked per-layer leaves (under a stage's ``stacked`` subtree) get one scale
*per layer* (leading axis), mirroring the paper's per-layer groups.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.packed import PackedArray, pack
from repro.core.policy import PrecisionPolicy
from repro.core.scale import ScaleState

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any                    # f32 pytree (sim) | PackedArray pytree
    opt: Any                       # optimizer state (matching storage)
    scale: ScaleState
    step: Array                    # int32 scalar

    def num_params(self) -> int:
        return sum(
            (x.size for x in jax.tree.leaves(
                self.params, is_leaf=lambda n: isinstance(n, PackedArray))))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_leaf_groups(params) -> Dict[str, tuple]:
    """Map each param leaf path -> scale-group shape (per-layer if stacked)."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = _path_str(path)
        stacked = "stacked" in name
        out[name] = (leaf.shape[0],) if (stacked and leaf.ndim > 0) else ()
    return out


def param_group_shapes(params) -> Dict[str, tuple]:
    shapes = {}
    for name, shape in param_leaf_groups(params).items():
        shapes[f"p:{name}"] = shape
        shapes[f"pg:{name}"] = shape
        shapes[f"pm:{name}"] = shape
    return shapes


def init_train_state(params, opt_state, model_groups: Dict[str, tuple],
                     policy: PrecisionPolicy,
                     init_exp: float | Dict[str, float] = -8.0) -> TrainState:
    groups = dict(model_groups)
    groups.update(param_group_shapes(params))
    scale = ScaleState.create(groups, init_exp)
    if policy.storage == "packed":
        params = pack_tree(params, scale, "p:", policy.update_width)
        opt_state = pack_tree(opt_state, scale, "pm:", policy.update_width,
                              strip_prefix=1)
    elif policy.arithmetic in ("fixed", "dfxp"):
        # paper: parameters live at the update width from step 0 (packed
        # mode gets this from pack(); sim mode quantizes in place)
        def q(path, leaf):
            e = scale.exps[f"p:{_path_str(path)}"]
            from repro.train.step import quantize_param
            return quantize_param(leaf, policy.update_width, e)[0]
        params = jax.tree_util.tree_map_with_path(q, params)
    return TrainState(params=params, opt=opt_state, scale=scale,
                      step=jnp.int32(0))


def pack_tree(tree, scale: ScaleState, prefix: str, width: int,
              strip_prefix: int = 0):
    """Pack every leaf into a PackedArray using its group's exponent."""
    def pack_leaf(path, leaf):
        name = _path_str(path[strip_prefix:] if strip_prefix else path)
        e = scale.exps[f"{prefix}{name}"]
        return pack(leaf, width, _bexp(e, leaf))
    return jax.tree_util.tree_map_with_path(pack_leaf, tree)


def unpack_tree(tree, dtype=jnp.float32):
    from repro.core.packed import unpack
    return jax.tree.map(
        lambda x: unpack(x, dtype) if isinstance(x, PackedArray) else x,
        tree, is_leaf=lambda x: isinstance(x, PackedArray))


def _bexp(e: Array, x) -> Array:
    """Broadcast a per-layer exponent [L] against a stacked leaf [L, ...]."""
    e = jnp.asarray(e, jnp.float32)
    if e.ndim == 0:
        return e
    return e.reshape(e.shape + (1,) * (x.ndim - e.ndim))
