"""DistCtx — the mesh-axis contract threaded through models and launchers.

A ``DistCtx`` names which mesh axes play which logical role; model code
never mentions concrete axis names. An inactive context (``all_axes=()``,
the default) means single-host execution: every ``dist``-aware code path
must collapse to plain local math, which is what the equivalence tests
(EP MoE == local MoE, CP attention == monolithic attention) pin down.

Roles:
  * ``token_axes``  — axes the flattened token batch is sharded over
    (data parallel; ``("pod", "data")`` across pods);
  * ``ep_axis``     — expert-parallel axis: MoE expert banks are sharded
    over it and dispatch/combine are ``all_to_all``s along it;
  * ``fsdp_axis``   — parameter-sharding axis: expert weights live sliced
    over it and are all-gathered per layer (training) or kept stationary
    with activations moving instead (``moe_stationary`` decode);
  * ``cp_axis``     — context parallelism: with ``cp_decode`` set (the
    long-context serving cells, where ``ShardingRules(seq_shard_cache=
    True)`` shards the KV window over ``cp_axis``), decode attention runs
    :func:`repro.dist.cp_attention.cp_decode_attention` over the shards;
  * ``attn_seq_shard`` — shard training attention over the sequence instead
    of heads (for archs whose head counts don't divide the TP degree).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


class MeshConfigError(ValueError):
    """An incoherent mesh/parallelism request, rejected at construction.

    Raised by the serve-side factories (``serve_pod_ctx``,
    ``launch.mesh.make_serve_mesh``, ``serve.kv_pool.make_kv_pool``,
    ``ServeEngine``) for combinations that would otherwise surface as a
    late, cryptic jit/GSPMD failure: a mesh larger than the visible
    device count, CP over a paged arena, a KV window the CP degree does
    not divide, a ``DistCtx`` naming axes the mesh doesn't have.
    """


@dataclasses.dataclass(frozen=True)
class DistCtx:
    token_axes: Tuple[str, ...] = ()
    ep_axis: Optional[str] = None
    fsdp_axis: Optional[str] = None
    cp_axis: Optional[str] = None
    all_axes: Tuple[str, ...] = ()
    moe_stationary: bool = False
    attn_seq_shard: bool = False
    cp_decode: bool = False        # decode KV window is sharded over cp_axis

    @property
    def active(self) -> bool:
        """Whether a mesh is in play at all (single-host ⇔ False)."""
        return bool(self.all_axes)

    @property
    def cp_axes(self) -> Tuple[str, ...]:
        return (self.cp_axis,) if self.cp_axis else ()


def single_pod_ctx() -> DistCtx:
    """16×16 single-pod mesh: ``data`` × ``model`` (see launch/mesh.py)."""
    return DistCtx(token_axes=("data",), ep_axis="model", fsdp_axis="data",
                   cp_axis="data", all_axes=("data", "model"))


def serve_pod_ctx(*, tp: int = 1, cp: int = 1) -> DistCtx:
    """Serving context for a ``make_serve_mesh(tp, cp)`` mesh.

    Serving tensor-parallelism shards the **KV pool** over its kv-head
    axis (``model``) — the HBM-bound tensor at production batch sizes —
    while parameters stay replicated, so every contraction that could
    reorder partial sums runs identically on every device and the
    sharded engine's greedy streams stay bit-identical to single-device.
    ``cp > 1`` shards the decode KV *window* over ``data`` instead
    (long-context slots) and sets ``cp_decode`` so attention runs the
    exact log-sum-exp merge of :mod:`repro.dist.cp_attention`.
    """
    if tp < 1 or cp < 1:
        raise MeshConfigError(f"tp={tp} and cp={cp} must be >= 1")
    axes = tuple(a for a, n in (("data", cp), ("model", tp)) if n > 1)
    return DistCtx(ep_axis="model" if tp > 1 else None,
                   cp_axis="data" if cp > 1 else None,
                   all_axes=axes, cp_decode=cp > 1)


def multi_pod_ctx() -> DistCtx:
    """2×16×16 two-pod mesh: pure-DP ``pod`` axis in front of the pod mesh.

    FSDP stays *within* a pod (``data``) so weight all-gathers never cross
    the slow inter-pod links; only gradient all-reduce does — which is
    exactly the wire :func:`repro.dist.compress.compress_decompress`
    narrows to low-bit lanes.
    """
    return DistCtx(token_axes=("pod", "data"), ep_axis="model",
                   fsdp_axis="data", cp_axis="data",
                   all_axes=("pod", "data", "model"))
