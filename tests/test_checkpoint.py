"""Checkpoint manager: roundtrip, atomicity, retention, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.core.packed import pack, unpack


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (16, 8)),
            "nested": {"b": jax.random.normal(k2, (4,)),
                       "step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_tree(t, str(tmp_path / "ck"))
    r = restore_tree(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape,
                                                                 x.dtype), t),
                     str(tmp_path / "ck"))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_arrays_roundtrip(tmp_path):
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    t = {"w": pack(x, 12, jnp.float32(-8))}
    save_tree(t, str(tmp_path / "ck"))
    r = restore_tree(t, str(tmp_path / "ck"))
    np.testing.assert_array_equal(np.asarray(unpack(t["w"])),
                                  np.asarray(unpack(r["w"])))
    assert r["w"].mantissa.dtype == jnp.int16


def test_manager_latest_retention_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(jax.random.PRNGKey(2))
    for s in (10, 20, 30):
        mgr.save(s, jax.tree.map(lambda x: x + s, t))
    assert mgr.latest() == 30
    assert mgr.all_steps() == [20, 30]  # retention pruned step 10
    r = mgr.restore(t)
    np.testing.assert_allclose(np.asarray(r["a"]),
                               np.asarray(t["a"] + 30), rtol=1e-6)


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(jax.random.PRNGKey(3))
    mgr.save(10, t)
    # simulate a torn save: directory without _COMMITTED
    os.makedirs(tmp_path / "step_00000020")
    assert mgr.latest() == 10


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(jax.random.PRNGKey(4))
    mgr.save_async(5, t)
    mgr.wait()
    assert mgr.latest() == 5


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore({"a": jnp.zeros(3)})
