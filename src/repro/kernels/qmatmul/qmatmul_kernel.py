"""Pallas TPU kernel: DFXP quantized matmul with fused operand quantization.

Computes ``C = clipround(A) @ clipround(B)`` with f32 accumulation — the
paper's multiplication contract (§6-§7: narrow multiplier operands, wide
accumulators == the TPU MXU's native mode). Fusing the operand rounding
into the matmul's tile loads removes two full HBM round-trips per matmul
versus quantize-then-matmul.

TPU adaptation:
  * 128-aligned (bm, bn, bk) tiles feed the MXU directly;
  * accumulation lives in a VMEM scratch tile across the k-grid dimension
    (k is the innermost/sequential grid axis);
  * operand scales are bit-exact powers of two in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; interpret mode works without them
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _q(x, inv_step, step, qmax, qmin):
    m = jnp.round(x.astype(jnp.float32) * inv_step)
    return jnp.clip(m, qmin, qmax) * step


def _kernel(scales_ref, a_ref, b_ref, c_ref, acc_ref, *, qmax_a, qmin_a,
            qmax_b, qmin_b, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    step_a, inv_a, step_b, inv_b = (scales_ref[0, 0], scales_ref[0, 1],
                                    scales_ref[0, 2], scales_ref[0, 3])
    aq = _q(a_ref[...], inv_a, step_a, qmax_a, qmin_a)
    bq = _q(b_ref[...], inv_b, step_b, qmax_b, qmin_b)
    acc_ref[...] += jnp.dot(aq, bq, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


@functools.partial(jax.jit, static_argnames=("width", "block_m", "block_n",
                                             "block_k", "interpret"))
def qmatmul_2d(a, b, e_a, e_b, *, width: int, block_m: int = 128,
               block_n: int = 128, block_k: int = 128,
               interpret: bool = False):
    """``a``: [M, K], ``b``: [K, N], dims multiples of the block sizes."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))
    from repro.core.quant import exact_pow2
    e_a = jnp.asarray(e_a, jnp.float32)
    e_b = jnp.asarray(e_b, jnp.float32)
    scales = jnp.stack([exact_pow2(e_a), exact_pow2(-e_a),
                        exact_pow2(e_b), exact_pow2(-e_b)]).reshape(1, 4)
    nk = K // block_k

    scratch = [_VMEM((block_m, block_n), jnp.float32)]

    return pl.pallas_call(
        functools.partial(_kernel, qmax_a=qmax, qmin_a=qmin, qmax_b=qmax,
                          qmin_b=qmin, nk=nk),
        grid=(M // block_m, N // block_n, nk),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, j, k: (0, 0)),
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(scales, a, b)
