"""Paper artifacts: Table 3 + Figures 1-4, on the scaled synthetic task.

Each function prints CSV rows ``name,us_per_call,derived`` where derived is
the normalized final loss (÷ fp32 baseline) — the paper's presentation.
"""
from __future__ import annotations

from repro.core import PrecisionPolicy

from ._common import fp32_baseline, train_once


def table3_formats():
    """Table 3: error by format (fp32 / fp16 / fixed 20 / dfxp 10-12)."""
    base_loss, base_acc, base_sps = fp32_baseline()
    rows = [("table3/float32_32_32", PrecisionPolicy("float32"))]
    rows += [("table3/half_float_16_16", PrecisionPolicy("float16"))]
    rows += [("table3/fixed_20_20",
              PrecisionPolicy("fixed", comp_width=20, update_width=20))]
    rows += [("table3/dfxp_10_12",
              PrecisionPolicy("dfxp", comp_width=10, update_width=12,
                              update_interval=10))]
    out = []
    for name, pol in rows:
        loss, acc, sps = train_once(pol)
        out.append((name, sps * 1e6, loss / base_loss))
    return out


def fig1_radix():
    """Fig 1: static fixed point, radix position sweep at width 32."""
    base_loss, _, _ = fp32_baseline()
    out = []
    for int_bits in (1, 3, 5, 7, 9, 12):
        pol = PrecisionPolicy("fixed", comp_width=32, update_width=32,
                              fixed_int_bits=int_bits)
        loss, acc, sps = train_once(pol)
        out.append((f"fig1/radix_{int_bits}", sps * 1e6, loss / base_loss))
    return out


def fig2_comp_width():
    """Fig 2: computation bit-width sweep (dfxp + fixed), update width 31."""
    base_loss, _, _ = fp32_baseline()
    out = []
    for w in (14, 12, 10, 8, 6):
        pol = PrecisionPolicy("dfxp", comp_width=w, update_width=31,
                              update_interval=10)
        loss, _, sps = train_once(pol)
        out.append((f"fig2/dfxp_comp_{w}", sps * 1e6, loss / base_loss))
    for w in (24, 20, 16):
        pol = PrecisionPolicy("fixed", comp_width=w, update_width=31)
        loss, _, sps = train_once(pol)
        out.append((f"fig2/fixed_comp_{w}", sps * 1e6, loss / base_loss))
    return out


def fig3_update_width():
    """Fig 3: parameter-update bit-width sweep, computation width 31."""
    base_loss, _, _ = fp32_baseline()
    out = []
    for w in (16, 12, 10, 8):
        pol = PrecisionPolicy("dfxp", comp_width=31, update_width=w,
                              update_interval=10)
        loss, _, sps = train_once(pol)
        out.append((f"fig3/dfxp_update_{w}", sps * 1e6, loss / base_loss))
    for w in (20, 16):
        pol = PrecisionPolicy("fixed", comp_width=31, update_width=w)
        loss, _, sps = train_once(pol)
        out.append((f"fig3/fixed_update_{w}", sps * 1e6, loss / base_loss))
    return out


def fig4_overflow_rate():
    """Fig 4: max-overflow-rate × computation width."""
    base_loss, _, _ = fp32_baseline()
    out = []
    for rate in (1e-2, 1e-3, 1e-4):
        for w in (8, 10):
            pol = PrecisionPolicy("dfxp", comp_width=w, update_width=31,
                                  update_interval=10,
                                  max_overflow_rate=rate)
            loss, _, sps = train_once(pol)
            out.append((f"fig4/rate_{rate:g}_comp_{w}", sps * 1e6,
                        loss / base_loss))
    return out
