"""Pallas TPU kernel: fused DFXP quantize + clip + overflow counting.

The quantization site is the hottest elementwise op in DFXP training — it
runs on every activation, backprop signal, and parameter-use. Unfused, the
paper's recipe costs 4 HBM passes per site (round, two overflow compares,
clip); this kernel does one read + one write per tile and keeps the
overflow statistics as per-tile partial sums in VMEM.

TPU adaptation notes:
  * tiles are (block_m × block_n) in VMEM, block_n a multiple of 128
    (lane width) and block_m a multiple of 8 (f32 sublanes);
  * ``step``/``inv_step`` are precomputed bit-exact powers of two and land
    in SMEM as (1,1) scalars — ``exp2`` inside the kernel would re-derive
    them through a polynomial approximation (observed inexact on CPU XLA,
    see core.quant.exact_pow2);
  * per-tile statistics go to a (grid_m, grid_n, 2) output summed by the
    caller — cheaper than cross-tile atomics, exact because counts are
    integers ≪ 2^24.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(step_ref, inv_ref, x_ref, y_ref, stats_ref, *, qmax: float,
            qmin: float):
    step = step_ref[0, 0]
    inv_step = inv_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)
    m = jnp.round(x * inv_step)               # round-half-to-even
    over = (m > qmax) | (m < qmin)
    over_half = (m > qmax / 2) | (m < qmin / 2)
    y_ref[...] = (jnp.clip(m, qmin, qmax) * step).astype(y_ref.dtype)
    stats_ref[0, 0, 0] = jnp.sum(over.astype(jnp.float32))
    stats_ref[0, 0, 1] = jnp.sum(over_half.astype(jnp.float32))


@functools.partial(jax.jit,
                   static_argnames=("width", "block_m", "block_n",
                                    "interpret"))
def dfxp_quantize_2d(x, step, inv_step, *, width: int, block_m: int = 256,
                     block_n: int = 512, interpret: bool = False):
    """``x``: [M, N] (M % block_m == 0, N % block_n == 0).

    Returns (y, stats[2]) with stats = (n_overflow, n_overflow_half).
    """
    M, N = x.shape
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))
    gm, gn = M // block_m, N // block_n
    step2 = jnp.asarray(step, jnp.float32).reshape(1, 1)
    inv2 = jnp.asarray(inv_step, jnp.float32).reshape(1, 1)

    y, stats = pl.pallas_call(
        functools.partial(_kernel, qmax=qmax, qmin=qmin),
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1, 2), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), x.dtype),
            jax.ShapeDtypeStruct((gm, gn, 2), jnp.float32),
        ],
        interpret=interpret,
    )(step2, inv2, x)
    return y, stats.sum(axis=(0, 1))
