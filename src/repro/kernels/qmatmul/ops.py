"""jit'd wrapper for the quantized matmul kernel: padding + block choice."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .qmatmul_kernel import qmatmul_2d


def _round_up(x, m):
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def qmatmul(a, b, e_a, e_b, *, width: int = 10, interpret: bool = True):
    """DFXP matmul ``q(a) @ q(b)`` with f32 accumulation. Any [M,K]x[K,N]."""
    M, K = a.shape
    _, N = b.shape
    bm = min(128, _round_up(M, 8))
    bn = min(128, _round_up(N, 128))
    bk = min(128, _round_up(K, 128))
    Mp, Kp, Np = _round_up(M, bm), _round_up(K, bk), _round_up(N, bn)
    ap = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    bp = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    c = qmatmul_2d(ap, bp, e_a, e_b, width=width, block_m=bm, block_n=bn,
                   block_k=bk, interpret=interpret)
    return c[:M, :N]
