"""Optimizers, faithful to the paper's recipe (§8.1) + AdamW for LM configs.

Paper recipe: minibatch SGD with a *linearly decaying learning rate*, a
*linearly saturating momentum*, dropout, and a max-norm constraint on each
weight column (Srebro & Shraibman 2005). All pure functions over pytrees —
no optax dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "sgd"              # sgd|adamw
    lr: float = 0.05
    # paper schedules
    lr_decay_steps: int = 10_000   # linear decay horizon
    lr_min_factor: float = 0.01
    momentum_init: float = 0.5
    momentum_final: float = 0.7
    momentum_sat_steps: int = 2_000
    # adamw
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    # constraints
    max_col_norm: float = 0.0      # 0 = off (paper maxout: 1.9365)
    grad_clip: float = 0.0         # global-norm clip, 0 = off


def lr_at(cfg: OptConfig, step: Array) -> Array:
    frac = 1.0 - step.astype(jnp.float32) / cfg.lr_decay_steps
    return cfg.lr * jnp.clip(frac, cfg.lr_min_factor, 1.0)


def momentum_at(cfg: OptConfig, step: Array) -> Array:
    t = jnp.clip(step.astype(jnp.float32) / cfg.momentum_sat_steps, 0.0, 1.0)
    return cfg.momentum_init + (cfg.momentum_final - cfg.momentum_init) * t


SGDState = Dict[str, Any]     # {"momentum": pytree}
AdamWState = Dict[str, Any]   # {"m": pytree, "v": pytree}


def sgd_init(params) -> SGDState:
    return {"momentum": jax.tree.map(jnp.zeros_like, params)}


def sgd_update(cfg: OptConfig, grads, state: SGDState, step: Array):
    """Returns (updates, new_state). updates are *deltas* to add to params."""
    lr = lr_at(cfg, step)
    mom = momentum_at(cfg, step)
    new_m = jax.tree.map(lambda m, g: mom * m + g, state["momentum"], grads)
    updates = jax.tree.map(lambda m: -lr * m, new_m)
    return updates, {"momentum": new_m}


def adamw_init(params) -> AdamWState:
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params)}


def adamw_update(cfg: OptConfig, grads, state: AdamWState, step: Array,
                 params=None):
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    b1, b2 = cfg.beta1, cfg.beta2
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    c1, c2 = 1 - b1 ** t, 1 - b2 ** t
    def upd(mi, vi, pi):
        u = -(lr * (mi / c1) / (jnp.sqrt(vi / c2) + cfg.eps))
        if cfg.weight_decay and pi is not None:
            u = u - lr * cfg.weight_decay * pi
        return u
    if params is None:
        updates = jax.tree.map(lambda mi, vi: upd(mi, vi, None), m, v)
    else:
        updates = jax.tree.map(upd, m, v, params)
    return updates, {"m": m, "v": v}


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), n


def apply_max_norm(params, max_col_norm: float):
    """Paper's max-norm constraint: clip each weight column's L2 norm.

    Applied to every rank-2+ leaf whose last-1 axis indexes output columns
    (the convention of all our dense/maxout weights).
    """
    if not max_col_norm:
        return params

    def clip(x):
        if x.ndim < 2:
            return x
        axes = tuple(range(x.ndim - 1))
        norms = jnp.sqrt(jnp.sum(jnp.square(x), axis=axes, keepdims=True))
        scale = jnp.minimum(1.0, max_col_norm / jnp.maximum(norms, 1e-9))
        return x * scale

    return jax.tree.map(clip, params)
