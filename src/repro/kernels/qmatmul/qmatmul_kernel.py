"""Pallas TPU kernels: DFXP quantized matmul family with fused operand rounding.

One kernel body, three contraction layouts — together they cover the whole
training graph of a quantized weighted sum (paper §6-§7: narrow multiplier
operands, wide f32 accumulators == the TPU MXU's native mode):

  * ``nn`` — ``C[M,N] = q(A)[M,K] @ q(B)[K,N]``            (forward)
  * ``nt`` — ``C[M,K] = q(G)[M,N] @ q(B)[K,N]^T``          (dgrad)
  * ``tn`` — ``C[K,N] = q(A)[M,K]^T @ q(G)[M,N]``          (wgrad)

Quantization is *per operand* and optional (``width=None`` loads the tile
as-is): the forward fuses weight rounding into the B loads, the backward
kernels fuse the cotangent's DFXP rounding into the G loads — matching the
``qbound`` numerics — so each pass is one HBM round-trip instead of a
quantize→matmul chain.

TPU adaptation:
  * 128-aligned lane/contraction tiles feed the MXU directly; the
    accumulator lives in a VMEM scratch tile across the reduction grid
    axis (innermost/sequential);
  * operand scales are bit-exact powers of two in a (1, 4) SMEM-resident
    operand: ``[step_a, 1/step_a, step_b, 1/step_b]``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; interpret mode works without them
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

# (lhs contracting dims, rhs contracting dims) per layout.
_CONTRACT = {"nn": ((1,), (0,)), "nt": ((1,), (1,)), "tn": ((0,), (0,))}


def _load(ref, scales_ref, slot: int, width, cast):
    """Tile load with optional fused DFXP rounding (``width=None`` → raw)."""
    x = ref[...]
    if width is None:
        return x
    step = scales_ref[0, 2 * slot]
    inv_step = scales_ref[0, 2 * slot + 1]
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))
    m = jnp.round(x.astype(jnp.float32) * inv_step)
    return (jnp.clip(m, qmin, qmax) * step).astype(cast)


def _kernel(scales_ref, a_ref, b_ref, c_ref, acc_ref, *, kind: str,
            width_a, width_b, cast, nred: int):
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    aq = _load(a_ref, scales_ref, 0, width_a, cast)
    bq = _load(b_ref, scales_ref, 1, width_b, cast)
    acc_ref[...] += jax.lax.dot_general(
        aq, bq, (_CONTRACT[kind], ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(r == nred - 1)
    def _done():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "kind", "width_a", "width_b", "block_r", "block_c", "block_d",
    "cast", "out_dtype", "interpret"))
def qmm_2d(a, b, scales, *, kind: str, width_a, width_b, block_r: int,
           block_c: int, block_d: int, cast=jnp.float32, out_dtype=None,
           interpret: bool = False):
    """Blocked quantized matmul on pre-padded 2D operands.

    Output is (R, C) with reduction length D; per layout the operand
    shapes are ``nn``: a[R,D], b[D,C] · ``nt``: a[R,D'], b[C,D'] (D=D') ·
    ``tn``: a[D,R], b[D,C].  All dims must be multiples of their block.
    ``scales`` is the (1, 4) array [step_a, 1/step_a, step_b, 1/step_b].
    """
    if kind == "nn":
        R, D = a.shape
        _, C = b.shape
        a_spec = pl.BlockSpec((block_r, block_d), lambda i, j, r: (i, r))
        b_spec = pl.BlockSpec((block_d, block_c), lambda i, j, r: (r, j))
    elif kind == "nt":
        R, D = a.shape
        C, _ = b.shape
        a_spec = pl.BlockSpec((block_r, block_d), lambda i, j, r: (i, r))
        b_spec = pl.BlockSpec((block_c, block_d), lambda i, j, r: (j, r))
    elif kind == "tn":
        D, R = a.shape
        _, C = b.shape
        a_spec = pl.BlockSpec((block_d, block_r), lambda i, j, r: (r, i))
        b_spec = pl.BlockSpec((block_d, block_c), lambda i, j, r: (r, j))
    else:
        raise ValueError(f"unknown layout {kind!r}")

    nred = D // block_d
    out_dtype = a.dtype if out_dtype is None else out_dtype

    return pl.pallas_call(
        functools.partial(_kernel, kind=kind, width_a=width_a,
                          width_b=width_b, cast=cast, nred=nred),
        grid=(R // block_r, C // block_c, nred),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, j, r: (0, 0)),
            a_spec,
            b_spec,
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), out_dtype),
        scratch_shapes=[_VMEM((block_r, block_c), jnp.float32)],
        interpret=interpret,
    )(scales, a, b)
