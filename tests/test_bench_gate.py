"""benchmarks.check_regression: the CI bench-gate comparison logic."""
import json

import pytest

from benchmarks.check_regression import compare, main, merge_min


def _payload(rows, tiny=True):
    return {"meta": {"backend": "cpu", "tiny": tiny},
            "rows": [{"name": n, "us_per_call": us, "derived": 1.0}
                     for n, us in rows]}


BASE = _payload([("a_jnp", 100.0), ("a_fused", 120.0),
                 ("b_jnp", 50.0), ("b_fused", 60.0), ("c", 400.0)])


def test_identical_runs_pass():
    assert compare(BASE, BASE) == []


def test_uniform_machine_slowdown_passes():
    """A 3x slower CI machine shifts every row; the median normalization
    must cancel it completely."""
    fresh = _payload([(r["name"], r["us_per_call"] * 3.0)
                      for r in BASE["rows"]])
    assert compare(BASE, fresh) == []


def test_single_row_regression_fails():
    rows = [(r["name"], r["us_per_call"]) for r in BASE["rows"]]
    rows[1] = ("a_fused", 120.0 * 1.6)          # one row 60% slower
    problems = compare(BASE, _payload(rows))
    assert len(problems) == 1 and "a_fused" in problems[0]
    # and it sits inside the tolerance band when the band is widened
    assert compare(BASE, _payload(rows), tolerance=0.8) == []


def test_missing_row_fails_even_when_fast():
    fresh = _payload([(r["name"], r["us_per_call"])
                      for r in BASE["rows"][:-1]])
    problems = compare(BASE, fresh)
    assert problems == ["missing row: c"]


def test_extra_fresh_rows_are_fine():
    fresh = _payload([(r["name"], r["us_per_call"])
                      for r in BASE["rows"]] + [("new_pair", 10.0)])
    assert compare(BASE, fresh) == []


def test_shape_mismatch_refuses_to_compare():
    fresh = _payload([(r["name"], r["us_per_call"])
                      for r in BASE["rows"]], tiny=False)
    problems = compare(BASE, fresh)
    assert any("shape mismatch" in p for p in problems)


def test_empty_baseline_fails():
    assert compare(_payload([]), BASE) == ["committed baseline has no rows"]


def test_merge_min_takes_per_row_floor(tmp_path):
    """A one-run throttle spike on a single row disappears in the merge
    (the retry path's defense); a real regression present in both runs
    survives."""
    spiky = _payload([("a_jnp", 100.0), ("a_fused", 120.0 * 3.0),
                      ("b_jnp", 50.0), ("b_fused", 60.0),
                      ("c", 400.0 * 2.0)])
    real = _payload([("a_jnp", 100.0), ("a_fused", 120.0),
                     ("b_jnp", 50.0), ("b_fused", 60.0),
                     ("c", 400.0 * 2.0)])       # c slow in BOTH runs
    p1, p2 = tmp_path / "r1.json", tmp_path / "r2.json"
    p1.write_text(json.dumps(spiky))
    p2.write_text(json.dumps(real))
    merged = merge_min([str(p1), str(p2)])
    assert compare(BASE, merged) != []          # c's regression survives
    vals = {r["name"]: r["us_per_call"] for r in merged["rows"]}
    assert vals["a_fused"] == 120.0             # spike cancelled
    assert vals["c"] == 800.0


@pytest.mark.parametrize("regress", [False, True])
def test_cli_exit_codes(tmp_path, regress):
    cpath, fpath = tmp_path / "c.json", tmp_path / "f.json"
    rows = [(r["name"], r["us_per_call"] * (2.0 if regress and
                                            r["name"] == "c" else 1.0))
            for r in BASE["rows"]]
    cpath.write_text(json.dumps(BASE))
    fpath.write_text(json.dumps(_payload(rows)))
    rc = main(["--committed", str(cpath), "--fresh", str(fpath)])
    assert rc == (1 if regress else 0)
