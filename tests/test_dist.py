"""Distribution tests that need >1 device run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
must keep seeing 1 device — required by the dry-run contract)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.compress import compress_decompress, compress_tree


# ---------------------------------------------------------------------------
# single-device numerics of the gradient compressor
# ---------------------------------------------------------------------------

def test_compress_error_feedback_converges():
    """With error feedback, repeated compression of a constant gradient
    accumulates to the true value (unbiasedness over time)."""
    g = jax.random.normal(jax.random.PRNGKey(0), (512,)) * 1e-3
    r = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        gh, r = compress_decompress(g, r, bits=8)
        acc = acc + gh
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                               atol=float(jnp.abs(g).max()) * 0.02)


def test_compress_tree_shapes():
    g = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,)) * 1e-5}
    r = jax.tree.map(jnp.zeros_like, g)
    gh, rn = compress_tree(g, r, bits=16)
    assert gh["w"].shape == (8, 4) and rn["b"].shape == (4,)
    # 16-bit grid resolves 1.0 and 1e-5 within their leaf scales
    np.testing.assert_allclose(np.asarray(gh["w"]), 1.0, rtol=1e-3)


def _run_subprocess(body: str):
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = "
              "'--xla_force_host_platform_device_count=8'\n"
              + textwrap.dedent(body))
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


@pytest.mark.multidevice
def test_compressed_psum_multidevice():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, AxisType
        from repro.dist.compress import compress_decompress
        mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 256)) * 1e-3
        def f(g, r):
            return compress_decompress(g, r, bits=16, axis_name="data")
        with jax.set_mesh(mesh):
            gh, rn = jax.jit(jax.shard_map(
                f, in_specs=(P("data", None), P("data", None)),
                out_specs=(P("data", None), P("data", None)),
                check_vma=False))(g, jnp.zeros((8, 256)))
        # compressed mean-reduce ≈ true mean across the 8 replicas
        true = jnp.broadcast_to(g.mean(0), (8, 256))
        err = float(jnp.abs(gh - true).max() / jnp.abs(true).max())
        assert err < 1e-3, err
        print("OK", err)
    """)
    assert "OK" in out


@pytest.mark.multidevice
def test_cp_attention_exact_multidevice():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, math
        from jax.sharding import AxisType
        from repro.dist.cp_attention import cp_decode_attention
        mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
        B, W, H, K, hd = 2, 64, 4, 2, 16
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(kq, (B, 1, H, hd))
        ck = jax.random.normal(kk, (B, W, K, hd))
        cv = jax.random.normal(kv, (B, W, K, hd))
        pos = jnp.broadcast_to(jnp.arange(W), (B, W)).astype(jnp.int32)
        pos = pos.at[:, -3:].set(-1)       # some empty slots
        q_pos = jnp.full((B, 1), 40, jnp.int32)

        with jax.set_mesh(mesh):
            out = jax.jit(lambda *a: cp_decode_attention(
                *a, num_heads=H, num_kv_heads=K, head_dim=hd,
                cp_axes=("data",)))(q, ck, cv, pos, q_pos)

        # monolithic reference
        G = H // K
        qg = q.reshape(B, 1, K, G, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck) / math.sqrt(hd)
        valid = (pos >= 0) & (q_pos - pos >= 0)        # [B, W]
        s = jnp.where(valid[:, None, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, -1)
        ref = jnp.einsum("bkgqs,bskh->bqkgh", p, cv).reshape(B, 1, H*hd)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


@pytest.mark.multidevice
def test_moe_ep_multidevice_matches_local():
    """Expert-parallel shard_map MoE == the no-mesh local path."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, AxisType
        from repro.models.moe import MoESpec, init_moe, moe_ffn
        from repro.core.tape import QTape
        from repro.core.policy import PrecisionPolicy
        from repro.dist.context import DistCtx

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        spec = MoESpec(d_model=32, d_ff=16, num_experts=8, top_k=2,
                       capacity_factor=8.0)  # dropless for exactness
        params = init_moe(jax.random.PRNGKey(0), spec)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
        pol = PrecisionPolicy("float32")

        tape = QTape(pol, {}, {})
        y_local = moe_ffn(params, spec, x, tape, "moe", DistCtx())

        dist = DistCtx(token_axes=("data",), ep_axis="model",
                       fsdp_axis=None, all_axes=("data", "model"))
        with jax.set_mesh(mesh):
            tape2 = QTape(pol, {}, {})
            y_ep = jax.jit(lambda p, xx: moe_ffn(p, spec, xx,
                                                 QTape(pol, {}, {}),
                                                 "moe", dist))(params, x)
        err = float(jnp.abs(y_local - y_ep).max() /
                    (jnp.abs(y_local).max() + 1e-9))
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out
