"""repro.obs: tracer + validator, metrics registry, numerics timeline,
dispatch profiling, and the zero-cost-when-disabled contract."""
import json
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.kernels import dispatch
from repro.models import transformer as T
from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NumericsLog,
    Tracer,
    count_moves,
    read_jsonl,
    serve_records,
    start_http_server,
    train_records,
    validate_trace,
)
from repro.serve import CacheQuantConfig, EngineOptions, ServeEngine
from repro.serve.metrics import ServeMetrics


# ---------------------------------------------------------------------------
# tracer + Chrome-trace validator
# ---------------------------------------------------------------------------

def _fake_clock():
    t = [0.0]

    def clock():
        return t[0]
    return t, clock


def test_span_nesting_and_export():
    t, clock = _fake_clock()
    tr = Tracer(clock=clock)
    tr.begin("outer", n=1)
    t[0] = 1e-3
    tr.begin("inner")
    t[0] = 2e-3
    tr.end()                      # inner: [1000, 2000) us
    t[0] = 4e-3
    tr.end(extra=7)               # outer: [0, 4000) us
    tr.instant("mark", tid="requests", uid=3)
    tr.counter("queue", {"depth": 2, "active": 1.0})

    obj = tr.to_chrome()
    validate_trace(obj)
    xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert [e["name"] for e in xs] == ["outer", "inner"]   # parent first
    outer, inner = xs
    assert outer["ts"] == 0.0 and outer["dur"] == pytest.approx(4000.0)
    assert inner["ts"] == pytest.approx(1000.0)
    assert inner["dur"] == pytest.approx(1000.0)
    assert outer["args"] == {"n": 1, "extra": 7}
    mark, = [e for e in obj["traceEvents"] if e["ph"] == "i"]
    assert mark["tid"] == "requests" and mark["s"] == "t"
    ctr, = [e for e in obj["traceEvents"] if e["ph"] == "C"]
    assert ctr["args"] == {"depth": 2.0, "active": 1.0}
    # every track got a thread_name metadata event
    meta_tids = {e["tid"] for e in obj["traceEvents"] if e["ph"] == "M"
                 and e["name"] == "thread_name"}
    assert {"engine", "requests", "counters"} <= meta_tids


def test_export_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("decode_step", n_active=2):
        tr.instant("submit", tid="requests")
    path = tr.export(str(tmp_path / "t.json"))
    obj = json.load(open(path))
    validate_trace(obj)
    assert tr.span_names() == ["decode_step"]
    assert len(tr.find("submit", "i")) == 1


def test_end_without_begin_raises():
    with pytest.raises(RuntimeError):
        Tracer().end()


def test_unclosed_span_closed_at_export():
    tr = Tracer()
    tr.begin("open_ended")
    obj = tr.to_chrome()
    validate_trace(obj)
    ev, = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert ev["args"]["unclosed_at_export"] is True


def _ev(name="e", ph="X", ts=0.0, dur=1.0, tid="t", **kw):
    e = {"name": name, "ph": ph, "ts": ts, "pid": 0, "tid": tid, **kw}
    if ph == "X":
        e.setdefault("dur", dur)
    return e


@pytest.mark.parametrize("bad", [
    [],                                           # not a dict
    {"traceEvents": 3},                           # traceEvents not a list
    {"traceEvents": [{"ph": "X", "ts": 0.0}]},    # no name
    {"traceEvents": [_ev(ph="B")]},               # phase not emitted here
    {"traceEvents": [_ev(dur=None)]},             # X without numeric dur
    {"traceEvents": [_ev(ts=-1.0)]},              # negative ts
    {"traceEvents": [_ev(ph="C", args={})]},      # counter without series
    {"traceEvents": [_ev(ph="C", args={"a": "hi"})]},   # non-numeric
    {"traceEvents": [_ev(ts=5.0), _ev(ts=1.0)]},  # out of ts order
    {"traceEvents": [_ev(ts=0.0, dur=4.0),        # overlap, not nested
                     _ev(ts=2.0, dur=4.0)]},
])
def test_validate_rejects(bad):
    with pytest.raises(ValueError):
        validate_trace(bad)


def test_validate_accepts_nested_and_disjoint():
    validate_trace({"traceEvents": [
        _ev(ts=0.0, dur=10.0), _ev(ts=0.5, dur=100.0, tid="other"),
        _ev(ts=1.0, dur=2.0), _ev(ts=4.0, dur=6.0), _ev(ts=12.0, dur=1.0),
    ]})


# ---------------------------------------------------------------------------
# metrics: counters, gauges, log-bucketed histograms, registry outputs
# ---------------------------------------------------------------------------

def test_counter_and_gauge():
    c = Counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge("g")
    g.set(5)
    g.set(2)
    assert g.value == 2 and g.peak == 5


def test_histogram_bucket_edges():
    h = Histogram("h", lo=1.0, n_buckets=3, base=2.0)
    assert h.edges == [1.0, 2.0, 4.0, 8.0]
    # exact power-of-2 edges land in the bucket they open (half-open)
    for v, want in [(0.5, 0), (1.0, 1), (1.999, 1), (2.0, 2), (3.999, 2),
                    (4.0, 3), (7.999, 3), (8.0, 4), (100.0, 4)]:
        before = list(h.counts)
        h.observe(v)
        got = [i for i, (a, b) in enumerate(zip(before, h.counts)) if b > a]
        assert got == [want], f"observe({v}) -> bucket {got}, want {want}"
    assert h.count == 9
    assert h.min == 0.5 and h.max == 100.0
    assert h.sum == pytest.approx(0.5 + 1.0 + 1.999 + 2.0 + 3.999 + 4.0
                                  + 7.999 + 8.0 + 100.0)
    assert h.quantile(0.0) == 0.5
    assert h.quantile(1.0) == 100.0
    assert 1.0 <= h.quantile(0.5) <= 8.0


def test_histogram_rejects_bad_params():
    for kw in ({"lo": 0.0}, {"base": 1.0}, {"n_buckets": 0}):
        with pytest.raises(ValueError):
            Histogram("h", **kw)


def test_registry_get_or_create_and_type_clash():
    r = MetricsRegistry()
    assert r.counter("a") is r.counter("a")
    with pytest.raises(TypeError):
        r.gauge("a")
    assert "a" in r and "b" not in r


def test_registry_snapshot_and_prometheus():
    r = MetricsRegistry()
    r.counter("reqs", "total requests").inc(3)
    r.gauge("depth").set(4)
    h = r.histogram("lat", "latency", lo=1.0, n_buckets=2, base=2.0)
    for v in (0.5, 1.5, 3.0, 9.0):
        h.observe(v)
    snap = r.snapshot()
    assert snap["reqs"] == {"type": "counter", "value": 3}
    assert snap["depth"]["peak"] == 4
    assert snap["lat"]["counts"] == [1, 1, 1, 1]

    text = r.prometheus_text()
    assert "# TYPE reqs counter" in text and "reqs 3" in text
    assert "depth_peak 4" in text
    # cumulative buckets: le=2 covers underflow+bucket1, +Inf == count
    assert 'lat_bucket{le="2"} 2' in text
    assert 'lat_bucket{le="4"} 3' in text
    assert 'lat_bucket{le="+Inf"} 4' in text
    assert "lat_count 4" in text


def test_snapshot_jsonl(tmp_path):
    r = MetricsRegistry()
    r.counter("c").inc()
    p = str(tmp_path / "m.jsonl")
    r.snapshot_jsonl(p, {"step": 1})
    r.snapshot_jsonl(p, {"step": 2})
    recs = read_jsonl(p)
    assert [x["step"] for x in recs] == [1, 2]
    assert recs[0]["metrics"]["c"]["value"] == 1
    assert "t" in recs[0]


def test_http_metrics_endpoint():
    r = MetricsRegistry()
    r.counter("up").inc()
    server = start_http_server(r, port=0)
    try:
        port = server.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5).read().decode()
        assert "up 1" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"http://127.0.0.1:{port}/nope",
                                   timeout=5)
    finally:
        server.shutdown()


def test_serve_metrics_summary_schema_and_registry():
    m = ServeMetrics()
    m.on_submit(0, 8)
    m.observe_queue_depth(1)
    m.on_admit(0)
    m.on_decode_step()
    m.on_token(0)
    m.on_decode_step()
    m.on_token(0)
    m.on_finish(0, "ok")
    m.on_submit(1, 4)
    m.on_reject(1)
    s = m.summary(extra={"cache": 1})
    assert set(s) == {
        "requests_submitted", "requests_finished", "requests_rejected",
        "requests_timed_out", "requests_failed", "preemptions",
        "queue_depth_peak", "new_tokens", "decode_steps", "wall_s",
        "tok_per_s", "ttft_mean_s", "ttft_max_s", "queue_wait_mean_s",
        "queue_wait_max_s", "prefill_chunks", "cache"}
    assert s["requests_submitted"] == 2 and s["requests_finished"] == 1
    assert s["requests_rejected"] == 1 and s["new_tokens"] == 2
    assert s["decode_steps"] == 2 and s["queue_depth_peak"] == 1
    assert s["ttft_mean_s"] > 0
    # the same hooks fed the obs registry
    r = m.registry
    assert r.counter("serve_new_tokens").value == 2
    assert r.histogram("serve_ttft_seconds").count == 1
    assert r.histogram("serve_queue_wait_seconds").count == 1
    assert r.histogram("serve_decode_step_seconds").count == 1  # 2 steps
    assert r.histogram("serve_request_tok_per_s").count == 1


# ---------------------------------------------------------------------------
# numerics timeline
# ---------------------------------------------------------------------------

def _snap(k_e, v_e, ovf, tot):
    return {"dec/0:attn": {"k_e": k_e, "v_e": v_e, "ovf": ovf,
                           "half": [[0.0] * len(k_e[0])] * len(k_e),
                           "tot": tot}}


def test_serve_records_first_sample_and_moves():
    cur = _snap([[-4.0, -3.0]], [[-4.0, -4.0]],
                [[2.0, 0.0]], [[10.0, 10.0]])
    first = serve_records(cur, None, step=4, t=0.1, slot_uids={0: 7, 1: 9})
    assert len(first) == 2
    assert first[0]["k_move"] is None and first[0]["uid"] == 7
    assert first[0]["ovf_rate"] == [0.2]

    nxt = _snap([[-3.0, -3.0]], [[-5.0, -4.0]],
                [[2.0, 0.0]], [[20.0, 20.0]])
    recs = serve_records(nxt, cur, step=8, t=0.2, slot_uids={0: 7, 1: 9})
    assert recs[0]["k_move"] == [1]       # exponent grew: scale-up
    assert recs[0]["v_move"] == [-1]      # exponent shrank: scale-down
    assert recs[1]["k_move"] == [0] and recs[1]["v_move"] == [0]
    assert count_moves(recs) == 2
    assert count_moves(first) == 0


def test_serve_records_skips_out_of_range_slots():
    cur = _snap([[-4.0]], [[-4.0]], [[0.0]], [[1.0]])
    recs = serve_records(cur, None, step=1, t=0.0, slot_uids={0: 1, 5: 2})
    assert [r["slot"] for r in recs] == [0]


def test_train_records_aggregates_by_class():
    prev = {"a:h0": [-4.0, -4.0], "w:dense": -6.0}
    new = {"a:h0": [-3.0, -4.0], "w:dense": -7.0}
    acc = {"a:h0": [[3.0, 5.0, 100.0], [0.0, 0.0, 100.0]],
           "w:dense": [0.0, 1.0, 50.0]}
    recs = train_records(prev, new, acc, step=20, t=1.5)
    by_cls = {r["class"]: r for r in recs}
    assert set(by_cls) == {"activation", "weight"}
    act = by_cls["activation"]
    assert act["n_groups"] == 2 and act["moves_up"] == 1
    assert act["moves_down"] == 0
    assert act["ovf_rate"] == pytest.approx(3.0 / 200.0)
    w = by_cls["weight"]
    assert w["moves_down"] == 1 and w["exp_mean"] == -7.0
    assert count_moves(recs) == 2


def test_numerics_log_jsonl_roundtrip(tmp_path):
    p = str(tmp_path / "n.jsonl")
    with NumericsLog(p) as log:
        log.record({"kind": "serve", "step": 1})
        log.record({"kind": "train", "step": 2, "moves_up": 1,
                    "moves_down": 0})
    assert [r["step"] for r in read_jsonl(p)] == [1, 2]
    assert len(log.records) == 2


def test_train_numerics_tap_end_to_end():
    """The jit-side tap feeds train_records with real controller state."""
    from repro.models import maxout as MX
    from repro.optim.opt import OptConfig, sgd_init
    from repro.train import init_train_state, make_train_step

    cfg = MX.MaxoutConfig(hidden=(16, 16), pieces=2)
    gs = MX.group_shapes(cfg)
    policy = PrecisionPolicy("dfxp", update_interval=4)
    params = MX.init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(params, sgd_init(params), gs, policy,
                             init_exp=-8.0)

    def loss_fn(p, b, s, exps):
        return MX.loss_fn(cfg, policy, p, b, exps, s,
                          rng=jax.random.PRNGKey(1))

    step = jax.jit(make_train_step(
        loss_fn, gs, policy, OptConfig(kind="sgd", lr=0.1),
        numerics_tap=True))
    from repro.data import SyntheticImages
    data = SyntheticImages()
    log = NumericsLog()
    for i in range(8):
        b = data.batch(i, 32)
        state, m = step(state, {"x": jnp.asarray(b["x"]),
                                "y": jnp.asarray(b["y"])},
                        jax.random.PRNGKey(i))
        if (i + 1) % 4 == 0:
            tap = jax.device_get(m["numerics"])
            for rec in train_records(tap["prev_exps"], tap["exps"],
                                     tap["acc"], step=i + 1, t=float(i)):
                log.record(rec)
    assert log.records, "tap produced no records"
    classes = {r["class"] for r in log.records}
    assert "activation" in classes
    for r in log.records:
        assert 0.0 <= r["ovf_rate"] <= 1.0
        assert r["n_groups"] >= 1


# ---------------------------------------------------------------------------
# engine integration: trace spans, serve numerics, greedy bit-identity
# ---------------------------------------------------------------------------

POL_CHUNK = PrecisionPolicy("float32", prefill_chunk=4)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("llama3_8b")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prompts(model):
    cfg, _ = model
    return np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                         cfg.vocab_size))


def _run_wave(eng, prompts, max_new=8):
    uids = [eng.submit(p, max_new=max_new) for p in prompts]
    out = eng.run()
    return [out[u] for u in uids]


@pytest.fixture(scope="module")
def traced_run(model, prompts):
    cfg, params = model
    tracer = Tracer()
    nlog = NumericsLog()
    eng = ServeEngine(cfg, POL_CHUNK, params, max_slots=2, max_len=24,
                      options=EngineOptions(
                          cache_bits=8,
                          cache_cfg=CacheQuantConfig(width=8,
                                                     update_interval=2),
                          tracer=tracer, numerics_log=nlog,
                          numerics_every=2))
    out = _run_wave(eng, prompts)
    return eng, tracer, nlog, out


def test_engine_trace_spans_validate(traced_run, tmp_path):
    _, tracer, _, _ = traced_run
    names = set(tracer.span_names())
    assert {"admit", "prefill_chunk", "decode_step"} <= names
    for inst in ("submit", "admitted", "finish"):
        assert tracer.find(inst, "i"), f"missing {inst} instant"
    assert tracer.find("queue", "C"), "missing queue counter samples"
    path = tracer.export(str(tmp_path / "engine.json"))
    validate_trace(json.load(open(path)))


def test_engine_numerics_timeline(traced_run):
    _, _, nlog, _ = traced_run
    assert nlog.records, "no serve numerics samples on controller cadence"
    rec = nlog.records[0]
    assert rec["kind"] == "serve"
    assert len(rec["k_e"]) >= 1 and len(rec["v_e"]) == len(rec["k_e"])
    for r in nlog.records:
        for rate in r["ovf_rate"] + r["half_rate"]:
            assert 0.0 <= rate <= 1.0
        assert r["uid"] in (0, 1)


def test_traced_tokens_bit_identical_to_untraced(model, prompts, traced_run):
    cfg, params = model
    _, _, _, traced_out = traced_run
    plain = ServeEngine(cfg, POL_CHUNK, params, max_slots=2, max_len=24,
                        options=EngineOptions(
                            cache_bits=8,
                            cache_cfg=CacheQuantConfig(width=8,
                                                       update_interval=2)))
    plain_out = _run_wave(plain, prompts)
    for a, b in zip(traced_out, plain_out):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# zero-cost-when-disabled: no extra device syncs, no obs code on hot path
# ---------------------------------------------------------------------------

def test_disabled_obs_adds_no_device_syncs(model, prompts, monkeypatch):
    """Booby-trap: with observability off, a pure decode step performs
    EXACTLY the 3 device fetches (nxt, bad, rate) it did before repro.obs
    existed, and no tracer/numerics code runs at all."""
    import repro.serve.engine as eng_mod

    cfg, params = model
    eng = ServeEngine(cfg, PrecisionPolicy("float32"), params, max_slots=2,
                      max_len=64)
    assert eng._tracer is None and eng._numerics is None
    uids = [eng.submit(p, max_new=40) for p in prompts]

    # any obs entry point reached with obs disabled trips the trap
    for meth in ("begin", "end", "instant", "counter"):
        monkeypatch.setattr(
            Tracer, meth,
            lambda *a, _m=meth, **k: (_ for _ in ()).throw(
                AssertionError(f"Tracer.{_m} called with obs disabled")))
    monkeypatch.setattr(
        eng_mod.kv_pool, "numerics_snapshot",
        lambda *a, **k: (_ for _ in ()).throw(
            AssertionError("numerics_snapshot called with obs disabled")))

    real_asarray = np.asarray
    fetches = [0]

    def counting_asarray(x, *a, **k):
        if isinstance(x, jax.Array):
            fetches[0] += 1
        return real_asarray(x, *a, **k)

    eng.step()                    # admission + first decode (prefill syncs)
    monkeypatch.setattr(eng_mod.np, "asarray", counting_asarray)
    for _ in range(5):            # pure decode steps: nothing admits/ends
        eng.step()
    monkeypatch.setattr(eng_mod.np, "asarray", real_asarray)
    assert fetches[0] == 3 * 5, (
        f"expected 3 device fetches per pure decode step, got "
        f"{fetches[0]} over 5 steps")
    out = eng.run()
    assert all(len(out[u]) == 40 for u in uids)


# ---------------------------------------------------------------------------
# dispatch profiling
# ---------------------------------------------------------------------------

def test_dispatch_profile_disabled_records_nothing():
    dispatch.reset_profile()
    dispatch.profile_enable(False)
    dispatch.blocks_for("fwd", 8, 8, 8, interpret=True)
    assert dispatch.profile_stats() == {}


def test_dispatch_profile_records_and_renders():
    dispatch.reset_profile()
    dispatch.profile_enable(True)
    try:
        for _ in range(3):
            blocks = dispatch.blocks_for("fwd", 8, 16, 32, interpret=True)
        assert blocks == (8, 16, 32)
        w = dispatch.attn_blocks_for(64, 4, 8, interpret=True)
        assert w == 64
        stats = dispatch.profile_stats()
        mm = stats[("mm", "fwd", "interp")]
        assert mm["calls"] == 3 and mm["hits"] == 3 and mm["misses"] == 0
        assert mm["blocks"] == (8, 16, 32)
        assert ("attn", "interp") in stats

        table = dispatch.profile_table()
        assert "mm|fwd|interp" in table and "calls" in table

        tr = Tracer()
        dispatch.profile_trace_counters(tr)
        assert tr.find("dispatch/mm|fwd|interp", "C")
        validate_trace(tr.to_chrome())
    finally:
        dispatch.profile_enable(False)
        dispatch.reset_profile()
