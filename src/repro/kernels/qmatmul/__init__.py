from .ops import qmatmul  # noqa: F401
