"""QTape — the per-trace quantization context model code writes against.

A layer function receives a tape scoped to its own scale/sink slices and
calls ``tape.act(name, x)`` after every weighted sum / nonlinearity and
``tape.weight(name, w)`` when a stored parameter enters a multiplication.
The tape records forward overflow statistics; backward statistics arrive via
sink cotangents (see :mod:`repro.core.quant`). Layer functions return
``tape.stats`` explicitly so ``lax.scan`` stacks them per layer.

Group naming convention (mirrors the paper's per-layer groups):
  ``a:<site>`` activation scale, ``g:<site>`` gradient scale,
  ``w:<name>`` weight use-time scale, ``p:<name>`` parameter-storage scale.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .policy import PrecisionPolicy
from .quant import q_stats, qbound, ste_quant

Array = jax.Array

# Group-prefix → tensor-class names, the paper's §3 breakdown plus the
# optimizer-side groups train/state.py adds ("pg:" gradient-of-parameter,
# "pm:" momentum).  repro.obs aggregates numeric-health series per class.
_TENSOR_CLASSES = {
    "a": "activation",
    "g": "gradient",
    "w": "weight",
    "p": "param",
    "pg": "param_grad",
    "pm": "momentum",
}


def tensor_class(group: str) -> str:
    """Tensor class of a tape group name (``"a:mlp_out"`` → ``"activation"``)."""
    prefix = group.split(":", 1)[0]
    return _TENSOR_CLASSES.get(prefix, prefix)


class QTape:
    def __init__(
        self,
        policy: PrecisionPolicy,
        scales: Dict[str, Array],
        sinks: Dict[str, Array],
    ):
        self.policy = policy
        self.scales = scales
        self.sinks = sinks
        self.stats: Dict[str, Array] = {}

    # -- helpers --------------------------------------------------------
    def _exp(self, group: str) -> Array:
        return self.scales.get(group, jnp.float32(0.0))

    def _record(self, group: str, stats: Array) -> None:
        if group in self.stats:
            self.stats[group] = self.stats[group] + stats
        else:
            self.stats[group] = stats

    # -- quantization sites ----------------------------------------------
    def act(self, name: str, x: Array) -> Array:
        """Activation site: fwd quant at comp width, bwd cotangent quant too."""
        pol = self.policy
        if not pol.enabled:
            return x
        fmt = pol.comp_format()
        a_e, g_e = self._exp(f"a:{name}"), self._exp(f"g:{name}")
        sink = self.sinks.get(f"g:{name}")
        if sink is None:
            sink = jnp.zeros((3,), jnp.float32)
        y = qbound(x, fmt, fmt, a_e, g_e, sink)
        if pol.dynamic or pol.observing:
            self._record(f"a:{name}", q_stats(x, fmt, a_e))
        return y

    def weight(self, name: str, w: Array) -> Array:
        """Weight use-time site: re-quantize storage-width param to comp width.

        Straight-through backward — the weight gradient is quantized once,
        in the train step, with its own ``p:`` group statistics.
        """
        pol = self.policy
        if not pol.enabled:
            return w
        fmt = pol.comp_format()
        e = self._exp(f"w:{name}")
        y = ste_quant(w, fmt, e)
        if pol.dynamic or pol.observing:
            self._record(f"w:{name}", q_stats(w, fmt, e))
        return y

    def state(self, name: str, x: Array, record: bool = True) -> Array:
        """Recurrent-state site: quantized at the *update* width (paper §6 —
        states, like parameters, accumulate many small contributions).

        Pass ``record=False`` when calling from inside an inner ``lax.scan``
        body (stats recorded there would leak tracers out of the scan); then
        record once afterwards with :meth:`record_state_stats` on the stacked
        values.
        """
        pol = self.policy
        if not pol.enabled:
            return x
        fmt = pol.update_format()
        a_e, g_e = self._exp(f"a:{name}"), self._exp(f"g:{name}")
        sink = self.sinks.get(f"g:{name}")
        if sink is None:
            sink = jnp.zeros((3,), jnp.float32)
        y = qbound(x, fmt, fmt, a_e, g_e, sink)
        if (pol.dynamic or pol.observing) and record:
            self._record(f"a:{name}", q_stats(x, fmt, a_e))
        return y

    def record_state_stats(self, name: str, x: Array) -> None:
        pol = self.policy
        if pol.enabled and (pol.dynamic or pol.observing):
            self._record(f"a:{name}",
                         q_stats(x, pol.update_format(), self._exp(f"a:{name}")))

    def dot(self, name: str, x: Array, w: Array, *,
            transpose_b: bool = False) -> Array:
        """Quantized matmul: weight re-quantized to comp width, wide accumulate.

        Operands are cast to ``x.dtype`` (the policy's compute container);
        accumulation is f32 — the MXU contract / paper §7.  ``transpose_b``
        contracts against ``w``'s last dim (the tied-lm-head layout).

        With ``policy.fused_matmul`` set under DFXP arithmetic, the whole
        site — weight rounding, matmul, dgrad, wgrad — runs as one fused
        Pallas kernel per pass (:mod:`repro.kernels.dispatch`), bit-identical
        to the composite below; stats recording is unchanged.
        """
        pol = self.policy
        if pol.dynamic and pol.fused_matmul:
            from repro.kernels.dispatch import tape_dot
            fmt = pol.comp_format()
            e = self._exp(f"w:{name}")
            y = tape_dot(x, w, e, width=fmt.width, transpose_b=transpose_b)
            self._record(f"w:{name}", q_stats(w, fmt, e))
            return y
        wq = self.weight(name, w).astype(x.dtype)
        if transpose_b:
            y = jnp.einsum("...d,vd->...v", x, wq,
                           preferred_element_type=jnp.float32)
        else:
            y = jnp.matmul(x, wq, preferred_element_type=jnp.float32)
        return y.astype(x.dtype)


def null_tape(policy: PrecisionPolicy) -> QTape:
    """Tape with default scales — for fp32/float-emulation paths."""
    return QTape(policy, {}, {})
