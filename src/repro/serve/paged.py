"""Paged KV pool: fixed-size pages + per-request block tables (vLLM-style).

The slot-major pool (:mod:`repro.serve.kv_pool`) reserves every slot's
worst-case ``[W]`` ring rows for the request's whole lifetime, and two
requests with the same system prompt re-prefill and re-store it twice.
This module replaces that reservation with **pages**: K/V mantissas live
in a global ``[n_pages, page_size, K, hd]`` arena per layer, a per-request
*block table* maps logical token blocks to physical pages, and admission
hashes the prompt prefix page-by-page so identical prefixes map the same
physical pages copy-on-write (refcounted; any write to a shared page
forks a private copy first).

DFXP storage keeps the paper's §5 discipline, at the granularity this
layout forces (Ortiz et al. 2018's block-wise shared exponents):

* exponents, overflow accumulators, and cumulative counters are
  **per-page** (``[n_pages]`` / ``[n_pages, 3]``) — a shared page carries
  one exponent no matter how many requests map it;
* a page calibrates (``core.scale.calibrate_exp`` + margin bit) when its
  first row is written; later writes quantize against the page exponent;
* the ×2/÷2 controller applies on the writing request's
  ``update_interval`` crossings, to its **tail page** only — completed
  pages are immutable (shared pages are never written; copy-on-write
  forks them first), so rescaling them would cost a re-grid with no
  accuracy return.

Split of responsibilities:

* :class:`PagedKVCodec` — the jit side.  Implements the
  ``repro.models.layers.RawKVCodec`` protocol on paged entries, so the
  model layer stays storage-agnostic.  ``width=None`` stores raw f32
  pages (bit-identical to the slot-major f32 pool through the same
  logical positions).
* :class:`PageAllocator` — the host side.  Free list, refcounts, the
  prompt-prefix hash index, copy-on-write decisions, and peak-usage
  accounting.  The engine consults it between steps and applies its
  decisions through the jitted pool ops (:func:`reset_slot`,
  :func:`cow_page`, :func:`set_block`).

Page 0 is the permanent **null page**: block-table rows point at it when
no page is mapped, its rows are never written, and its ``pos`` image is
always -1 so attention masks it out.
"""
from __future__ import annotations

import hashlib
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packed import (_overflow_counts, container_dtype, pack_rows,
                               qrange)
from repro.core.quant import exact_pow2
from repro.core.scale import ScaleState, calibrate_exp, controller_step
from repro.models import transformer as T
from repro.serve.kv_pool import CacheQuantConfig, _rescale, is_attn_entry

Array = jax.Array

# entry leaves indexed by slot on axis 1 (full [n, B, ...] shapes); the
# page-storage leaves (k_m/v_m/k_e/v_e/acc_*/tot_*) are indexed by page
_SLOT_KEYS = ("bt", "pos", "n_app", "key")


class PageExhausted(RuntimeError):
    """The page arena has no free or evictable page left.

    A ``RuntimeError`` subclass so pre-existing callers (and tests
    matching ``"exhausted"``) keep working, but typed so the engine can
    catch exhaustion *specifically* and respond with preemption — a
    scheduling event, not a crash — without masking genuine errors.
    """


def is_paged_entry(entry: dict) -> bool:
    """True for paged attention cache entries (block table present)."""
    return isinstance(entry, dict) and "bt" in entry and "pos" in entry


def _pack_paged_rows(x: Array, width: int, e_rows: Array, keep: Array,
                     key=None, det=None):
    """Quantize chunk rows ``[B, C, ...]`` against per-row exponents.

    Unlike ``kv_pool._pack_chunk`` (one exponent per slot), ``e_rows``
    is ``[B, C]`` — each row quantizes against *its destination page's*
    exponent.  Returns ``(mantissa int[B, C, ...], stats f32[B, C, 3])``
    with per-row statistics so the caller can scatter-add them per page.
    """
    qmax, qmin = qrange(width)
    step = exact_pow2(e_rows).reshape(e_rows.shape + (1,) * (x.ndim - 2))
    m = x.astype(jnp.float32) / step
    if key is not None:
        u = jax.vmap(lambda k: jax.random.uniform(k, m.shape[1:]))(key)
        m = jnp.where(det.reshape((-1,) + (1,) * (x.ndim - 1)),
                      jnp.round(m), jnp.floor(m + u))
    else:
        m = jnp.round(m)
    kexp = keep.reshape(keep.shape + (1,) * (x.ndim - 2))
    axes = tuple(range(2, x.ndim))
    ovf, ovfh = _overflow_counts(m, width, axes=axes, mask=kexp)
    row_sz = float(np.prod(x.shape[2:]))
    total = keep.astype(jnp.float32) * row_sz
    stats = jnp.stack([ovf, ovfh, total], axis=-1)           # [B, C, 3]
    m = jnp.clip(m, qmin, qmax).astype(container_dtype(width))
    return m, stats


class PagedKVCodec:
    """KV-cache codec over paged storage + per-request block tables.

    Entry layout (leading layer dim ``n`` stripped inside the layer
    scan; ``P`` = page_size, ``Wp`` = nblocks × P ≥ max_len)::

        k_m, v_m : int8/int16 (or f32) [n, n_pages, P, K, hd]  page arena
        bt       : int32 [n, B, nblocks]   block table (0 = null page)
        pos      : int32 [n, B, Wp]        logical positions (-1 = empty)
        k_e, v_e : f32 [n, n_pages]        per-PAGE log2-steps (packed)
        acc_k/v  : f32 [n, n_pages, 3]     controller window stats
        tot_k/v  : f32 [n, n_pages, 3]     cumulative stats (metrics)
        n_app    : f32 [n, B]              absolute stored-token count
        key      : uint32 [n, B, 2]        (stochastic mode only)

    The block table is duplicated per layer so it rides the layer
    ``lax.scan`` with the rest of the entry; every layer's row is
    identical (one allocator decision maps a logical block to the same
    page id in every layer's arena).  Logical row ``r`` of a request
    lives at physical ``(bt[b, r // P], r % P)``; ``pos`` is indexed by
    the logical row, so attention masking is unchanged from the
    slot-major pool.

    ``config=None`` stores raw f32 pages — no exponents, statistics, or
    controller; token streams are bit-identical to the slot-major f32
    pool.  Admission state (position reset, block-table row, prefix
    sharing) is **host-driven** via :func:`reset_slot` — unlike
    ``PackedKVCodec.append_chunk`` there is no ``p0 == 0`` reset here,
    only the slot-major rounding convention (admission chunks round
    deterministically in stochastic mode).
    """

    def __init__(self, page_size: int, config: Optional[CacheQuantConfig]
                 = None, fused_decode: Optional[bool] = None, *,
                 tp_axis: Optional[str] = None):
        if page_size < 1:
            raise ValueError(f"page_size {page_size} < 1")
        if fused_decode is not None:
            import warnings
            warnings.warn(
                "PagedKVCodec(fused_decode=...) is deprecated; build "
                "pools through repro.serve.kv_pool.make_kv_pool, which "
                "owns the decode-path choice", DeprecationWarning,
                stacklevel=2)
        self.page_size = page_size
        self.cfg = config
        self._fused_decode = bool(fused_decode)
        self.tp_axis = tp_axis

    @property
    def fused_decode(self) -> bool:
        """Whether decode/prefill attention runs the fused paged kernels
        on the page arenas (set by the pool factory)."""
        return self._fused_decode

    @property
    def width(self) -> Optional[int]:
        return None if self.cfg is None else self.cfg.width

    # -- model-layer protocol (called per layer inside lax.scan) ----------
    def load(self, entry: dict):
        """Gather the block table into ``[B, Wp, K, hd]`` f32 K/V."""
        bt = entry["bt"]                                     # [B, nblocks]
        B, nblocks = bt.shape
        P = entry["k_m"].shape[1]
        k = jnp.take(entry["k_m"], bt, axis=0).astype(jnp.float32)
        v = jnp.take(entry["v_m"], bt, axis=0).astype(jnp.float32)
        if self.cfg is not None:
            k = k * exact_pow2(jnp.take(entry["k_e"], bt,
                                        axis=0))[..., None, None, None]
            v = v * exact_pow2(jnp.take(entry["v_e"], bt,
                                        axis=0))[..., None, None, None]
        shp = (B, nblocks * P) + k.shape[3:]
        return k.reshape(shp), v.reshape(shp), entry["pos"]

    def fused_attention(self, entry: dict, qg: Array, q_pos: Array, *,
                        scale: float, window=None, causal: bool = True):
        """Flash-decode through the block-table gather (no ``load``)."""
        from repro.kernels.attn.ops import flash_decode_paged
        return flash_decode_paged(
            qg, entry["k_m"], entry["v_m"], entry["bt"], entry["pos"], q_pos,
            entry.get("k_e"), entry.get("v_e"), width=self.width,
            scale=scale, window=window, causal=causal,
            tp_axis=self.tp_axis)

    def fused_prefill(self, entry: dict, qg: Array, k_new: Array,
                      v_new: Array, p0: Array, n_valid: Array, *,
                      scale: float, window=None, causal: bool = True):
        """Flash-prefill through the block-table gather (no ``load``)."""
        from repro.kernels.attn.ops import flash_prefill_paged
        return flash_prefill_paged(
            qg, k_new, v_new, entry["k_m"], entry["v_m"], entry["bt"],
            entry["pos"], p0, n_valid, entry.get("k_e"), entry.get("v_e"),
            width=self.width, scale=scale, window=window, causal=causal,
            tp_axis=self.tp_axis)

    def append(self, entry: dict, k_new: Array, v_new: Array,
               pos: Array, mask: Optional[Array] = None) -> dict:
        """Append one token's K/V per slot into its tail page.

        The engine guarantees the destination block is writable before
        the step runs: a block whose row 0 is being written was mapped to
        a fresh private page, and a shared tail page was copy-on-write
        forked (:meth:`PageAllocator.ensure_block`).  A row whose page
        starts here (``pos % P == 0``) calibrates the page exponent from
        the row and resets the page's statistics; ``mask`` drops writes,
        statistics, counter advances, and PRNG moves exactly like the
        slot-major codec.
        """
        P = entry["k_m"].shape[1]
        n_pages = entry["k_m"].shape[0]
        bt = entry["bt"]
        B = bt.shape[0]
        Wp = entry["pos"].shape[1]
        bidx = jnp.arange(B)
        posi = pos.astype(jnp.int32)
        blk = jnp.clip(posi // P, 0, bt.shape[1] - 1)
        off = posi % P
        pages = bt[bidx, blk]                                # [B]
        mask = jnp.ones((B,), bool) if mask is None else mask
        wpg = jnp.where(mask, pages, n_pages)                # OOB rows drop
        wrow = jnp.where(mask, posi, Wp)

        out = dict(entry)
        if self.cfg is None:
            out["k_m"] = entry["k_m"].at[wpg, off].set(k_new, mode="drop")
            out["v_m"] = entry["v_m"].at[wpg, off].set(v_new, mode="drop")
            out["pos"] = entry["pos"].at[bidx, wrow].set(posi, mode="drop")
            return out

        cfg = self.cfg
        key_k = key_v = None
        if cfg.stochastic:
            ks = jax.vmap(lambda k: jax.random.split(k, 3))(entry["key"])
            key_k, key_v = ks[:, 0], ks[:, 1]
            out["key"] = jnp.where(mask[:, None], ks[:, 2], entry["key"])

        fresh = (off == 0) & mask
        wfresh = jnp.where(fresh, pages, n_pages)

        def _cal(x):
            ax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(1, 2))
            return calibrate_exp(ax, cfg.width, cfg.margin_bits)

        k_e = entry["k_e"].at[wfresh].set(_cal(k_new), mode="drop")
        v_e = entry["v_e"].at[wfresh].set(_cal(v_new), mode="drop")
        k_m, st_k = pack_rows(k_new, cfg.width, k_e[pages],
                              stochastic_keys=key_k)
        v_m, st_v = pack_rows(v_new, cfg.width, v_e[pages],
                              stochastic_keys=key_v)
        mf = mask.astype(jnp.float32)
        st_k = st_k * mf[:, None]
        st_v = st_v * mf[:, None]
        k_buf = entry["k_m"].at[wpg, off].set(k_m, mode="drop")
        v_buf = entry["v_m"].at[wpg, off].set(v_m, mode="drop")
        out["pos"] = entry["pos"].at[bidx, wrow].set(posi, mode="drop")
        acc_k = entry["acc_k"].at[wfresh].set(0.0, mode="drop") \
            .at[wpg].add(st_k, mode="drop")
        acc_v = entry["acc_v"].at[wfresh].set(0.0, mode="drop") \
            .at[wpg].add(st_v, mode="drop")
        out["tot_k"] = entry["tot_k"].at[wfresh].set(0.0, mode="drop") \
            .at[wpg].add(st_k, mode="drop")
        out["tot_v"] = entry["tot_v"].at[wfresh].set(0.0, mode="drop") \
            .at[wpg].add(st_v, mode="drop")
        pf = posi.astype(jnp.float32)
        out["n_app"] = jnp.where(mask, pf + 1.0, entry["n_app"])

        # §5 controller on update_interval crossings of the absolute
        # stored-token count, applied to the writing row's page only
        interval = float(cfg.update_interval)
        cross = (jnp.floor((pf + 1.0) / interval)
                 > jnp.floor(pf / interval)) & mask
        apply = jnp.zeros((n_pages,), bool).at[
            jnp.where(cross, pages, n_pages)].set(True, mode="drop")
        st = controller_step(
            ScaleState(exps={"k": k_e, "v": v_e},
                       acc={"k": acc_k, "v": acc_v}),
            max_overflow_rate=cfg.max_overflow_rate, apply=apply)
        out["k_e"], out["v_e"] = st.exps["k"], st.exps["v"]
        out["acc_k"], out["acc_v"] = st.acc["k"], st.acc["v"]
        de_k = out["k_e"] - k_e
        de_v = out["v_e"] - v_e
        out["k_m"], out["v_m"] = jax.lax.cond(
            jnp.any(de_k != 0.0) | jnp.any(de_v != 0.0),
            lambda a: (_rescale(a[0], de_k, cfg.width),
                       _rescale(a[1], de_v, cfg.width)),
            lambda a: a, (k_buf, v_buf))
        return out

    def append_chunk(self, entry: dict, k_new: Array, v_new: Array,
                     p0: Array, n_valid: Array) -> dict:
        """Quantize-on-write one prefill chunk into the mapped pages.

        A page is **fresh** when its first logical row is inside this
        chunk (``block·P >= p0``): it calibrates from the chunk rows
        landing on it and its statistics reset.  A partially-filled page
        continuing from an earlier chunk (or a copy-on-write fork of a
        shared tail) keeps its exponent.  ``n_app`` tracks the absolute
        stored-token count, so controller cadence is a pure function of
        position — identical whether a prefix was shared or re-prefilled.
        Rows ``>= n_valid`` (ragged final chunk) drop from writes and
        statistics.
        """
        P = entry["k_m"].shape[1]
        n_pages = entry["k_m"].shape[0]
        bt = entry["bt"]
        B, nblocks = bt.shape
        Wp = entry["pos"].shape[1]
        C = k_new.shape[1]
        idx = jnp.arange(C, dtype=jnp.int32)
        pos = p0[:, None] + idx[None, :]                     # [B, C]
        keep = idx[None, :] < n_valid[:, None]               # [B, C]
        blk = jnp.clip(pos // P, 0, nblocks - 1)
        off = pos % P
        pages = jnp.take_along_axis(bt, blk, axis=1)         # [B, C]
        bidx = jnp.arange(B)[:, None]
        wpg = jnp.where(keep, pages, n_pages)
        wrow = jnp.where(keep, pos, Wp)

        out = dict(entry)
        if self.cfg is None:
            out["k_m"] = entry["k_m"].at[wpg, off].set(k_new, mode="drop")
            out["v_m"] = entry["v_m"].at[wpg, off].set(v_new, mode="drop")
            out["pos"] = entry["pos"].at[bidx, wrow].set(pos, mode="drop")
            return out

        cfg = self.cfg
        key_k = key_v = det = None
        if cfg.stochastic:
            ks = jax.vmap(lambda k: jax.random.split(k, 3))(entry["key"])
            key_k, key_v, out["key"] = ks[:, 0], ks[:, 1], ks[:, 2]
            det = p0 == 0      # admission chunks round deterministically

        fresh_row = keep & (blk * P >= p0[:, None])
        wfr = jnp.where(fresh_row, pages, n_pages).ravel()
        fresh_pg = jnp.zeros((n_pages,), bool).at[wfr].set(True, mode="drop")

        def _cal(x, e_old):
            rmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(2, 3))
            pmax = jnp.zeros((n_pages,), jnp.float32).at[wfr].max(
                rmax.ravel(), mode="drop")
            return jnp.where(fresh_pg,
                             calibrate_exp(pmax, cfg.width, cfg.margin_bits),
                             e_old)

        k_e = _cal(k_new, entry["k_e"])
        v_e = _cal(v_new, entry["v_e"])
        k_m, rst_k = _pack_paged_rows(k_new, cfg.width, k_e[pages], keep,
                                      key_k, det)
        v_m, rst_v = _pack_paged_rows(v_new, cfg.width, v_e[pages], keep,
                                      key_v, det)
        k_buf = entry["k_m"].at[wpg, off].set(k_m, mode="drop")
        v_buf = entry["v_m"].at[wpg, off].set(v_m, mode="drop")
        out["pos"] = entry["pos"].at[bidx, wrow].set(pos, mode="drop")

        wpg_f = wpg.ravel()
        acc_k = jnp.where(fresh_pg[:, None], 0.0, entry["acc_k"]) \
            .at[wpg_f].add(rst_k.reshape(-1, 3), mode="drop")
        acc_v = jnp.where(fresh_pg[:, None], 0.0, entry["acc_v"]) \
            .at[wpg_f].add(rst_v.reshape(-1, 3), mode="drop")
        out["tot_k"] = jnp.where(fresh_pg[:, None], 0.0, entry["tot_k"]) \
            .at[wpg_f].add(rst_k.reshape(-1, 3), mode="drop")
        out["tot_v"] = jnp.where(fresh_pg[:, None], 0.0, entry["tot_v"]) \
            .at[wpg_f].add(rst_v.reshape(-1, 3), mode="drop")
        pf0 = p0.astype(jnp.float32)
        nv = n_valid.astype(jnp.float32)
        out["n_app"] = pf0 + nv

        interval = float(cfg.update_interval)
        cross = (jnp.floor((pf0 + nv) / interval)
                 > jnp.floor(pf0 / interval)) & (n_valid > 0)
        last_blk = jnp.clip((p0 + n_valid - 1) // P, 0, nblocks - 1)
        tail_pg = jnp.take_along_axis(bt, last_blk[:, None], axis=1)[:, 0]
        apply = jnp.zeros((n_pages,), bool).at[
            jnp.where(cross, tail_pg, n_pages)].set(True, mode="drop")
        st = controller_step(
            ScaleState(exps={"k": k_e, "v": v_e},
                       acc={"k": acc_k, "v": acc_v}),
            max_overflow_rate=cfg.max_overflow_rate, apply=apply)
        out["k_e"], out["v_e"] = st.exps["k"], st.exps["v"]
        out["acc_k"], out["acc_v"] = st.acc["k"], st.acc["v"]
        de_k = out["k_e"] - k_e
        de_v = out["v_e"] - v_e
        out["k_m"], out["v_m"] = jax.lax.cond(
            jnp.any(de_k != 0.0) | jnp.any(de_v != 0.0),
            lambda a: (_rescale(a[0], de_k, cfg.width),
                       _rescale(a[1], de_v, cfg.width)),
            lambda a: a, (k_buf, v_buf))
        return out

    # -- pool construction (full [n, B, ...] shapes, outside the scan) ----
    def init_like(self, raw: dict, n_pages: int) -> dict:
        """Paged zero-entry matching a raw ``{"k","v","pos"}`` entry."""
        n, B, W, K, hd = raw["k"].shape
        P = self.page_size
        nblocks = -(-W // P)
        dtype = (jnp.float32 if self.cfg is None
                 else container_dtype(self.cfg.width))
        entry = {
            "k_m": jnp.zeros((n, n_pages, P, K, hd), dtype),
            "v_m": jnp.zeros((n, n_pages, P, K, hd), dtype),
            "bt": jnp.zeros((n, B, nblocks), jnp.int32),
            "pos": jnp.full((n, B, nblocks * P), -1, jnp.int32),
        }
        if self.cfg is not None:
            entry.update({
                "k_e": jnp.zeros((n, n_pages), jnp.float32),
                "v_e": jnp.zeros((n, n_pages), jnp.float32),
                "acc_k": jnp.zeros((n, n_pages, 3), jnp.float32),
                "acc_v": jnp.zeros((n, n_pages, 3), jnp.float32),
                "tot_k": jnp.zeros((n, n_pages, 3), jnp.float32),
                "tot_v": jnp.zeros((n, n_pages, 3), jnp.float32),
                "n_app": jnp.zeros((n, B), jnp.float32),
            })
            if self.cfg.stochastic:
                entry["key"] = jnp.zeros((n, B, 2), jnp.uint32)
        return entry


def make_paged_pool(cfg: T.ModelConfig, max_slots: int, max_len: int,
                    codec: PagedKVCodec,
                    n_pages: Optional[int] = None) -> dict:
    """Zero paged pool: ``init_cache`` with attn entries re-laid as pages.

    ``n_pages`` defaults to full residency (every slot can map its whole
    ``max_len`` ring) **plus** the null page; a smaller page budget is
    legal — the allocator recycles freed and evicted pages — and turns
    exhaustion into a ``RuntimeError`` instead of silent corruption.
    Non-attention entries (none in the dense family the paged engine
    accepts) pass through slot-major.
    """
    raw = T.init_cache(cfg, max_slots, max_len)
    P = codec.page_size
    caps = {e["k"].shape[2] for sc in raw.values() for e in sc.values()
            if is_attn_entry(e)}
    if len(caps) > 1:
        raise ValueError(f"paged pool needs one ring cap, got {caps} "
                         "(windowed attention is not paged)")
    nblocks = -(-max(caps) // P) if caps else 0
    if n_pages is None:
        n_pages = 1 + max_slots * nblocks
    return {sname: {bkey: codec.init_like(e, n_pages) if is_attn_entry(e)
                    else e for bkey, e in sc.items()}
            for sname, sc in raw.items()}


# -- jitted pool ops (engine-driven admission / sharing / copy-on-write) --
def reset_slot(pool: dict, slot, shared_len, bt_row: Array,
               n_app0) -> dict:
    """Re-admit ``slot``: block-table row, position reset, counter seed.

    ``bt_row`` [nblocks] carries the allocator's mapping (shared prefix
    pages first, null elsewhere); positions ``< shared_len`` are marked
    live (the shared pages already hold those rows), the rest empty.
    Jit-safe — ``slot``/``shared_len``/``n_app0`` may be traced.
    """
    new_pool = {}
    for sname, sc in pool.items():
        new_sc = {}
        for bkey, e in sc.items():
            if is_paged_entry(e):
                e = dict(e)
                Wp = e["pos"].shape[2]
                iota = jnp.arange(Wp, dtype=jnp.int32)
                e["pos"] = e["pos"].at[:, slot].set(
                    jnp.where(iota < shared_len, iota, -1))
                e["bt"] = e["bt"].at[:, slot].set(
                    bt_row.astype(jnp.int32))
                if "n_app" in e:
                    e["n_app"] = e["n_app"].at[:, slot].set(
                        jnp.asarray(n_app0, jnp.float32))
            new_sc[bkey] = e
        new_pool[sname] = new_sc
    return new_pool


def cow_page(pool: dict, src, dst) -> dict:
    """Copy page ``src`` onto ``dst`` in every layer of every paged entry.

    The copy-on-write fork: mantissas, the page exponent, and the page's
    controller/cumulative statistics all move, so the fork continues
    exactly where the shared page's writer left off.
    """
    new_pool = {}
    for sname, sc in pool.items():
        new_sc = {}
        for bkey, e in sc.items():
            if is_paged_entry(e):
                e = dict(e)
                for f in ("k_m", "v_m", "k_e", "v_e",
                          "acc_k", "acc_v", "tot_k", "tot_v"):
                    if f in e:
                        e[f] = e[f].at[:, dst].set(e[f][:, src])
            new_sc[bkey] = e
        new_pool[sname] = new_sc
    return new_pool


def set_block(pool: dict, slot, block, page) -> dict:
    """Point ``slot``'s logical ``block`` at physical ``page`` (all layers)."""
    new_pool = {}
    for sname, sc in pool.items():
        new_sc = {}
        for bkey, e in sc.items():
            if is_paged_entry(e):
                e = dict(e)
                e["bt"] = e["bt"].at[:, slot, block].set(
                    jnp.asarray(page, jnp.int32))
            new_sc[bkey] = e
        new_pool[sname] = new_sc
    return new_pool


def slice_slot(pool: dict, slot) -> dict:
    """One-slot view for the chunked-prefill jit.

    Per-slot leaves (block table, positions, counters, PRNG keys) slice
    to ``[n, 1, ...]``; the page arenas pass through whole — the chunk's
    scatter-writes land in global pages, so no per-slot copy exists to
    slice.  Non-paged entries slice on axis 1 wholesale (the slot-major
    layout).
    """
    def _one(a):
        return jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1)

    new_pool = {}
    for sname, sc in pool.items():
        new_sc = {}
        for bkey, e in sc.items():
            if is_paged_entry(e):
                new_sc[bkey] = {f: (_one(a) if f in _SLOT_KEYS else a)
                                for f, a in e.items()}
            else:
                new_sc[bkey] = jax.tree_util.tree_map(_one, e)
        new_pool[sname] = new_sc
    return new_pool


def merge_slot(pool: dict, sub: dict, slot) -> dict:
    """Merge a :func:`slice_slot` view back after a chunk ran on it.

    Per-slot leaves update the slot's row; page-arena leaves *replace*
    the pool's (the sliced run scatter-wrote the global pages in place).
    """
    def _upd(dst, src):
        return jax.lax.dynamic_update_slice_in_dim(dst, src, slot, axis=1)

    new_pool = {}
    for sname, sc in pool.items():
        new_sc = {}
        for bkey, e in sc.items():
            s = sub[sname][bkey]
            if is_paged_entry(e):
                new_sc[bkey] = {f: (_upd(e[f], s[f]) if f in _SLOT_KEYS
                                    else s[f]) for f in e}
            else:
                new_sc[bkey] = jax.tree_util.tree_map(_upd, e, s)
        new_pool[sname] = new_sc
    return new_pool


def page_nbytes(pool: dict) -> int:
    """HBM bytes of ONE page across every layer of every paged entry.

    Counts the mantissa rows plus the per-page exponent/statistic scalars
    — the marginal cost of mapping one more page, which × the
    allocator's ``peak_pages`` is the pool's true working set (the
    number the memory-regression bench rows record).
    """
    total = 0
    for sc in pool.values():
        for e in sc.values():
            if not is_paged_entry(e):
                continue
            n_pages = e["k_m"].shape[1]
            for f in ("k_m", "v_m", "k_e", "v_e",
                      "acc_k", "acc_v", "tot_k", "tot_v"):
                if f in e:
                    total += e[f].nbytes // n_pages
    return total


def slot_nbytes(pool: dict) -> int:
    """HBM bytes ONE slot permanently reserves in a slot-major pool."""
    total = 0
    for sc in pool.values():
        for e in sc.values():
            if not is_attn_entry(e) or is_paged_entry(e):
                continue
            B = e["pos"].shape[1]
            for f in ("k", "v", "k_m", "v_m", "k_e", "v_e", "pos",
                      "acc_k", "acc_v", "tot_k", "tot_v", "n_app"):
                if f in e:
                    total += e[f].nbytes // B
    return total


class PageAllocator:
    """Host-side page bookkeeping: free list, refcounts, prefix index.

    The allocator never touches device arrays — it decides, the engine
    applies through the jitted pool ops.  Page ids are ``1..n_pages-1``
    (0 is the null page).  Invariants:

    * ``rc[p] >= 1`` while any block table maps ``p``; the prefix index
      holds one extra pin on every registered page;
    * a page with ``rc > 1`` is **shared** and immutable — the engine
      must :meth:`ensure_block` before any write, which forks a private
      copy (copy-on-write) or maps a fresh page for a new block;
    * eviction only unpins index-registered pages nobody maps
      (``rc == 1``), oldest registration first.
    """

    def __init__(self, n_pages: int, page_size: int, nblocks: int):
        self.n_pages = n_pages
        self.page_size = page_size
        self.nblocks = nblocks
        self._free = list(range(n_pages - 1, 0, -1))     # pop() -> 1, 2, ...
        self.rc = np.zeros(n_pages, np.int32)
        self.bt: dict = {}                               # slot -> [nblocks]
        self._index: dict = {}                           # digest -> page
        self._rev: dict = {}                             # page -> digest
        self._order: List[str] = []                      # registration FIFO
        self.peak_pages = 0
        self.hits = 0                                    # prefix page hits
        self.cow_forks = 0
        self.evictions = 0
        self.allocs = 0

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - 1 - len(self._free)

    # -- allocation -------------------------------------------------------
    def alloc(self) -> int:
        if not self._free:
            self._evict_one()
        if not self._free:
            raise PageExhausted(
                f"page pool exhausted ({self.n_pages - 1} pages, "
                f"{len(self._index)} registered prefixes all still mapped)")
        p = self._free.pop()
        self.rc[p] = 1
        self.allocs += 1
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return p

    def _release(self, p: int) -> None:
        self.rc[p] = 0
        self._free.append(p)

    def decref(self, p: int) -> None:
        self.rc[p] -= 1
        if self.rc[p] == 0:
            self._free.append(p)

    def _evict_one(self) -> None:
        """Unpin the oldest registered prefix page nobody maps."""
        for d in self._order:
            p = self._index[d]
            if self.rc[p] == 1:                          # index pin only
                self._order.remove(d)
                del self._index[d]
                del self._rev[p]
                self._release(p)
                self.evictions += 1
                return

    # -- per-slot block tables -------------------------------------------
    def new_slot(self, slot: int, mapped: List[int]) -> np.ndarray:
        """Open ``slot`` with ``mapped`` prefix pages; returns the bt row."""
        row = np.zeros(self.nblocks, np.int32)
        row[:len(mapped)] = mapped
        self.bt[slot] = row
        return row

    def free_slot(self, slot: int) -> None:
        for p in self.bt.pop(slot, []):
            if p:
                self.decref(int(p))

    def ensure_block(self, slot: int, block: int) -> Optional[Tuple]:
        """Make ``slot``'s ``block`` writable before a step touches it.

        Returns ``None`` (already private), ``("alloc", 0, page)`` (a
        fresh page was mapped), or ``("cow", src, dst)`` (a shared page
        was forked — the engine must copy ``src → dst`` on device).
        """
        page = int(self.bt[slot][block])
        if page == 0:
            p = self.alloc()
            self.bt[slot][block] = p
            return ("alloc", 0, p)
        if self.rc[page] > 1:
            dst = self.alloc()
            self.rc[page] -= 1
            self.bt[slot][block] = dst
            self.cow_forks += 1
            return ("cow", page, dst)
        return None

    # -- fault injection --------------------------------------------------
    def grab(self, n: int) -> List[int]:
        """Hold up to ``n`` pages hostage (fault injection: forced
        exhaustion).  Grabbed pages are allocated but mapped by no block
        table, so nothing reads or writes them; :meth:`ungrab` returns
        them.  Stops early (without raising) when the arena runs dry —
        the caller decides how much pressure it wants."""
        out: List[int] = []
        for _ in range(n):
            try:
                out.append(self.alloc())
            except PageExhausted:
                break
        return out

    def ungrab(self, pages: List[int]) -> None:
        """Release pages held by :meth:`grab` back to the free list."""
        for p in pages:
            self.decref(int(p))

    # -- prompt-prefix sharing -------------------------------------------
    @staticmethod
    def _page_bytes(tokens, i: int, P: int) -> bytes:
        return np.asarray(tokens[i * P:(i + 1) * P], np.int64).tobytes()

    def match_prefix(self, tokens) -> Tuple[List[int], int]:
        """Longest registered page-prefix of ``tokens``; increfs the hits.

        Returns ``(pages, shared_len)``.  ``shared_len`` is capped at
        ``len(tokens) - 1`` — at least one prompt token must run through
        the model to produce the first logits — so a fully-registered
        prompt keeps its last matched page mapped but re-computes (and
        copy-on-write rewrites) its final row.
        """
        P = self.page_size
        L = len(tokens)
        h = hashlib.sha1()
        pages: List[int] = []
        for i in range(L // P):
            h.update(self._page_bytes(tokens, i, P))
            p = self._index.get(h.hexdigest())
            if p is None:
                break
            pages.append(p)
        shared_len = min(len(pages) * P, L - 1)
        for p in pages:
            self.rc[p] += 1
        self.hits += len(pages)
        return pages, shared_len

    def register_prefix(self, slot: int, tokens) -> int:
        """Index ``slot``'s full prompt pages for future admissions.

        Called once the prompt is fully stored (final prefill chunk).
        Each newly-registered page gains the index pin; already-known
        digests keep their existing page.  Returns #pages registered.
        """
        P = self.page_size
        h = hashlib.sha1()
        n = 0
        for i in range(len(tokens) // P):
            h.update(self._page_bytes(tokens, i, P))
            d = h.hexdigest()
            if d in self._index:
                continue
            p = int(self.bt[slot][i])
            if p == 0:
                break
            self._index[d] = p
            self._rev[p] = d
            self._order.append(d)
            self.rc[p] += 1
            n += 1
        return n

    def stats(self) -> dict:
        return {
            "page_cache_hits": self.hits,
            "page_cow_forks": self.cow_forks,
            "page_evictions": self.evictions,
            "pages_allocated": self.allocs,
            "pages_in_use": self.pages_in_use,
            "pages_in_use_peak": self.peak_pages,
            "pages_registered": len(self._index),
        }
