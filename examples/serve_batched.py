"""Continuous-batching serving with mixed-length prompts + int8 KV cache.

Six requests with three different prompt lengths share four slots. The
first run uses whole-prompt prefill (equal lengths grouped, the rest
queue until a decoding slot frees); the second enables chunked prefill
(`--prefill-chunk 8`): every request admits immediately, one 8-token
chunk runs per engine step interleaved with decode, and its K/V is
quantized straight into the int8 pool — one prefill compile for all
three lengths. (Under dfxp arithmetic the two paths are
numerics-equivalent, not token-identical — the activation quantizer
re-rounds reordered float ops; run both with `--arithmetic float32`
to see identical greedy streams.)

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    args = ["--arch", "llama3_8b", "--smoke", "--arithmetic", "dfxp",
            "--num-requests", "6", "--slots", "4",
            "--prompt-len", "8,16,32", "--max-new", "16",
            "--cache-bits", "8"]
    serve_main(args)
    serve_main(args + ["--prefill-chunk", "8", "--fused-decode"])
