# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Paper artifacts (Table 3, Figures 1-4) train the maxout network under
# each arithmetic on the scaled synthetic task; ``derived`` is the final
# loss normalized by the fp32 baseline (the paper's normalized test error).
# Kernel rows report microseconds per call; ``derived`` is MFLOP for
# matmuls. Run with: PYTHONPATH=src python -m benchmarks.run [--quick]
#
# JSON-emitting suites each persist their rows to a per-suite file —
# ``kernels`` → BENCH_kernels.json (jnp-composite vs fused Pallas pairs),
# ``serve`` → BENCH_serve.json (sequential vs continuous-batched,
# f32 vs packed-cache tok/s) — the perf-trajectory record; ``--tiny``
# shrinks both to CI-smoke shapes that assert execution, not perf.
# ``--json-out`` overrides the path when exactly one such suite runs.
import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="table3 + kernels only")
    ap.add_argument("--only", default="")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-smoke shapes for the kernels/serve suites")
    ap.add_argument("--json-out", default="",
                    help="override the JSON path (needs exactly one "
                         "JSON-emitting suite selected, e.g. --only serve)")
    ap.add_argument("--profile", action="store_true",
                    help="enable repro.kernels.dispatch profiling and print "
                         "the per-bucket call/hit/compile table to stderr "
                         "(fails if nothing was recorded)")
    args = ap.parse_args()

    from . import kernels_bench, paper_tables, serve_bench

    if args.profile:
        from repro.kernels import dispatch
        dispatch.profile_enable(True)

    suites = [
        ("table3", paper_tables.table3_formats, None),
        ("fig1", paper_tables.fig1_radix, None),
        ("fig2", paper_tables.fig2_comp_width, None),
        ("fig3", paper_tables.fig3_update_width, None),
        ("fig4", paper_tables.fig4_overflow_rate, None),
        ("kernels", lambda: kernels_bench.run(tiny=args.tiny),
         "BENCH_kernels.json"),
        ("serve", lambda: serve_bench.run(tiny=args.tiny),
         "BENCH_serve.json"),
    ]
    if args.quick:
        suites = [s for s in suites if s[0] in ("table3", "kernels")]
    if args.only:
        suites = [s for s in suites if s[0] in args.only.split(",")]
    json_suites = [name for name, _, path in suites if path]
    if args.json_out and len(json_suites) != 1:
        ap.error(f"--json-out needs exactly one JSON-emitting suite "
                 f"selected, got {json_suites}")

    print("name,us_per_call,derived")
    for name, fn, json_path in suites:
        try:
            rows = list(fn())
            if not rows:
                # a suite that silently emits nothing would commit an empty
                # BENCH_*.json and read as "measured, no regression"
                raise RuntimeError(f"suite {name!r} emitted no rows")
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]:.4f}", flush=True)
            # rows may carry a 4th "kind" field ("time" default; "mem"
            # rows are byte counts the gate diffs as direct ratios)
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0,0  # {e}", file=sys.stderr)
            raise
        if json_path:
            import jax
            out_path = args.json_out or json_path
            payload = {
                "meta": {"backend": jax.default_backend(),
                         "suite": name, "tiny": args.tiny},
                "rows": [{"name": r[0], "us_per_call": round(r[1], 1),
                          "derived": r[2],
                          "kind": r[3] if len(r) > 3 else "time"}
                         for r in rows],
            }
            if name == "serve" and serve_bench.OBS:
                # per-row obs metrics snapshots (TTFT/tok-s histograms);
                # render with: python -m benchmarks.make_report --serve-json
                payload["obs"] = serve_bench.OBS
            with open(out_path, "w") as f:
                json.dump(payload, f, indent=1)
            print(f"# wrote {len(rows)} {name} rows -> {out_path}",
                  file=sys.stderr)

    if args.profile:
        from repro.kernels import dispatch
        stats = dispatch.profile_stats()
        if not stats:
            raise SystemExit("--profile: dispatch recorded no buckets — "
                             "profiling hooks are broken or no kernel "
                             "dispatch ran")
        print(dispatch.profile_table(), file=sys.stderr)


if __name__ == '__main__':
    main()
