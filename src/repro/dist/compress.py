"""Low-bit compression for the distributed wires (beyond-paper §DFXP-comm).

The paper quantizes *compute*; at scale the bytes that hurt are the ones
crossing the interconnect. Three wires, same DFXP grid machinery as
:mod:`repro.core.quant`:

  * :func:`compress_decompress` — data-parallel gradient mean-reduce in
    ``bits``-bit lanes with **error feedback**: the quantization residual is
    carried to the next step, so the time-averaged update is unbiased
    (Seide et al. 1-bit SGD / Karimireddy et al. EF-SGD). Inside
    ``shard_map`` a shared power-of-two scale is agreed via ``pmax`` so
    every replica quantizes onto the same grid and the ``psum`` is exact
    integer addition.
  * :func:`compress_tree` — the same over a gradient pytree, one scale per
    leaf (weight-gradient magnitudes differ by orders across layers).
  * :func:`compressed_all_to_all` — MoE dispatch/combine ``all_to_all`` in
    int8/int16 lanes, reusing the tape's activation scale exponent for the
    site; backward pass runs the reverse ``all_to_all`` through the same
    quantizer (low-bit both directions, matching the paper's quantized
    backprop signals).

Stochastic rounding (``fixed_round(..., stochastic=True)``) is available via
``stochastic_key`` for unbiasedness per-step; the default deterministic
round relies on error feedback for unbiasedness over time and keeps tests
reproducible.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import exact_pow2, fixed_round

Array = jax.Array

_TINY = 1e-38


def _grid_exp(amax: Array, bits: int) -> Array:
    """Smallest integer ``e`` such that ``amax`` fits the ``bits``-bit grid
    ``k * 2**e``, ``|k| <= 2**(bits-1)-1``."""
    qmax = float(2 ** (bits - 1) - 1)
    return jnp.ceil(jnp.log2(jnp.maximum(amax, _TINY) / qmax))


def compress_decompress(
    g: Array,
    r: Array,
    bits: int,
    axis_name=None,
    *,
    stochastic_key: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Quantize ``g + r`` to ``bits`` bits; optionally mean-reduce over
    ``axis_name``. Returns ``(g_hat, r_new)``.

    ``r`` is the error-feedback residual from the previous step; ``r_new``
    is this step's residual (``compensated - quantized``, always local).
    With ``axis_name`` (inside ``shard_map``) the scale is agreed globally
    with ``pmax`` and ``g_hat`` is the mean of the per-replica quantized
    gradients — the compressed all-reduce.
    """
    c = g.astype(jnp.float32) + r.astype(jnp.float32)
    amax = jnp.max(jnp.abs(c))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    e = _grid_exp(amax, bits)
    q, _ = fixed_round(c, bits, e, stochastic=stochastic_key is not None,
                       key=stochastic_key)
    r_new = c - q
    if axis_name is not None:
        # q values are k·2**e with small integer k: the psum is exact
        # integer addition in the shared grid (the int-lane wire format).
        n = jax.lax.psum(jnp.float32(1.0), axis_name)
        q = jax.lax.psum(q, axis_name) / n
    return q.astype(g.dtype), r_new.astype(r.dtype)


def ef_init(params):
    """Zero error-feedback residuals matching ``params``' *compute* view.

    Residuals live at the wire's precision (f32), not the storage
    container's — a :class:`~repro.core.packed.PackedArray` leaf maps to
    an f32 zeros array of its logical shape.  This is the tree a
    checkpointed trainer must save/restore for bit-exact resume of
    compressed-gradient training.
    """
    from repro.core.packed import PackedArray

    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params,
                        is_leaf=lambda x: isinstance(x, PackedArray))


def compress_tree(g, r, bits: int, axis_name=None, *,
                  stochastic_key: Optional[Array] = None):
    """:func:`compress_decompress` over a pytree, one scale per leaf.

    Returns ``(g_hat_tree, r_new_tree)`` with the structure of ``g``.
    """
    leaves_g, treedef = jax.tree.flatten(g)
    leaves_r = treedef.flatten_up_to(r)
    outs = []
    for i, (gl, rl) in enumerate(zip(leaves_g, leaves_r)):
        key = (jax.random.fold_in(stochastic_key, i)
               if stochastic_key is not None else None)
        outs.append(compress_decompress(gl, rl, bits, axis_name,
                                        stochastic_key=key))
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def _int_lane_dtype(bits: int):
    if bits <= 8:
        return jnp.int8
    if bits <= 16:
        return jnp.int16
    return jnp.int32


def _quantized_all_to_all(x: Array, e: Array, bits: int, axis_name: str,
                          split_axis: int, concat_axis: int) -> Array:
    """Round onto the ``2**e`` grid, ship int mantissas, dequantize."""
    e = jnp.asarray(e, jnp.float32)
    step = exact_pow2(e)
    qmax = float(2 ** (bits - 1) - 1)
    qmin = -float(2 ** (bits - 1))
    m = jnp.clip(jnp.round(x.astype(jnp.float32) / step), qmin, qmax)
    mi = m.astype(_int_lane_dtype(bits))
    mo = jax.lax.all_to_all(mi, axis_name, split_axis=split_axis,
                            concat_axis=concat_axis, tiled=True)
    return (mo.astype(jnp.float32) * step).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _ca2a(x, e, bits, axis_name, split_axis, concat_axis):
    return _quantized_all_to_all(x, e, bits, axis_name, split_axis,
                                 concat_axis)


def _ca2a_fwd(x, e, bits, axis_name, split_axis, concat_axis):
    return _ca2a(x, e, bits, axis_name, split_axis, concat_axis), e


def _ca2a_bwd(bits, axis_name, split_axis, concat_axis, e, ct):
    # Transpose of all_to_all(split, concat) is all_to_all(concat, split);
    # the cotangent rides the wire at the same width (quantized backprop).
    ctx = _quantized_all_to_all(ct, e, bits, axis_name, concat_axis,
                                split_axis)
    return ctx, jnp.zeros_like(jnp.asarray(e, jnp.float32))


_ca2a.defvjp(_ca2a_fwd, _ca2a_bwd)


def compressed_all_to_all(x: Array, e: Array, bits: int, axis_name: str, *,
                          split_axis: int, concat_axis: int) -> Array:
    """Tiled ``all_to_all`` of ``x`` in ``bits``-bit integer lanes.

    ``e`` is the DFXP scale exponent of the activation group being shipped
    (the tape already tracks one per dispatch/combine site); values are
    rounded onto ``k * 2**e`` and the int mantissas cross the wire.
    """
    return _ca2a(x, jnp.asarray(e, jnp.float32), int(bits), axis_name,
                 int(split_axis), int(concat_axis))
