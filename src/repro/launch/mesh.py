"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 v5e chips) or 2×16×16 two-pod (512) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_serve_mesh(*, tp: int = 1, cp: int = 1):
    """``(cp, tp)`` serving mesh: ``data`` (CP window shards) × ``model``
    (KV-head TP shards), matching :func:`repro.dist.serve_pod_ctx`.

    Size-1 axes are kept (a 1×1 mesh is a valid single-device "sharded"
    engine — the degenerate case the identity tests anchor on).  Raises
    :class:`repro.dist.MeshConfigError` up front when the request
    exceeds the visible device count, instead of a late
    ``jax.make_mesh`` assertion mid-engine-construction.
    """
    from repro.dist import MeshConfigError

    if tp < 1 or cp < 1:
        raise MeshConfigError(f"tp={tp} and cp={cp} must be >= 1")
    have = jax.device_count()
    if tp * cp > have:
        raise MeshConfigError(
            f"serve mesh needs tp*cp = {tp * cp} devices but only {have} "
            f"are visible (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N for CPU testing)")
    return jax.make_mesh((cp, tp), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for multi-device CPU tests (forced host device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
