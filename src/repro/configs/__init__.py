"""Architecture registry: the 10 assigned configs + the paper's maxout nets.

``get(name)`` → full ModelConfig; ``get_smoke(name)`` → reduced same-family
config for CPU smoke tests; ``cells(name)`` → the runnable shape cells
(skips are documented in each config file and DESIGN.md §6).
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.transformer import ModelConfig

from .shapes import SHAPES, ShapeSpec, input_specs  # noqa: F401

ARCHS = (
    "zamba2_1p2b",
    "llama3_8b",
    "qwen3_14b",
    "phi3_medium_14b",
    "gemma3_27b",
    "seamless_m4t_medium",
    "llama4_maverick_400b",
    "granite_moe_1b",
    "mamba2_370m",
    "qwen2_vl_72b",
)

_ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "llama3-8b": "llama3_8b",
    "qwen3-14b": "qwen3_14b",
    "phi3-medium-14b": "phi3_medium_14b",
    "gemma3-27b": "gemma3_27b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "llama4-maverick-400b": "llama4_maverick_400b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "granite-moe-1b": "granite_moe_1b",
    "mamba2-370m": "mamba2_370m",
    "qwen2-vl-72b": "qwen2_vl_72b",
}


def _module(name: str):
    key = _ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{key}")


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def cells(name: str) -> Tuple[str, ...]:
    return _module(name).CELLS


def all_cells() -> Dict[str, Tuple[str, ...]]:
    return {a: cells(a) for a in ARCHS}
