"""Context-parallel GQA decode attention (KV window sharded over the mesh).

At 500k-token contexts the decode KV cache dwarfs everything else on a
device; ``ShardingRules(seq_shard_cache=True)`` shards the cache *window*
axis over the data axis, and this module runs single-query attention
against that sharded window: each device computes attention over its local
slots only, and the partial softmax statistics ``(max, sum-exp, weighted
values)`` are combined **exactly** across devices with
``pmax``/``psum`` — the standard log-sum-exp merge, so the result is
bit-close to monolithic attention (the multidevice test pins 1e-4).

Empty ring-buffer slots carry position ``-1``; validity is
``pos >= 0 and q_pos >= pos`` (causality in absolute positions), evaluated
locally — a device whose whole shard is invalid contributes zero weight
through the ``exp(m_local - m_global)`` correction.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._jax_compat import ambient_mesh

Array = jax.Array


def _partial_attention(q, ck, cv, pos, q_pos, *, num_heads: int,
                       num_kv_heads: int, head_dim: int):
    """Local softmax partials over a (shard of the) KV window.

    ``q``: [B, Sq, H, hd]; ``ck``/``cv``: [B, W, K, hd]; ``pos``: [B, W]
    (slot absolute positions, -1 = empty); ``q_pos``: [B, Sq].
    Returns ``(o, l, m)``: [B, K, G, Sq, hd], [B, K, G, Sq], [B, K, G, Sq].
    """
    B, Sq = q.shape[:2]
    K, G = num_kv_heads, num_heads // num_kv_heads
    qg = q.astype(jnp.float32).reshape(B, Sq, K, G, head_dim)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    s = s / math.sqrt(head_dim)
    valid = (pos[:, None, :] >= 0) & (q_pos[:, :, None] - pos[:, None, :]
                                      >= 0)                       # [B,Sq,W]
    vexp = valid[:, None, None, :, :]                             # [B,1,1,Sq,W]
    s = jnp.where(vexp, s, -1e30)
    m = jnp.max(s, axis=-1)                                       # [B,K,G,Sq]
    # fully-masked shard: s - m == 0 everywhere would leak exp(0)=1 — the
    # explicit where() zeroes invalid slots regardless of m.
    p = jnp.where(vexp, jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)                                            # [B,K,G,Sq]
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, cv.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    return o, l, m


def _merge(o, l, m, axes: Tuple[str, ...]):
    """Exact cross-shard softmax merge: rescale partials to the global max."""
    m_glob = jax.lax.pmax(m, axes)
    alpha = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * alpha, axes)
    o_glob = jax.lax.psum(o * alpha[..., None], axes)
    return o_glob, l_glob


def _finish(o, l, B: int, Sq: int, num_heads: int, head_dim: int, dtype):
    out = o / jnp.maximum(l, 1e-30)[..., None]     # [B, K, G, Sq, hd]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, num_heads * head_dim)
    return out.astype(dtype)


def cp_decode_attention(q: Array, cache_k: Array, cache_v: Array,
                        cache_pos: Array, q_pos: Array, *, num_heads: int,
                        num_kv_heads: int, head_dim: int,
                        cp_axes: Tuple[str, ...] = ()) -> Array:
    """Single-query attention over a (possibly window-sharded) KV cache.

    ``q``: [B, Sq, H, hd] (decode: Sq == 1); ``cache_k``/``cache_v``:
    [B, W, K, hd]; ``cache_pos``: [B, W] absolute positions (-1 empty);
    ``q_pos``: [B, Sq]. Returns [B, Sq, H*hd].

    With ``cp_axes`` naming live mesh axes that evenly divide ``W``, the
    window is sharded over them inside a ``shard_map`` and the partial
    statistics are merged exactly; otherwise (no mesh, axis missing,
    indivisible window) it computes the identical monolithic result.
    """
    B, Sq = q.shape[:2]
    W = cache_k.shape[1]
    kw = dict(num_heads=num_heads, num_kv_heads=num_kv_heads,
              head_dim=head_dim)

    cp_axes = tuple(cp_axes)
    mesh = ambient_mesh() if cp_axes else None
    cp_size = 0
    if mesh is not None and all(a in mesh.shape for a in cp_axes):
        cp_size = 1
        for a in cp_axes:
            cp_size *= mesh.shape[a]
    if cp_size > 1 and W % cp_size == 0:
        def local(q, ck, cv, pos, q_pos):
            o, l, m = _partial_attention(q, ck, cv, pos, q_pos, **kw)
            o, l = _merge(o, l, m, cp_axes)
            return _finish(o, l, B, Sq, num_heads, head_dim, q.dtype)

        fn = jax.shard_map(
            local,
            in_specs=(P(), P(None, cp_axes, None, None),
                      P(None, cp_axes, None, None), P(None, cp_axes), P()),
            out_specs=P(),
            check_vma=False,
        )
        return fn(q, cache_k, cache_v, cache_pos, q_pos)

    o, l, _ = _partial_attention(q, cache_k, cache_v, cache_pos, q_pos, **kw)
    return _finish(o, l, B, Sq, num_heads, head_dim, q.dtype)
