"""Deterministic fault injection for the serve engine (chaos testing).

The robustness layer in :mod:`repro.serve.engine` — admission control,
preemption under page exhaustion, numeric quarantine — is only worth
trusting if the failure paths actually run.  This module injects the
failures on purpose, deterministically, at the real boundaries:

* :class:`LogitNaN` poisons one decode step's logits for one request
  **inside the decode jit** (the engine's ``nan_mask`` input), so the
  device-side sentinel (``sampler.guard_logits``) genuinely detects it —
  the fault travels the same path a real numeric blowup would.
* :class:`KVBitFlip` XORs a mantissa bit in the victim's *private* KV
  storage (int8/int16 pools), modeling a storage upset.  The engine must
  keep draining and sibling streams must be byte-identical — pages are
  refcounted precisely so one request's corruption cannot leak.
* :class:`PageSqueeze` grabs free pages hostage
  (:meth:`PageAllocator.grab`), forcing genuine mid-decode exhaustion —
  the preemption path's trigger — and optionally releases them later.
* :class:`AdmitDelay` holds a request in the queue until a given step,
  exercising deadline expiry and queue-depth accounting.

:class:`FaultHarness` owns a fault list, fires each exactly once at its
trigger, and keeps a structured event log (JSON-serializable) that the
chaos tests and the CI chaos lane assert on and upload as an artifact.
:func:`chaos_plan` draws a reproducible random fault mix from a seed.

Every injector is a no-op when its precondition fails (victim already
finished, pool is f32, arena already dry) — it logs ``skipped`` instead
of raising, so a chaos sweep never crashes the harness itself.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["LogitNaN", "KVBitFlip", "PageSqueeze", "AdmitDelay",
           "FaultHarness", "chaos_plan"]


@dataclasses.dataclass
class LogitNaN:
    """Poison the decode logits of ``uid``'s slot once, device-side.

    Fires on the decode step where the request has generated exactly
    ``token_idx`` tokens — so tokens ``0 .. token_idx-1`` stream out
    clean and the poisoned token is the would-be ``token_idx``-th.  The
    engine's sentinel must drop it and quarantine the request FAILED.
    (``token_idx >= 1``: token 0 is sampled from prefill logits, which
    the injection mask doesn't reach.)
    """

    uid: int
    token_idx: int = 1
    fired: bool = False

    def __post_init__(self):
        if self.token_idx < 1:
            raise ValueError("token_idx must be >= 1 (token 0 comes from "
                             "prefill logits)")


@dataclasses.dataclass
class KVBitFlip:
    """XOR bit ``bit`` of one stored K mantissa of ``uid`` at ``step``.

    Only touches storage that is *privately owned* by the victim —
    slot-major rows are private by construction; paged mode picks a
    mapped page with refcount 1 (never a shared/registered prefix page,
    whose corruption would be the allocator's bug, not a fault model).
    Skips (with a logged reason) on f32 pools — there is no mantissa to
    flip — and when the victim has no written private storage yet.
    """

    step: int
    uid: int
    bit: int = 5
    fired: bool = False


@dataclasses.dataclass
class PageSqueeze:
    """Grab up to ``n_pages`` free pages at ``step``; release at
    ``release_step`` (never, if None).  Grabbed pages are allocated but
    unmapped, so the squeeze is invisible except as scarcity — the
    engine's next page demand hits genuine exhaustion and must preempt.
    """

    step: int
    n_pages: int
    release_step: Optional[int] = None
    fired: bool = False
    released: bool = False
    held: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class AdmitDelay:
    """Hold ``uid`` in the queue until engine step ``until_step``."""

    uid: int
    until_step: int
    fired: bool = False


class FaultHarness:
    """Drives a fault list against a running engine.

    The engine calls three hooks (all cheap no-ops with no pending
    faults): :meth:`on_step` at the top of every step (bit flips, page
    squeezes), :meth:`admit_ok` per queued request during admission
    (delays), and :meth:`nan_mask` before the decode jit (logit
    poisoning).  ``log`` accumulates one JSON-able dict per event.
    """

    def __init__(self, faults, seed: int = 0, tracer=None):
        self.faults = list(faults)
        self.seed = seed
        self.log: List[dict] = []
        # optional repro.obs.Tracer: every injected fault also lands as
        # an instant on the trace's "faults" track (the engine attaches
        # its tracer here when it has one)
        self.tracer = tracer

    def _event(self, kind: str, **kw) -> None:
        self.log.append({"kind": kind, **kw})
        if self.tracer is not None:
            self.tracer.instant(f"fault:{kind}", tid="faults", **kw)

    # -- engine hooks -----------------------------------------------------
    def on_step(self, eng) -> None:
        step = eng._step_idx
        for f in self.faults:
            if isinstance(f, PageSqueeze):
                if not f.fired and step >= f.step:
                    f.fired = True
                    if eng._paged:
                        f.held = eng._alloc.grab(f.n_pages)
                        self._event("page_squeeze", step=step,
                                    requested=f.n_pages, held=len(f.held))
                    else:
                        self._event("page_squeeze_skipped", step=step,
                                    reason="engine is not paged")
                if (f.fired and not f.released and f.release_step is not None
                        and step >= f.release_step):
                    f.released = True
                    eng._alloc.ungrab(f.held)
                    self._event("page_release", step=step,
                                released=len(f.held))
                    f.held = []
            elif isinstance(f, KVBitFlip):
                if not f.fired and step >= f.step:
                    f.fired = True
                    self._flip(eng, f, step)

    def admit_ok(self, uid: int, step: int) -> bool:
        for f in self.faults:
            if isinstance(f, AdmitDelay) and f.uid == uid:
                if step < f.until_step:
                    return False
                if not f.fired:
                    f.fired = True
                    self._event("admit_released", uid=uid, step=step)
        return True

    def nan_mask(self, eng) -> np.ndarray:
        mask = np.zeros(eng.max_slots, bool)
        for f in self.faults:
            if isinstance(f, LogitNaN) and not f.fired:
                s = _slot_of(eng, f.uid)
                if s is not None and eng._active[s] and \
                        len(eng._gen[s]) == f.token_idx:
                    mask[s] = True
                    f.fired = True
                    self._event("logit_nan", uid=f.uid, slot=s,
                                token_idx=f.token_idx, step=eng._step_idx)
        return mask

    # -- bit-flip mechanics ------------------------------------------------
    def _flip(self, eng, f: KVBitFlip, step: int) -> None:
        s = _slot_of(eng, f.uid)
        if s is None:
            self._event("bit_flip_skipped", uid=f.uid, step=step,
                        reason="request not in a slot")
            return
        target = self._flip_target(eng, s)
        if target is None:
            return  # _flip_target logged the reason
        entry, idx = target
        m = entry["k_m"]
        if not jnp.issubdtype(m.dtype, jnp.integer):
            self._event("bit_flip_skipped", uid=f.uid, step=step,
                        reason="f32 pool has no mantissa to flip")
            return
        width = 8 * m.dtype.itemsize
        bit = min(f.bit, width - 2)        # keep off the sign bit
        old = int(np.asarray(m[idx]))
        entry["k_m"] = m.at[idx].set(
            jnp.bitwise_xor(m[idx], jnp.asarray(1 << bit, m.dtype)))
        self._event("bit_flip", uid=f.uid, slot=s, step=step, bit=bit,
                    index=[int(i) for i in idx], old=old,
                    new=int(np.asarray(entry["k_m"][idx])))

    def _flip_target(self, eng, s: int):
        """Locate (entry, index) of one privately-owned written K row.

        Mutates the engine's pool dict in place at the entry level (the
        caller rewrites ``entry["k_m"]``), which is safe: the pool dict
        is host-side plumbing between jit calls.
        """
        pos = int(eng._pos[s])
        if pos < 1:
            self._event("bit_flip_skipped", slot=s,
                        reason="no rows written yet")
            return None
        for sc in eng._pool.values():
            for bkey, e in sc.items():
                if not isinstance(e, dict) or "k_m" not in e:
                    continue
                if "bt" in e:              # paged: newest private page
                    P = eng.page_size
                    for b in range((pos - 1) // P, -1, -1):
                        page = int(eng._alloc.bt[s][b])
                        if page == 0 or eng._alloc.rc[page] != 1:
                            continue       # unmapped or shared: hands off
                        off = min(pos - 1 - b * P, P - 1)
                        return e, (0, page, off, 0, 0)
                    self._event("bit_flip_skipped", slot=s,
                                reason="no private page mapped")
                    return None
                W = e["k_m"].shape[2]      # slot-major ring [n, B, W, K, hd]
                return e, (0, s, (pos - 1) % W, 0, 0)
        self._event("bit_flip_skipped", slot=s,
                    reason="no packed attention entry in pool")
        return None

    # -- reporting ---------------------------------------------------------
    def summary(self) -> dict:
        counts: dict = {}
        for ev in self.log:
            counts[ev["kind"]] = counts.get(ev["kind"], 0) + 1
        return {"seed": self.seed, "n_faults": len(self.faults),
                "events": list(self.log), "event_counts": counts}


def _slot_of(eng, uid: int) -> Optional[int]:
    for s, r in enumerate(eng._reqs):
        if r is not None and r.uid == uid:
            return s
    return None


def chaos_plan(seed: int, uids, *, n_steps: int = 32,
               p_nan: float = 0.25, p_flip: float = 0.25,
               p_delay: float = 0.25, squeeze_pages: int = 0) -> list:
    """Reproducible random fault mix over ``uids`` for a chaos sweep.

    Same seed → same plan (``random.Random(seed)``, no global state).
    Each uid independently draws a logit-NaN, a KV bit flip, and/or an
    admission delay; ``squeeze_pages > 0`` adds one mid-run PageSqueeze
    with a later release, so the run exercises exhaustion-preemption AND
    recovery in the same drain.
    """
    rng = random.Random(seed)
    faults: list = []
    for uid in uids:
        if rng.random() < p_nan:
            faults.append(LogitNaN(uid, token_idx=rng.randint(1, 4)))
        if rng.random() < p_flip:
            faults.append(KVBitFlip(step=rng.randint(2, max(3, n_steps // 2)),
                                    uid=uid, bit=rng.randint(0, 5)))
        if rng.random() < p_delay:
            faults.append(AdmitDelay(uid,
                                     until_step=rng.randint(2, n_steps // 2)))
    if squeeze_pages > 0:
        t = rng.randint(3, max(4, n_steps // 2))
        faults.append(PageSqueeze(step=t, n_pages=squeeze_pages,
                                  release_step=t + rng.randint(3, 8)))
    return faults
