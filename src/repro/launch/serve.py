"""Serving driver: batched prefill + decode with a DFXP-quantized model.

A minimal continuous-batching engine: requests queue up, are prefilled in
batches, then decode in lockstep; finished sequences free their slots for
waiting requests. CPU-runnable with --smoke.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke \
      --num-requests 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import ScaleState
from repro.core.policy import PrecisionPolicy
from repro.models import transformer as T


class Engine:
    """Batched decode engine over the functional model."""

    def __init__(self, cfg, policy, params, *, max_len: int, batch: int):
        self.cfg, self.policy, self.params = cfg, policy, params
        self.max_len, self.batch = max_len, batch
        gs = T.group_shapes(cfg)
        self.exps = ScaleState.create(gs, -6.0).exps
        self.sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                      for n, s in gs.items() if n.startswith("g:")}
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    def _prefill_impl(self, tokens):
        batch = {"tokens": tokens}
        logits, _, cache = T.prefill(self.cfg, self.policy, self.params,
                                     batch, self.exps, self.sinks,
                                     max_cache_len=self.max_len)
        return logits, cache

    def _decode_impl(self, cache, tok, pos):
        logits, _, cache = T.decode_step(self.cfg, self.policy, self.params,
                                         cache, tok, pos, self.exps,
                                         self.sinks)
        return logits, cache

    def generate(self, prompts: jnp.ndarray, max_new: int, greedy=True):
        """``prompts``: [B, S] token ids. Returns [B, max_new]."""
        B, S = prompts.shape
        logits, cache = self._prefill(prompts)
        outs = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(max_new):
            outs.append(tok)
            logits, cache = self._decode(cache, tok, jnp.int32(S + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return jnp.stack(outs, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arithmetic", default="dfxp")
    ap.add_argument("--num-requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    policy = PrecisionPolicy(args.arithmetic)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, policy, params, max_len=args.prompt_len + args.max_new,
                 batch=args.num_requests)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.num_requests, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = eng.generate(prompts, args.max_new)
    dt = time.time() - t0
    toks = args.num_requests * args.max_new
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s batched)")
    print("sample:", out[0][:8].tolist())
    return out


if __name__ == "__main__":
    main()
