"""Kernel dispatch: routes quantized matmuls onto the fused Pallas path.

This is the production entry point for the DFXP matmul family.  It owns
four concerns the kernels themselves stay agnostic of:

  * **differentiability** — :func:`fused_dot` wraps the forward kernel in
    a ``jax.custom_vjp`` whose backward runs two more Pallas kernels:
    dgrad (``q_g(ct) @ q(B)^T``, layout ``nt``) and wgrad
    (``q(A)^T @ q_g(ct)``, layout ``tn``), with the cotangent's DFXP
    rounding fused into the tile loads (``grad_width``), matching the
    ``qbound`` numerics;
  * **shape collapsing** — batched/ND left operands ``[..., K]`` are
    flattened to ``[M, K]`` around the kernel call (reshape is exact and
    linear, so autodiff through it is free);
  * **block selection** — shape-bucketed, with a small measured autotune
    cache: on compiled backends the first matmul in a bucket times a
    handful of candidate tilings on dummy operands and the winner is
    cached; in interpret mode (no real perf to measure) the shared
    heuristic is cached instead;
  * **backend detection** — compiled Pallas on TPU, interpret elsewhere,
    resolved once per process (``_tiling.default_interpret``).

``QTape.dot`` calls :func:`tape_dot` when the policy enables the fused
path (``PrecisionPolicy.fused_matmul``); numerics are bit-identical to
the ``ste_quant`` + ``jnp.matmul`` composite it replaces.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels._tiling import (default_interpret, mm_blocks,
                                   resolve_interpret, round_up)
from repro.kernels.qmatmul.ops import qmm

Array = jax.Array


# ---------------------------------------------------------------------------
# shape-bucketed block selection with a measured autotune cache
# ---------------------------------------------------------------------------

# Candidate (block_r, block_c, block_d) tilings tried by the autotuner,
# filtered per shape to fit the operands and a VMEM budget.
_CANDIDATES = [
    (128, 128, 128), (128, 128, 256), (128, 128, 512),
    (128, 256, 128), (256, 128, 128), (256, 256, 128),
    (128, 256, 256), (512, 128, 128), (128, 512, 128),
]
_VMEM_BUDGET = 8 * 1024 * 1024  # bytes of f32 tiles per grid step

_AUTOTUNE: Dict[str, object] = {"measure": True, "reps": 3}
_BLOCK_CACHE: Dict[tuple, Tuple[int, int, int]] = {}


def _bucket(n: int) -> int:
    """Round up to the next power of two (min 8) — the cache granularity."""
    b = 8
    while b < n:
        b *= 2
    return b


def autotune_cache() -> Dict[tuple, Tuple[int, int, int]]:
    """The live {(kind, R̂, Ĉ, D̂): blocks} cache (mutable; compiled path
    only — interpret mode always uses exact full-shape blocks)."""
    return _BLOCK_CACHE


def reset_autotune() -> None:
    _BLOCK_CACHE.clear()


def set_autotune(measure: Optional[bool] = None,
                 reps: Optional[int] = None) -> None:
    if measure is not None:
        _AUTOTUNE["measure"] = measure
    if reps is not None:
        _AUTOTUNE["reps"] = reps


def _fits(blocks, R, C, D) -> bool:
    br, bc, bd = blocks
    # reject blocks larger than the 128-aligned problem (candidates are
    # all 128-multiples, so this is "no pure-padding tiles")
    if (br > round_up(R, 128) or bc > round_up(C, 128)
            or bd > round_up(D, 128)):
        return False
    vmem = 4 * (br * bd + bd * bc + 2 * br * bc)
    return vmem <= _VMEM_BUDGET


def _measure(kind: str, R: int, C: int, D: int, width) -> tuple:
    """Time candidate tilings on dummy operands; return the fastest."""
    if kind == "nn":
        sa, sb = (R, D), (D, C)
    elif kind == "nt":
        sa, sb = (R, D), (C, D)
    else:
        sa, sb = (D, R), (D, C)
    a = jnp.zeros(sa, jnp.float32)
    b = jnp.zeros(sb, jnp.float32)
    e = jnp.float32(0.0)
    best, best_t = None, float("inf")
    reps = max(1, int(_AUTOTUNE["reps"]))
    cands = [c for c in _CANDIDATES if _fits(c, R, C, D)]
    if not cands:
        cands = [mm_blocks(kind, R, C, D)]
    for blocks in cands:
        fn = lambda: qmm(a, b, e, e, kind=kind, width_a=width,
                         width_b=width, blocks=blocks, interpret=False)
        try:
            jax.block_until_ready(fn())  # compile
        except Exception:  # tiling rejected by the compiler — skip
            continue
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        t = time.perf_counter() - t0
        if t < best_t:
            best, best_t = blocks, t
    return best or mm_blocks(kind, R, C, D)


def blocks_for(kind: str, R: int, C: int, D: int, *, interpret: bool,
               width=10) -> tuple:
    """Cached block choice for a shape bucket (measured on compiled TPU).

    In interpret mode the blocks are the exact operand dims (one grid
    step, zero padding): the kernel body then executes literally the
    composite's dot on the composite's shapes, which is what makes the
    fused path *bit*-identical to the jnp composite — f32 accumulation
    order on CPU backends depends on operand shapes, so padding or
    splitting the reduction would drift ULPs on raw (straight-through)
    operands.  Compiled TPU tilings come from the measured autotune
    cache instead; there the MXU accumulation contract is the spec.
    """
    if interpret:
        return R, C, D
    key = (kind, _bucket(R), _bucket(C), _bucket(D))
    blocks = _BLOCK_CACHE.get(key)
    if blocks is None:
        if _AUTOTUNE["measure"]:
            blocks = _measure(kind, key[1], key[2], key[3], width)
        else:
            blocks = mm_blocks(kind, R, C, D)
        _BLOCK_CACHE[key] = blocks
    return blocks


# ---------------------------------------------------------------------------
# differentiable fused matmul
# ---------------------------------------------------------------------------

def _qmm_auto(a, b, e_a, e_b, *, kind, width_a, width_b, cast, out_dtype,
              interpret):
    """qmm with dispatch-selected blocks for the (collapsed) 2D shapes."""
    if kind == "nn":
        (R, D), C = a.shape, b.shape[1]
    elif kind == "nt":
        (R, D), C = a.shape, b.shape[0]
    else:
        (D, R), C = a.shape, b.shape[1]
    blocks = blocks_for(kind, R, C, D, interpret=interpret,
                        width=width_a or width_b)
    return qmm(a, b, e_a, e_b, kind=kind, width_a=width_a, width_b=width_b,
               blocks=blocks, cast=cast, out_dtype=out_dtype,
               interpret=interpret)


@functools.lru_cache(maxsize=None)
def _make_fused(width_a, width_b, grad_width, transpose_b: bool,
                cast, interpret: bool):
    """Build the custom-VJP fused matmul for one static configuration.

    Forward: ``q(a) @ q(b)`` (or ``q(a) @ q(b)^T`` with ``transpose_b``),
    each quantization optional (``width=None`` → raw operand, matching
    the straight-through composite).  Backward (STE through the operand
    rounding, quantized co-operands):

        da = q_g(ct) @ q(b)[^T]          db = q(a)^T @ q_g(ct)

    with ``q_g`` the optional ``grad_width`` cotangent rounding.
    """
    fwd_kind = "nt" if transpose_b else "nn"

    def _forward(a, b, e_a, e_b):
        return _qmm_auto(a, b, e_a, e_b, kind=fwd_kind, width_a=width_a,
                         width_b=width_b, cast=cast, out_dtype=a.dtype,
                         interpret=interpret)

    @jax.custom_vjp
    def fused(a, b, e_a, e_b, e_g):
        del e_g
        return _forward(a, b, e_a, e_b)

    def fwd(a, b, e_a, e_b, e_g):
        return _forward(a, b, e_a, e_b), (a, b, e_a, e_b, e_g)

    def bwd(res, ct):
        a, b, e_a, e_b, e_g = res
        if transpose_b:
            # y[M,V] = qa[M,D] @ qb[V,D]^T
            da = _qmm_auto(ct, b, e_g, e_b, kind="nn", width_a=grad_width,
                           width_b=width_b, cast=cast, out_dtype=a.dtype,
                           interpret=interpret)
            db = _qmm_auto(ct, a, e_g, e_a, kind="tn", width_a=grad_width,
                           width_b=width_a, cast=cast, out_dtype=b.dtype,
                           interpret=interpret)
        else:
            # y[M,N] = qa[M,K] @ qb[K,N]
            da = _qmm_auto(ct, b, e_g, e_b, kind="nt", width_a=grad_width,
                           width_b=width_b, cast=cast, out_dtype=a.dtype,
                           interpret=interpret)
            db = _qmm_auto(a, ct, e_a, e_g, kind="tn", width_a=width_a,
                           width_b=grad_width, cast=cast, out_dtype=b.dtype,
                           interpret=interpret)
        return (da, db, jnp.zeros_like(e_a), jnp.zeros_like(e_b),
                jnp.zeros_like(e_g))

    fused.defvjp(fwd, bwd)
    return fused


def fused_dot(a, b, e_a, e_b, *, width: int, grad_width: Optional[int] = None,
              e_g=0.0, quant_a: bool = True, quant_b: bool = True,
              transpose_b: bool = False, cast=jnp.float32,
              interpret: Optional[bool] = None) -> Array:
    """Differentiable fused DFXP matmul ``q(a) @ q(b)[^T]``.

    ``a``: [..., K] (leading dims collapsed around the kernel), ``b``:
    [K, N] (or [N, K] with ``transpose_b``).  ``grad_width`` enables the
    fused cotangent rounding (exponent ``e_g``) in both backward kernels;
    ``quant_a=False`` / ``quant_b=False`` pass that operand through raw —
    the straight-through composite contract used by ``QTape.dot``.
    """
    interpret = resolve_interpret(interpret)
    f = _make_fused(width if quant_a else None, width if quant_b else None,
                    grad_width, transpose_b, cast, interpret)
    e_a = jnp.asarray(e_a, jnp.float32)
    e_b = jnp.asarray(e_b, jnp.float32)
    e_g = jnp.asarray(e_g, jnp.float32)
    lead = a.shape[:-1]
    a2 = a.reshape(-1, a.shape[-1]) if a.ndim != 2 else a
    y = f(a2, b, e_a, e_b, e_g)
    return y.reshape(*lead, y.shape[-1]) if a.ndim != 2 else y


def tape_dot(x, w, e_w, *, width: int, transpose_b: bool = False,
             interpret: Optional[bool] = None) -> Array:
    """The ``QTape.dot`` fused path: raw activations × quantized weight.

    Bit-identical to the composite ``jnp.matmul(x, ste_quant(w))`` — the
    activation operand and the backward cotangent are *not* re-rounded
    here (the surrounding ``tape.act`` sites already hold them on the
    DFXP grid), and the weight gradient passes straight through, exactly
    like ``ste_quant``'s identity backward.
    """
    return fused_dot(x, w, 0.0, e_w, width=width, quant_a=False,
                     transpose_b=transpose_b, cast=x.dtype,
                     interpret=interpret)


__all__ = ["fused_dot", "tape_dot", "blocks_for", "autotune_cache",
           "reset_autotune", "set_autotune", "default_interpret"]
