"""Mixture-of-Experts FFN with expert-parallel shard_map dispatch.

Routing is capacity-based (Switch/GShard style): each token's top-k experts
get it unless the expert's local capacity ``C = ceil(T·k/E · cf)`` is
exhausted. Dispatch/combine are scatter/gather (cheap) rather than one-hot
einsums (dense FLOPs).

Under a mesh, the block is a ``shard_map`` island inside the jit program:
tokens stay sharded over the data axes, experts are sharded over ``ep_axis``
(the model axis), and two ``all_to_all``s move token slots to expert owners
and back — the standard EP pattern, visible as such in the dry-run HLO.
Expert weights are additionally FSDP-sharded over ``fsdp_axis`` and
``all_gather``-ed per layer (needed to fit 400B-class models).

DFXP: dispatched activations, expert hidden, and expert outputs are
quantization sites; router logits/softmax stay wide (documented deviation —
routing decisions are precision-sensitive and the paper predates MoE).
"""
from __future__ import annotations

import dataclasses
import math
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.tape import QTape
from repro.dist.context import DistCtx

from .layers import init_dense, init_swiglu, swiglu

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int                      # per-expert hidden dim
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    shared_expert_d_ff: int = 0    # 0 = no shared expert (llama4 uses one)
    renormalize: bool = True


def init_moe(key, spec: MoESpec) -> dict:
    ks = jax.random.split(key, 5)
    E, D, F = spec.num_experts, spec.d_model, spec.d_ff
    p = {
        "router": init_dense(ks[0], D, E, scale=0.02),
        "w_gate": jax.random.normal(ks[1], (E, D, F)) / math.sqrt(D),
        "w_up": jax.random.normal(ks[2], (E, D, F)) / math.sqrt(D),
        "w_down": jax.random.normal(ks[3], (E, F, D)) / math.sqrt(F),
    }
    if spec.shared_expert_d_ff:
        p["shared"] = init_swiglu(ks[4], D, spec.shared_expert_d_ff)
    return p


def _capacity(t_local: int, spec: MoESpec, dropless: bool = False) -> int:
    if dropless:
        # decode batches are tiny: full capacity costs nothing and keeps
        # decode bit-exact w.r.t. the full forward (no token dropping)
        return t_local
    return max(1, math.ceil(t_local * spec.top_k / spec.num_experts
                            * spec.capacity_factor))


def _moe_local(x, router_w, w_gate, w_up, w_down, scales, sinks,
               *, spec: MoESpec, policy, dist: DistCtx, prefix: str,
               t_local: int, dropless: bool = False):
    """Per-device MoE math. ``x``: [T_local, D] local tokens."""
    tape = QTape(policy, scales, sinks)
    E, k = spec.num_experts, spec.top_k
    C = _capacity(t_local, spec, dropless)
    T = x.shape[0]

    # --- routing (wide precision: documented deviation) -------------------
    logits = jnp.einsum("td,de->te", x, router_w,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                    # [T, k]
    if spec.renormalize:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    eid = ids.reshape(-1)                                   # [T*k]
    gate = gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), k)

    onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)        # [T*k, E]
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                              eid[:, None], axis=1)[:, 0]   # rank within expert
    keep = pos < C
    pos_c = jnp.minimum(pos, C - 1)

    # --- dispatch: scatter token slots to [E, C, D] ------------------------
    contrib = jnp.where(keep[:, None], x[tok], 0.0)
    xe = jnp.zeros((E, C, x.shape[1]), x.dtype).at[eid, pos_c].add(contrib)
    xe = tape.act(f"{prefix}/dispatch", xe)

    a2a_bits = getattr(policy, "a2a_compress_bits", 0)
    if dist.ep_axis:
        if a2a_bits:
            from repro.dist.compress import compressed_all_to_all
            e_disp = tape._exp(f"a:{prefix}/dispatch")
            xe = compressed_all_to_all(xe, e_disp, a2a_bits, dist.ep_axis,
                                       split_axis=0, concat_axis=1)
        else:
            xe = jax.lax.all_to_all(xe, dist.ep_axis, split_axis=0,
                                    concat_axis=1, tiled=True)  # [E/ep, C*ep, D]

    # --- expert compute ------------------------------------------------------
    stationary = dist.moe_stationary and dist.fsdp_axis and dropless
    if dist.fsdp_axis and not stationary:
        # training: gather FSDP-sharded weights per layer (tokens are huge,
        # weights amortize). [E/ep, D/fsdp, F] → [E/ep, D, F]; w_down is
        # [E/ep, F, D/fsdp].
        w_gate = jax.lax.all_gather(w_gate, dist.fsdp_axis, axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up, dist.fsdp_axis, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, dist.fsdp_axis, axis=2, tiled=True)
    w_gate = tape.weight(f"{prefix}/w_gate", w_gate)
    w_up = tape.weight(f"{prefix}/w_up", w_up)
    w_down = tape.weight(f"{prefix}/w_down", w_down)

    if stationary:
        # decode: weights stay put, activations move (the classic inference
        # trick — a 400B expert bank must not cross ICI per token). Each
        # fsdp rank holds a D-slice: partial matmuls + psum(h), then the
        # D-sharded down-proj output is all-gathered (activation-sized).
        didx = jax.lax.axis_index(dist.fsdp_axis)
        Dl = w_gate.shape[1]
        xe_l = jax.lax.dynamic_slice_in_dim(xe, didx * Dl, Dl, axis=2)
        g = jnp.einsum("ecd,edf->ecf", xe_l, w_gate,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", xe_l, w_up,
                       preferred_element_type=jnp.float32)
        g = jax.lax.psum(g, dist.fsdp_axis)
        u = jax.lax.psum(u, dist.fsdp_axis)
        h = tape.act(f"{prefix}/pre",
                     (jax.nn.silu(g) * u).astype(x.dtype))
        ye = jnp.einsum("ecf,efd->ecd", h, w_down,
                        preferred_element_type=jnp.float32).astype(x.dtype)
        ye = jax.lax.all_gather(ye, dist.fsdp_axis, axis=2, tiled=True)
    else:
        g = jnp.einsum("ecd,edf->ecf", xe, w_gate,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        u = jnp.einsum("ecd,edf->ecf", xe, w_up,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        h = tape.act(f"{prefix}/pre", jax.nn.silu(g) * u)
        ye = jnp.einsum("ecf,efd->ecd", h, w_down,
                        preferred_element_type=jnp.float32).astype(x.dtype)

    if dist.ep_axis:
        if a2a_bits:
            from repro.dist.compress import compressed_all_to_all
            e_out = tape._exp(f"a:{prefix}/expert_out")
            ye = compressed_all_to_all(ye, e_out, a2a_bits, dist.ep_axis,
                                       split_axis=1, concat_axis=0)
        else:
            ye = jax.lax.all_to_all(ye, dist.ep_axis, split_axis=1,
                                    concat_axis=0, tiled=True)  # [E, C, D]
    ye = tape.act(f"{prefix}/expert_out", ye)

    # --- combine -----------------------------------------------------------
    picked = ye[eid, pos_c] * (gate * keep)[:, None].astype(ye.dtype)
    y = jnp.zeros_like(x).at[tok].add(picked)

    stats = tape.stats
    if dist.active:
        stats = {n: jax.lax.psum(s, dist.all_axes) for n, s in stats.items()}
    return y, stats


def moe_ffn(params, spec: MoESpec, x: Array, tape: QTape, prefix: str,
            dist: DistCtx = DistCtx(), dropless: bool = False) -> Array:
    """MoE block. ``x``: [B, S, D]. Merges local stats into ``tape``."""
    B, S, D = x.shape
    x_flat = x.reshape(B * S, D)
    n_tok_shards = 1
    scales, sinks = tape.scales, tape.sinks

    if dist.active:
        import numpy as np
        mesh = jax.sharding.get_abstract_mesh()
        n_tok_shards = int(np.prod([mesh.shape[a] for a in dist.token_axes]))
        t_local = (B * S) // n_tok_shards
        fn = jax.shard_map(
            lambda xf, rw, wg, wu, wd, sc, sk: _moe_local(
                xf, rw, wg, wu, wd, sc, sk, spec=spec, policy=tape.policy,
                dist=dist, prefix=prefix, t_local=t_local,
                dropless=dropless),
            in_specs=(P(dist.token_axes, None), P(), P(dist.ep_axis, dist.fsdp_axis, None),
                      P(dist.ep_axis, dist.fsdp_axis, None),
                      P(dist.ep_axis, None, dist.fsdp_axis), P(), P()),
            out_specs=(P(dist.token_axes, None), P()),
            check_vma=False,
        )
        y, stats = fn(x_flat, params["router"], params["w_gate"],
                      params["w_up"], params["w_down"], scales, sinks)
    else:
        y, stats = _moe_local(
            x_flat, params["router"], params["w_gate"], params["w_up"],
            params["w_down"], scales, sinks, spec=spec, policy=tape.policy,
            dist=dist, prefix=prefix, t_local=B * S, dropless=dropless)

    for n, s in stats.items():
        tape._record(n, s)

    y = y.reshape(B, S, D)
    if spec.shared_expert_d_ff:
        y = y + swiglu(params["shared"], x, tape, f"{prefix}/shared")
    return tape.act(f"{prefix}/out", y)
