"""Paper-faithful Maxout networks (paper §2, §8; Goodfellow et al. 2013a).

Two model shapes, as in the paper:
  * permutation-invariant MLP — maxout hidden layers on flat inputs
    (paper's PI-MNIST model: 2 maxout layers + softmax),
  * convolutional maxout — conv layers whose channels are maxed over k
    pieces, with spatial max pooling, + dense softmax (MNIST/CIFAR10/SVHN).

Regularization follows the paper: dropout (input + hidden) and a max-norm
constraint on each weight column (Srebro & Shraibman 2005), the latter
applied in the optimizer (`repro.optim.apply_max_norm`). The training
recipe (SGD, linearly decaying LR, linearly saturating momentum) lives in
`repro.optim.schedules`.

Every weighted sum/output is a DFXP quantization site — these are exactly
the paper's per-layer groups (weights, biases, weighted sums, outputs and
their gradients).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.core.tape import QTape

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MaxoutConfig:
    name: str = "maxout_pi"
    input_dim: int = 784             # flat input (PI) or C*H*W (conv)
    image_shape: Tuple[int, int, int] = (1, 28, 28)   # (C, H, W), conv only
    num_classes: int = 10
    hidden: Tuple[int, ...] = (240, 240)
    pieces: int = 5                  # k linear pieces per maxout unit
    conv: bool = False
    conv_channels: Tuple[int, ...] = (48, 48, 24)
    conv_kernel: int = 5
    pool: int = 2
    dropout_input: float = 0.2
    dropout_hidden: float = 0.5
    max_col_norm: float = 1.9365     # pylearn2 default used by the paper


def init_params(cfg: MaxoutConfig, key) -> dict:
    params = {}
    if cfg.conv:
        C = cfg.image_shape[0]
        for i, ch in enumerate(cfg.conv_channels):
            key, k = jax.random.split(key)
            fan_in = C * cfg.conv_kernel ** 2
            params[f"conv{i}"] = {
                "w": jax.random.normal(
                    k, (cfg.pieces * ch, C, cfg.conv_kernel, cfg.conv_kernel),
                    jnp.float32) / math.sqrt(fan_in),
                "b": jnp.zeros((cfg.pieces * ch,), jnp.float32),
            }
            C = ch
        key, k = jax.random.split(key)
        feat = _conv_out_dim(cfg)
        params["out"] = {
            "w": jax.random.normal(k, (feat, cfg.num_classes), jnp.float32)
            / math.sqrt(feat),
            "b": jnp.zeros((cfg.num_classes,), jnp.float32),
        }
    else:
        d = cfg.input_dim
        for i, h in enumerate(cfg.hidden):
            key, k = jax.random.split(key)
            params[f"fc{i}"] = {
                "w": jax.random.normal(k, (d, cfg.pieces * h), jnp.float32)
                / math.sqrt(d),
                "b": jnp.zeros((cfg.pieces * h,), jnp.float32),
            }
            d = h
        key, k = jax.random.split(key)
        params["out"] = {
            "w": jax.random.normal(k, (d, cfg.num_classes), jnp.float32)
            / math.sqrt(d),
            "b": jnp.zeros((cfg.num_classes,), jnp.float32),
        }
    return params


def _conv_out_dim(cfg: MaxoutConfig) -> int:
    _, H, W = cfg.image_shape
    for _ in cfg.conv_channels:
        H, W = H // cfg.pool, W // cfg.pool
    return cfg.conv_channels[-1] * H * W


def group_shapes(cfg: MaxoutConfig) -> dict:
    groups = {}
    names = ([f"conv{i}" for i in range(len(cfg.conv_channels))]
             if cfg.conv else [f"fc{i}" for i in range(len(cfg.hidden))])
    for n in names + ["out"]:
        groups[f"w:{n}/w"] = ()
        for s in ("pre", "act"):
            groups[f"a:{n}/{s}"] = ()
            groups[f"g:{n}/{s}"] = ()
    return groups


def _dropout(x, rate, key):
    if key is None or rate == 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def forward(cfg: MaxoutConfig, policy: PrecisionPolicy, params, x: Array,
            scales, sinks, *, rng: Optional[Array] = None):
    """``x``: [B, input_dim] (PI) or [B, C, H, W] (conv). rng=None → eval."""
    tape = QTape(policy, scales, sinks)
    if rng is not None:
        rng, k = jax.random.split(rng)
        x = _dropout(x, cfg.dropout_input, k)

    if cfg.conv:
        for i, ch in enumerate(cfg.conv_channels):
            p = params[f"conv{i}"]
            w = tape.weight(f"conv{i}/w", p["w"])
            z = jax.lax.conv_general_dilated(
                x, w, window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NCHW", "OIHW", "NCHW"))
            z = z + p["b"][None, :, None, None]
            z = tape.act(f"conv{i}/pre", z)
            B, _, H, W = z.shape
            z = z.reshape(B, cfg.pieces, ch, H, W).max(axis=1)  # maxout
            z = jax.lax.reduce_window(
                z, -jnp.inf, jax.lax.max,
                (1, 1, cfg.pool, cfg.pool), (1, 1, cfg.pool, cfg.pool),
                "VALID")
            z = tape.act(f"conv{i}/act", z)
            if rng is not None:
                rng, k = jax.random.split(rng)
                z = _dropout(z, cfg.dropout_hidden, k)
            x = z
        x = x.reshape(x.shape[0], -1)
    else:
        for i, h in enumerate(cfg.hidden):
            p = params[f"fc{i}"]
            z = tape.dot(f"fc{i}/w", x, p["w"]) + p["b"]
            z = tape.act(f"fc{i}/pre", z)
            z = z.reshape(z.shape[0], cfg.pieces, h).max(axis=1)   # maxout
            z = tape.act(f"fc{i}/act", z)
            if rng is not None:
                rng, k = jax.random.split(rng)
                z = _dropout(z, cfg.dropout_hidden, k)
            x = z

    p = params["out"]
    logits = tape.dot("out/w", x, p["w"]) + p["b"]
    logits = tape.act("out/pre", logits)
    return logits, tape.stats


def loss_fn(cfg, policy, params, batch, scales, sinks, rng=None):
    logits, stats = forward(cfg, policy, params, batch["x"], scales, sinks,
                            rng=rng)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    return -ll.mean(), stats


def accuracy(cfg, policy, params, batch, scales, sinks) -> Array:
    logits, _ = forward(cfg, policy, params, batch["x"], scales, sinks)
    return (jnp.argmax(logits, -1) == batch["y"]).mean()
