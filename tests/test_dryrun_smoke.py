"""Dry-run plumbing smoke test on a small forced-device mesh (subprocess so
the main pytest process keeps 1 device), plus hlo_cost parser checks."""
import subprocess
import sys
import textwrap

import pytest

from benchmarks.hlo_cost import analyze_text

HLO_SAMPLE = textwrap.dedent("""\
    HloModule test

    %body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
      %w = f32[8,8]{1,0} constant({...})
      %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,8]) tuple(%ni, %d)
    }

    %cond.2 (p2: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%i2, %n), direction=LT
    }

    ENTRY %main.3 (a: f32[8,8]) -> f32[8,8] {
      %a = f32[8,8]{1,0} parameter(0)
      %z = s32[] constant(0)
      %tup = (s32[], f32[8,8]) tuple(%z, %a)
      %w2 = (s32[], f32[8,8]) while(%tup), condition=%cond.2, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %out = f32[8,8]{1,0} get-tuple-element(%w2), index=1
    }
    """)


def test_hlo_cost_counts_loop_trips():
    r = analyze_text(HLO_SAMPLE)
    # one 8x8x8 dot (1024 flops) × 5 trips
    assert r["flops"] == 5 * 2 * 8 * 8 * 8, r


def test_hlo_cost_collectives():
    txt = HLO_SAMPLE.replace(
        "%d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, "
        "rhs_contracting_dims={0}",
        "%d = f32[8,8]{1,0} all-reduce(%x), to_apply=%cond.2")
    r = analyze_text(txt)
    assert r["collective_bytes"] == 5 * 2 * 8 * 8 * 4  # 2x operand × trips
    assert r["collective_by_kind"]["all-reduce"] > 0


@pytest.mark.multidevice
@pytest.mark.slow
def test_minimesh_lower_compile_trainstep():
    """The full dry-run stack (rules, specs, train step) on a 2×4 mesh."""
    script = """
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.configs.shapes import ShapeSpec, input_specs
        from repro.core.policy import PrecisionPolicy
        from repro.dist.context import DistCtx
        from repro.dist.sharding import ShardingRules
        from repro.models import transformer as T
        from repro.optim.opt import OptConfig, sgd_init
        from repro.train import init_train_state, make_train_step
        from jax.sharding import AxisType

        cfg = configs.get_smoke('granite_moe_1b')
        mesh = jax.make_mesh((2, 4), ('data', 'model'),
                             axis_types=(AxisType.Auto,)*2)
        dist = DistCtx(token_axes=('data',), ep_axis='model',
                       fsdp_axis='data', cp_axis='data',
                       all_axes=('data', 'model'))
        pol = PrecisionPolicy('dfxp', comp_width=10, update_width=12)
        gs = T.group_shapes(cfg)
        opt = OptConfig(kind='sgd', lr=0.01, lr_decay_steps=100)

        def loss_fn(p, b, s, exps):
            return T.loss_fn(cfg, pol, p, b, exps, s, dist=dist,
                             remat='full', ce_chunk=16)

        step = make_train_step(loss_fn, gs, pol, opt, microbatches=2)
        def make_state():
            params = T.init_params(cfg, jax.random.PRNGKey(0))
            return init_train_state(params, sgd_init(params), gs, pol,
                                    init_exp=-8.0)
        state_shape = jax.eval_shape(make_state)
        rules = ShardingRules(mesh)
        state_sh = rules.state_shardings(state_shape)
        batch = {'tokens': jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 'labels': jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        batch_sh = rules.batch_shardings(batch)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        with jax.set_mesh(mesh):
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh, None),
                              out_shardings=(state_sh, None)).lower(
                state_shape, batch, rng)
            compiled = lowered.compile()
        txt = compiled.as_text()
        assert 'all-to-all' in txt or 'all-reduce' in txt
        from benchmarks.hlo_cost import analyze_text
        r = analyze_text(txt)
        assert r['flops'] > 0 and r['traffic_bytes'] > 0
        print('MINIMESH OK', int(r['flops']))
    """
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"STDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    assert "MINIMESH OK" in res.stdout
