"""``repro.obs`` — dependency-free tracing, metrics, and numeric health.

Three stdlib-only layers threaded through serve, kernels, and train:

* :mod:`repro.obs.trace` — :class:`Tracer` span/instant/counter events →
  Chrome-trace/Perfetto JSON (``launch.serve --trace-out``);
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` of counters,
  gauges, and log-bucketed histograms, with JSONL snapshots and a
  Prometheus-text endpoint (``--metrics-port``);
* :mod:`repro.obs.numerics` — the §5 controller's exponent/overflow
  timeline as JSONL (``--numerics-log``), serve- and train-side.

Every hook in the stack is zero-cost when disabled: call sites hold
``None`` and guard with one attribute check — no device syncs, no extra
per-token host work, token streams bit-identical with obs off.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      start_http_server)
from .numerics import (NumericsLog, count_moves, read_jsonl, serve_records,
                       train_records)
from .trace import Tracer, validate_trace

__all__ = [
    "Tracer", "validate_trace",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "start_http_server",
    "NumericsLog", "serve_records", "train_records", "count_moves",
    "read_jsonl",
]
