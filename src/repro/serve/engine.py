"""Continuous-batching decode engine over a fixed slot array.

Replaces the lockstep loop (prefill a batch, decode everyone for exactly
``max_new`` steps) with a real request lifecycle:

  queued → admitted into a free slot (prefill) → decoding at its own
  position → finished (EOS or its own ``max_new``) → slot freed →
  next queued request admitted **mid-decode**.

Every device computation is fixed-shape and jitted once per shape:

* ``_decode`` runs over all ``max_slots`` rows each step — per-slot
  position vector (``transformer.decode_step`` with ``pos: [B]``),
  per-slot PRNG streams, one compile for the engine's lifetime.  Free
  slots decode garbage into their own cache rows; row independence means
  active slots are unaffected, and admission overwrites the row anyway.
* ``_prefill`` (whole-prompt mode, ``prefill_chunk=0``) compiles per
  ``(group_size, prompt_len)``: admission groups queued requests of
  equal prompt length into one batch, so a burst of same-length requests
  costs one prefill — and an engine admitting B equal-length prompts
  into B free slots reproduces the lockstep engine's prefill bit-for-bit
  (the equivalence test's anchor).  Variable-length prompts prefill as
  separate length groups, never padded — padding would perturb MoE
  capacity routing and SSM state.  MoE models admit one request per
  prefill for the same reason: expert capacity is computed over the
  whole prefill batch, and the engine guarantees a request's tokens
  don't depend on who it shares with.
* ``_insert`` scatters the fresh cache entry into pool rows (axis 1) and,
  in packed mode, quantizes it first (``kv_pool.PackedKVCodec``).
* ``_chunk`` (**chunked-prefill mode**, ``prefill_chunk=C > 0``,
  attention-family models): any queued request is admitted into any free
  slot immediately, and each engine step runs ONE fixed-size prefill
  chunk for the oldest prefilling slot, interleaved with the decode
  batch.  The chunk jit slices the slot out of the pool (traced slot
  index, donated pool), runs ``transformer.prefill_chunk_step`` — the
  chunk attends its slot's already-written history straight off the
  packed storage (``codec.fused_prefill``, the flash-prefill kernel)
  and writes its K/V back as int mantissas (``codec.append_chunk``,
  quantize-on-write; no f32 K/V materializes in either direction) —
  and scatters the slot back.  Compile count is ONE for the engine's
  lifetime regardless of prompt lengths (ragged tails are masked
  in-kernel), and TTFT no longer waits for a same-length partner.
  While a slot is mid-prefill the decode batch's append is masked off
  for it (``append_mask``), so its pool row and controller state stay
  byte-identical to a solo run.  Whole-prompt mode remains the
  bit-for-bit reference path.

The KV pool stores K/V float32 (bit-identical to ``transformer.init_cache``)
or as DFXP-packed int8/int16 mantissas with controller-managed per-slot
exponents (``cache_bits=8|16``) — halving/quartering cache HBM and hence
multiplying concurrent slot capacity.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ScaleState
from repro.core.policy import PrecisionPolicy
from repro.models import layers as L
from repro.models import transformer as T

from . import kv_pool, metrics, paged, sampler

Array = jax.Array


@dataclasses.dataclass
class Request:
    """One generation request. ``tokens``: 1-D prompt ids."""

    uid: int
    tokens: np.ndarray
    max_new: int = 16
    eos_id: Optional[int] = None


class ServeEngine:
    """Continuous-batching engine over ``max_slots`` concurrent sequences.

    Parameters
    ----------
    cfg, policy, params: the functional model triple.
    max_slots: concurrent sequences (the decode batch shape).
    max_len: per-slot KV capacity; every request needs
        ``prompt_len + max_new <= max_len``.
    cache_bits: 0 → float32 KV pool (bit-identical to the lockstep
        engine); 8/16 → DFXP-packed mantissa pool.  With
        ``policy.fused_decode`` the decode attention runs as the fused
        Pallas flash-decode kernel straight on the pool's storage
        (packed mantissas dequantized in the tile loads — no per-layer
        f32 K/V materialization on the hot path).
    sampler_cfg: greedy / temperature / top-k, per-request PRNG streams.
    cache_cfg: overrides the packed pool's controller settings.
    prefill_chunk: chunk size ``C`` for chunked prefill (see module
        docstring); ``None`` takes ``policy.prefill_chunk``, 0 keeps the
        whole-prompt reference path.  Attention-family models only — MoE
        keeps the solo whole-prompt carve-out (batch-coupled expert
        capacity) and SSM/hybrid carry recurrent state across the
        prompt; both silently stay on the whole-prompt path.
    page_size: ``P > 0`` switches the KV pool to **paged** storage
        (:mod:`repro.serve.paged`): fixed-size pages + per-request block
        tables, refcounted prompt-prefix sharing with copy-on-write, and
        page-granular DFXP exponents.  Forces chunked prefill (``C``
        defaults to ``P``) and requires the dense attention family with
        global (non-windowed) attention; ``None``/0 takes
        ``policy.page_size``.  Prefix sharing is disabled under
        stochastic rounding (a shared page cannot replay two requests'
        PRNG streams) — pages and copy-on-write still apply.
    n_pages: paged-pool page budget (default: full residency — every
        slot can map its whole ``max_len`` — plus the null page).  A
        smaller budget recycles freed/evicted pages and raises
        ``RuntimeError`` on exhaustion.
    """

    def __init__(self, cfg: T.ModelConfig, policy: PrecisionPolicy, params,
                 *, max_slots: int, max_len: int, cache_bits: int = 0,
                 sampler_cfg: sampler.SamplerConfig = sampler.SamplerConfig(),
                 cache_cfg: Optional[kv_pool.CacheQuantConfig] = None,
                 seed: int = 0, init_exp: float = -6.0,
                 prefill_chunk: Optional[int] = None,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None):
        if cfg.input_mode != "tokens" or cfg.encoder_layers:
            raise ValueError("ServeEngine serves token-in decoder models")
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.cfg, self.policy, self.params = cfg, policy, params
        self.max_slots, self.max_len = max_slots, max_len
        self.sampler_cfg = sampler_cfg
        self.seed = seed
        gs = T.group_shapes(cfg)
        self.exps = ScaleState.create(gs, init_exp).exps
        self.sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                      for n, s in gs.items() if n.startswith("g:")}

        fused = bool(getattr(policy, "fused_decode", False))
        psize = page_size if page_size is not None else \
            int(getattr(policy, "page_size", 0))
        self.page_size = int(psize) if psize else 0
        self._paged = bool(self.page_size)
        if cache_bits:
            self.cache_cfg = cache_cfg or kv_pool.CacheQuantConfig(
                width=cache_bits)
            if self.cache_cfg.width != cache_bits:
                raise ValueError("cache_bits and cache_cfg.width disagree")
            if self._paged:
                self.codec = paged.PagedKVCodec(self.page_size,
                                                self.cache_cfg,
                                                fused_decode=fused)
            else:
                self.codec = kv_pool.PackedKVCodec(self.cache_cfg,
                                                   fused_decode=fused)
        else:
            # f32 pool; with --fused-decode the raw codec still routes
            # attention through the flash-decode kernel (width=None)
            self.cache_cfg = None
            if self._paged:
                # paged f32 still needs the paged codec: attention must
                # gather history through the block table either way
                self.codec = paged.PagedKVCodec(self.page_size, None,
                                                fused_decode=fused)
            else:
                self.codec = L.RawKVCodec(fused_decode=True) if fused \
                    else None
        self._packed = bool(cache_bits)
        if self._paged:
            if (cfg.family != "dense" or cfg.num_experts
                    or cfg.encoder_layers):
                raise ValueError(
                    "paged KV pool requires the dense attention family "
                    "(chunked prefill writes pages incrementally)")
            self._pool = paged.make_paged_pool(cfg, max_slots, max_len,
                                               self.codec, n_pages=n_pages)
            nblocks = -(-max_len // self.page_size)
            total_pages = n_pages if n_pages is not None else \
                1 + max_slots * nblocks
            self._alloc = paged.PageAllocator(total_pages, self.page_size,
                                              nblocks)
            # a shared page cannot replay two requests' stochastic PRNG
            # chains — sharing off, COW/paging still on
            self._share_prefix = not (self._packed
                                      and self.cache_cfg.stochastic)
            self._reset_slot = jax.jit(paged.reset_slot,
                                       donate_argnums=(0,))
            self._cow = jax.jit(paged.cow_page, donate_argnums=(0,))
            self._set_block = jax.jit(paged.set_block, donate_argnums=(0,))
        else:
            self._pool = kv_pool.make_pool(
                cfg, max_slots, max_len,
                self.codec if self._packed else None)

        # per-slot host state
        B = max_slots
        self._tok = np.zeros(B, np.int32)
        self._pos = np.zeros(B, np.int32)
        self._active = np.zeros(B, bool)
        self._reqs: List[Optional[Request]] = [None] * B
        self._gen: List[List[int]] = [[] for _ in range(B)]
        self._keys = np.zeros((B, 2), np.uint32)
        self._queue: collections.deque = collections.deque()
        self._results: Dict[int, np.ndarray] = {}
        self._next_uid = 0
        self._ovf = np.zeros(3, np.float64)   # harvested at request finish
        self.metrics = metrics.ServeMetrics()

        # chunked prefill: attention-family only (MoE capacity and SSM
        # state couple a whole prompt; they keep the whole-prompt path)
        pc = prefill_chunk if prefill_chunk is not None else \
            int(getattr(policy, "prefill_chunk", 0))
        if self._paged and not pc:
            pc = self.page_size   # paged mode always prefills in chunks
        chunkable = (cfg.family == "dense" and not cfg.num_experts
                     and not cfg.encoder_layers)
        self.prefill_chunk = pc if (pc and chunkable) else 0
        self._pfill = np.zeros(B, np.int32)       # prefill frontier per slot
        self._pstarted = np.zeros(B, bool)        # paged: block table mapped
        self._prefilling: collections.deque = collections.deque()  # slot FIFO

        # the pool argument is donated: decode/insert rewrite it in place
        # instead of holding two full copies live (the packed pool exists
        # to shrink cache HBM — doubling it back would defeat the point)
        self._prefill = jax.jit(self._prefill_impl)   # per (g, L) shape
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        if self.prefill_chunk:
            # ONE compile for any prompt length / slot: chunk shape is
            # static, slot index / start / valid count are traced
            self._chunk = jax.jit(self._chunk_impl, donate_argnums=(0,))
            self._seed_keys = jax.jit(kv_pool.seed_slot_keys,
                                      donate_argnums=(0,))
            self._decode = jax.jit(self._decode_masked_impl,
                                   donate_argnums=(0,))
        else:
            self._decode = jax.jit(self._decode_impl, donate_argnums=(0,))
        self._slot_tot = jax.jit(kv_pool.slot_totals)
        # MoE prefill routes with a capacity computed over the whole batch,
        # so batching prompts would couple their routing — admit one at a
        # time to keep the solo == shared token guarantee exact
        self._admit_group_cap = 1 if cfg.num_experts else max_slots

    # -- jitted device steps ----------------------------------------------
    def _prefill_impl(self, tokens, keys):
        logits, _, cache = T.prefill(self.cfg, self.policy, self.params,
                                     {"tokens": tokens}, self.exps,
                                     self.sinks, max_cache_len=self.max_len)
        # first generated token sits at absolute position L = prompt length
        pos = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        first = sampler.sample(logits, sampler.position_keys(keys, pos),
                               self.sampler_cfg)
        return first, cache

    def _insert_impl(self, pool, entry, slots, keys):
        return kv_pool.insert(pool, entry, slots, self.codec, keys)

    def _decode_impl(self, pool, tok, pos, keys):
        logits, _, pool = T.decode_step(self.cfg, self.policy, self.params,
                                        pool, tok, pos, self.exps,
                                        self.sinks, kv_codec=self.codec)
        nxt = sampler.sample(logits, sampler.position_keys(keys, pos + 1),
                             self.sampler_cfg)
        return nxt, pool

    def _decode_masked_impl(self, pool, tok, pos, keys, mask):
        # chunked mode: slots mid-prefill (or free) decode garbage whose
        # cache append must be dropped — their pool rows and controller
        # state must stay byte-identical to a solo run
        logits, _, pool = T.decode_step(self.cfg, self.policy, self.params,
                                        pool, tok, pos, self.exps,
                                        self.sinks, kv_codec=self.codec,
                                        append_mask=mask)
        nxt = sampler.sample(logits, sampler.position_keys(keys, pos + 1),
                             self.sampler_cfg)
        return nxt, pool

    def _chunk_impl(self, pool, tokens, slot, p0, n_valid, keys):
        """One prefill chunk for one slot. ``tokens``: [1, C] (padded);
        ``slot``/``p0``/``n_valid``: traced scalars; ``keys``: [1, 2]."""
        # paged-aware slicing: slot-indexed leaves narrow to B=1, page
        # arenas pass through whole (the chunk scatters into its own
        # slot's pages); reduces to the plain tree_map for slot-major
        sub = paged.slice_slot(pool, slot)
        logits, _, sub = T.prefill_chunk_step(
            self.cfg, self.policy, self.params, sub, tokens, p0[None],
            n_valid[None], self.exps, self.sinks, kv_codec=self.codec)
        pool = paged.merge_slot(pool, sub, slot)
        # the first generated token sits at absolute position p0 + n_valid
        # (== prompt length when this is the final chunk) — the same key
        # fold as whole-prompt _prefill_impl
        tok = sampler.sample(logits,
                             sampler.position_keys(keys, (p0 + n_valid)[None]),
                             self.sampler_cfg)
        return tok, pool

    # -- request lifecycle -------------------------------------------------
    def submit(self, prompt, max_new: int = 16,
               eos_id: Optional[int] = None) -> int:
        """Queue one request; returns its uid."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt_len {prompt.size} + max_new {max_new} exceeds "
                f"max_len {self.max_len}")
        # ssm/hybrid prompts need NOT align to ssm_chunk: ssm_forward pads
        # the final chunk and masks the pad positions' dt, so the decode
        # cache is exactly the state after the real tokens
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(Request(uid, prompt, max_new, eos_id))
        self.metrics.on_submit(uid, prompt.size)
        return uid

    def _finish(self, slot: int) -> None:
        req = self._reqs[slot]
        self._results[req.uid] = np.asarray(self._gen[slot], np.int32)
        self.metrics.on_finish(req.uid)
        if self._packed:
            self._ovf += np.asarray(self._slot_tot(self._pool, slot),
                                    np.float64)
        if self._paged:
            # decref the slot's pages AFTER the stats harvest above read
            # them; registered prefix pages stay resident for reuse
            self._alloc.free_slot(slot)
            self._pstarted[slot] = False
        self._active[slot] = False
        self._reqs[slot] = None

    def _maybe_finish(self, slot: int, tok: int) -> bool:
        """Finish the slot if its budget is spent or ``tok`` is its EOS."""
        req = self._reqs[slot]
        if len(self._gen[slot]) >= req.max_new or \
                (req.eos_id is not None and tok == req.eos_id):
            self._finish(slot)
            return True
        return False

    def _admit(self) -> None:
        """Fill free slots from the queue, grouping equal prompt lengths."""
        free = list(np.where(~self._active)[0])
        while self._queue and free:
            plen = self._queue[0].tokens.size
            cap = min(len(free), self._admit_group_cap)
            group: List[Request] = []
            while (self._queue and len(group) < cap
                   and self._queue[0].tokens.size == plen):
                group.append(self._queue.popleft())
            slots = [int(free.pop(0)) for _ in group]
            tokens = jnp.asarray(np.stack([r.tokens for r in group]))
            keys = jnp.stack([sampler.request_key(self.seed, r.uid)
                              for r in group])
            first, entry = self._prefill(tokens, keys)
            self._pool = self._insert(self._pool, entry,
                                      jnp.asarray(slots, jnp.int32), keys)
            first = np.asarray(first)
            for r, s, tok in zip(group, slots, first):
                self.metrics.on_admit(r.uid)
                self.metrics.on_token(r.uid)
                self._reqs[s], self._gen[s] = r, [int(tok)]
                self._tok[s], self._pos[s] = tok, plen
                self._keys[s] = np.asarray(
                    sampler.request_key(self.seed, r.uid))
                self._active[s] = True
                if self._maybe_finish(s, int(tok)):
                    free.append(s)

    def _admit_chunked(self) -> None:
        """Assign queued requests to free slots immediately (no grouping,
        no prefill compute yet — chunks run one per engine step)."""
        free = [s for s in range(self.max_slots) if self._reqs[s] is None]
        while self._queue and free:
            r = self._queue.popleft()
            s = free.pop(0)
            self._reqs[s] = r
            self._pfill[s] = 0
            self._pstarted[s] = False
            self._pos[s] = 0
            self._gen[s] = []
            self._active[s] = False
            key = sampler.request_key(self.seed, r.uid)
            self._keys[s] = np.asarray(key)
            if self._packed and self.cache_cfg.stochastic:
                # seed the slot's cache PRNG chains before its first chunk
                self._pool = self._seed_keys(self._pool, jnp.int32(s), key)
            self._prefilling.append(s)
            self.metrics.on_admit(r.uid)

    def _ensure_blocks(self, slot: int, start: int, n: int) -> None:
        """Paged mode: make the blocks covering rows ``[start, start+n)``
        privately writable — allocate fresh pages at block boundaries and
        fork (copy-on-write) shared pages the slot is about to write."""
        P = self.page_size
        for b in range(start // P, (start + n - 1) // P + 1):
            act = self._alloc.ensure_block(slot, b)
            if act is None:
                continue
            kind, src, dst = act
            if kind == "cow":
                self._pool = self._cow(self._pool, jnp.int32(src),
                                       jnp.int32(dst))
            self._pool = self._set_block(self._pool, jnp.int32(slot),
                                         jnp.int32(b), jnp.int32(dst))

    def _step_prefill_chunk(self) -> None:
        """Run ONE chunk for the oldest prefilling slot (FIFO)."""
        if not self._prefilling:
            return
        s = self._prefilling[0]
        r = self._reqs[s]
        if self._paged and not self._pstarted[s]:
            # first chunk for this request: map its block table, reusing
            # any registered prefix pages (refcounted, read-only until a
            # write forces a copy-on-write fork).  FIFO chunk order means
            # an earlier request registers its prefix before a later
            # request's first chunk looks it up.
            pages, shared = (self._alloc.match_prefix(r.tokens)
                             if self._share_prefix else ([], 0))
            row = self._alloc.new_slot(s, pages)
            self._pool = self._reset_slot(
                self._pool, jnp.int32(s), jnp.int32(shared),
                jnp.asarray(row), jnp.float32(shared))
            self._pfill[s] = shared   # shared rows are already written
            self._pstarted[s] = True
        f = int(self._pfill[s])
        C = self.prefill_chunk
        n = min(C, r.tokens.size - f)
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = r.tokens[f:f + n]
        if self._paged:
            self._ensure_blocks(s, f, n)
        first, self._pool = self._chunk(
            self._pool, jnp.asarray(toks), jnp.int32(s), jnp.int32(f),
            jnp.int32(n), jnp.asarray(self._keys[s:s + 1]))
        self._pfill[s] = f + n
        self._pos[s] = f + n          # frontier (RoPE-safe while masked)
        self.metrics.on_prefill_chunk(r.uid)
        if f + n == r.tokens.size:    # final chunk: first token sampled
            self._prefilling.popleft()
            if self._paged and self._share_prefix:
                self._alloc.register_prefix(s, r.tokens)
            tok = int(np.asarray(first)[0])
            self.metrics.on_token(r.uid)
            self._gen[s] = [tok]
            self._tok[s] = tok
            self._active[s] = True
            self._maybe_finish(s, tok)

    def step(self) -> None:
        """Admit what fits, run one prefill chunk (chunked mode), then
        decode one token on every active slot."""
        if self.prefill_chunk:
            self._admit_chunked()
            self._step_prefill_chunk()
        else:
            self._admit()
        if not self._active.any():
            return
        if self.prefill_chunk:
            if self._paged:
                # each active slot appends one row at _pos this step —
                # fresh page at a block boundary, COW if still shared
                for s in np.where(self._active)[0]:
                    self._ensure_blocks(int(s), int(self._pos[s]), 1)
            nxt, self._pool = self._decode(
                self._pool, jnp.asarray(self._tok), jnp.asarray(self._pos),
                jnp.asarray(self._keys), jnp.asarray(self._active))
        else:
            nxt, self._pool = self._decode(self._pool,
                                           jnp.asarray(self._tok),
                                           jnp.asarray(self._pos),
                                           jnp.asarray(self._keys))
        nxt = np.asarray(nxt)
        self.metrics.on_decode_step()
        for s in np.where(self._active)[0]:
            tok = int(nxt[s])
            self._gen[s].append(tok)
            self._pos[s] += 1
            self._tok[s] = tok
            self.metrics.on_token(self._reqs[s].uid)
            self._maybe_finish(s, tok)

    def run(self, max_steps: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Drive until the queue drains; returns ``{uid: generated ids}``."""
        if max_steps is not None:
            budget = max_steps
        else:
            pending = list(self._queue) + [r for r in self._reqs
                                           if r is not None]
            chunks = 0
            if self.prefill_chunk:
                chunks = sum(-(-r.tokens.size // self.prefill_chunk)
                             for r in pending)
            budget = (sum(r.max_new for r in pending) + chunks
                      + len(self._queue) + self.max_slots + 4)
        steps = 0
        while self._queue or self._prefilling or self._active.any():
            if steps >= budget:
                raise RuntimeError(f"engine did not drain in {budget} steps")
            self.step()
            steps += 1
        return dict(self._results)

    # -- introspection -----------------------------------------------------
    def reset_metrics(self) -> None:
        """Start a fresh measurement window (latency/throughput/overflow).

        Aggregates otherwise span the engine's whole lifetime — on an
        engine reused across waves, ``wall_s`` includes host idle time
        between ``run()`` calls, so reset before a wave you want to
        measure in isolation.
        """
        self.metrics = metrics.ServeMetrics()
        self._ovf = np.zeros(3, np.float64)

    def cache_stats(self) -> dict:
        """Append overflow rate over finished requests + in-flight slots."""
        live = kv_pool.overflow_summary(self._pool, self._active)
        ovf = self._ovf[0] + live["cache_overflow_rate"] * \
            live["cache_appends_quantized"]
        tot = self._ovf[2] + live["cache_appends_quantized"]
        return {"cache_overflow_rate": float(ovf / tot) if tot else 0.0,
                "cache_appends_quantized": float(tot)}

    def stats(self) -> dict:
        extra = self.cache_stats()
        if self._paged:
            extra.update(self._alloc.stats())
        return self.metrics.summary(extra=extra)
