"""Continuous-batching decode engine over a fixed slot array.

Replaces the lockstep loop (prefill a batch, decode everyone for exactly
``max_new`` steps) with a real request lifecycle:

  queued → admitted into a free slot (prefill) → decoding at its own
  position → finished (EOS or its own ``max_new``) → slot freed →
  next queued request admitted **mid-decode**.

Every device computation is fixed-shape and jitted once per shape:

* ``_decode`` runs over all ``max_slots`` rows each step — per-slot
  position vector (``transformer.decode_step`` with ``pos: [B]``),
  per-slot PRNG streams, one compile for the engine's lifetime.  Free
  slots decode garbage into their own cache rows; row independence means
  active slots are unaffected, and admission overwrites the row anyway.
* ``_prefill`` (whole-prompt mode, ``prefill_chunk=0``) compiles per
  ``(group_size, prompt_len)``: admission groups queued requests of
  equal prompt length into one batch, so a burst of same-length requests
  costs one prefill — and an engine admitting B equal-length prompts
  into B free slots reproduces the lockstep engine's prefill bit-for-bit
  (the equivalence test's anchor).  Variable-length prompts prefill as
  separate length groups, never padded — padding would perturb MoE
  capacity routing and SSM state.  MoE models admit one request per
  prefill for the same reason: expert capacity is computed over the
  whole prefill batch, and the engine guarantees a request's tokens
  don't depend on who it shares with.
* ``_insert`` scatters the fresh cache entry into pool rows (axis 1) and,
  in packed mode, quantizes it first (``kv_pool.PackedKVCodec``).
* ``_chunk`` (**chunked-prefill mode**, ``prefill_chunk=C > 0``,
  attention-family models): any queued request is admitted into any free
  slot immediately, and each engine step runs ONE fixed-size prefill
  chunk for the oldest prefilling slot, interleaved with the decode
  batch.  While a slot is mid-prefill the decode batch's append is
  masked off for it (``append_mask``), so its pool row and controller
  state stay byte-identical to a solo run.  Whole-prompt mode remains
  the bit-for-bit reference path.

The KV pool stores K/V float32 (bit-identical to ``transformer.init_cache``)
or as DFXP-packed int8/int16 mantissas with controller-managed per-slot
exponents (``cache_bits=8|16``) — halving/quartering cache HBM and hence
multiplying concurrent slot capacity.

Robustness layer (admission control, preemption, quarantine)
------------------------------------------------------------

Production serving fails in exactly the ways low-precision numerics make
survivable *per request* — if the engine can detect, quarantine, and
recover instead of crashing the batch:

* **admission control** — ``queue_cap`` bounds the queue (submit beyond
  it resolves the request ``REJECTED``, it never raises);
  ``deadline_ms`` (engine default, overridable per submit) expires
  queued *and* in-flight requests to ``TIMED_OUT`` with whatever tokens
  they harvested.  Every request ends in a terminal
  :class:`RequestStatus` readable via :meth:`ServeEngine.status`.
* **preemption under page exhaustion** — when the paged arena runs dry
  mid-step, the engine picks a victim (the *youngest decoding* request,
  falling back to the youngest prefilling one), releases its non-shared
  pages, and requeues it at the front of the queue with its
  generated-so-far tokens carried as prompt suffix.  Re-admission
  re-prefills prompt + carry through the chunked-prefill path — prefix
  caching makes the prompt part free when its pages are still registered
  — and the sampler keys on ``(seed, uid, absolute position)``, so the
  resumed stream continues exactly where it left off.  A request
  preempted more than ``max_preempts`` times resolves ``FAILED`` instead
  of thrashing; exhaustion with no preemptible sibling resolves the
  requester ``FAILED``.  ``run()`` never raises for page exhaustion.
* **numeric sentinels** — every decode/prefill jit guards its logits
  device-side (``sampler.guard_logits``): a NaN/Inf row flags ``bad``
  for its slot, harvested with the sampled tokens in the same device
  sync.  A flagged slot is quarantined ``FAILED`` — its poisoned token
  dropped, its slot freed and thereby masked out of subsequent appends —
  while sibling slots' streams are untouched (row independence + masked
  appends).  ``runaway_ovf`` adds a §5 overflow-rate runaway threshold:
  slots whose cumulative cache overflow rate exceeds it (the paper's
  controller has lost the race) quarantine the same way.
* **drain-timeout** — ``run()`` out of step budget resolves every
  in-flight request ``TIMED_OUT`` (queued preempted ones ``PREEMPTED``)
  and returns all harvested tokens instead of raising and discarding
  them.

Deterministic fault injectors driving all of this live in
:mod:`repro.serve.faults`.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ScaleState
from repro.core.policy import PrecisionPolicy
from repro.dist import DistCtx, MeshConfigError, serve_pod_ctx
from repro.models import transformer as T

from . import kv_pool, metrics, paged, sampler

Array = jax.Array


class RequestStatus(enum.Enum):
    """Terminal state of a request. The engine resolves every submitted
    uid to exactly one of these instead of raising mid-drain."""

    OK = "ok"                  # finished: EOS or its max_new budget
    REJECTED = "rejected"      # admission control: queue was full
    TIMED_OUT = "timed_out"    # deadline expired / drain ran out of steps
    PREEMPTED = "preempted"    # evicted for pages, still queued at drain end
    FAILED = "failed"          # quarantined: NaN/Inf logits, §5 runaway,
    #                            or page exhaustion with no victim


@dataclasses.dataclass
class Request:
    """One generation request. ``tokens``: 1-D prompt ids.

    ``deadline`` is an absolute ``time.perf_counter`` stamp (set by the
    engine from ``deadline_ms``); ``carry`` holds tokens generated
    before a preemption (they ride along as prompt suffix on requeue and
    are prepended to the final result); ``n_preempt`` counts evictions.
    """

    uid: int
    tokens: np.ndarray
    max_new: int = 16
    eos_id: Optional[int] = None
    deadline: Optional[float] = None
    carry: Tuple[int, ...] = ()
    n_preempt: int = 0


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """Every :class:`ServeEngine` knob beyond the model triple, the slot
    geometry, and the mesh, as one typed value.

    Groups the pool layout (``cache_bits``/``cache_cfg``/``page_size``/
    ``n_pages``/``prefill_chunk``), sampling (``sampler_cfg``/``seed``),
    admission control (``queue_cap``/``deadline_ms``), resilience
    (``runaway_ovf``/``max_preempts``/``faults``), and observability
    (``tracer``/``numerics_log``/``numerics_every``) knobs that used to
    travel as loose keyword arguments.  Field semantics are documented on
    :class:`ServeEngine` (they are the same knobs, one release of
    deprecation apart); defaults reproduce the bare
    ``ServeEngine(cfg, policy, params, max_slots=…, max_len=…)`` engine
    bit-for-bit.
    """

    cache_bits: int = 0
    sampler_cfg: sampler.SamplerConfig = sampler.SamplerConfig()
    cache_cfg: Optional[kv_pool.CacheQuantConfig] = None
    seed: int = 0
    init_exp: float = -6.0
    prefill_chunk: Optional[int] = None
    page_size: Optional[int] = None
    n_pages: Optional[int] = None
    queue_cap: Optional[int] = None
    deadline_ms: Optional[float] = None
    runaway_ovf: Optional[float] = None
    max_preempts: int = 4
    faults: object = None
    tracer: object = None
    numerics_log: object = None
    numerics_every: Optional[int] = None


_LEGACY_ENGINE_KWARGS = frozenset(
    f.name for f in dataclasses.fields(EngineOptions))


class ServeEngine:
    """Continuous-batching engine over ``max_slots`` concurrent sequences.

    Construction is ``ServeEngine(cfg, policy, params, max_slots=…,
    max_len=…, options=EngineOptions(…))`` plus, for multi-device
    serving, ``dist=serve_pod_ctx(tp=…, cp=…)`` and
    ``mesh=make_serve_mesh(tp=…, cp=…)``.  Passing the options fields as
    loose keyword arguments still works for one release and warns
    (``DeprecationWarning``); unknown keywords raise ``TypeError``.

    Multi-device serving shards the **KV pool** (the HBM-bound tensor):
    kv heads over the mesh's ``model`` axis (TP), and — with
    ``dist.cp_decode`` — the decode KV window over ``data`` (CP, exact
    log-sum-exp merge).  Parameters stay replicated and the attention
    output is gathered before the ``wo`` contraction, so the sharded
    engine's greedy token streams are bit-identical to single-device.
    Incoherent requests (active ``dist`` without its mesh, CP over a
    paged arena, a window CP doesn't divide) raise
    :class:`repro.dist.MeshConfigError` at construction.

    Parameters
    ----------
    cfg, policy, params: the functional model triple.
    max_slots: concurrent sequences (the decode batch shape).
    max_len: per-slot KV capacity; every request needs
        ``prompt_len + max_new <= max_len``.
    options: an :class:`EngineOptions`; the per-knob semantics below.
    dist: a :class:`repro.dist.DistCtx` naming the mesh axes in play
        (``serve_pod_ctx``); ``None`` with a ``mesh`` derives one from
        the mesh's axis sizes; both ``None`` = single-device (today's
        engine, bit-for-bit).
    mesh: the device mesh (``launch.mesh.make_serve_mesh``) backing an
        active ``dist``.
    cache_bits: 0 → float32 KV pool (bit-identical to the lockstep
        engine); 8/16 → DFXP-packed mantissa pool.  With
        ``policy.fused_decode`` the decode attention runs as the fused
        Pallas flash-decode kernel straight on the pool's storage
        (packed mantissas dequantized in the tile loads — no per-layer
        f32 K/V materialization on the hot path).
    sampler_cfg: greedy / temperature / top-k, per-request PRNG streams.
    cache_cfg: overrides the packed pool's controller settings.
    prefill_chunk: chunk size ``C`` for chunked prefill (see module
        docstring); ``None`` takes ``policy.prefill_chunk``, 0 keeps the
        whole-prompt reference path.  Attention-family models only — MoE
        keeps the solo whole-prompt carve-out (batch-coupled expert
        capacity) and SSM/hybrid carry recurrent state across the
        prompt; both silently stay on the whole-prompt path.
    page_size: ``P > 0`` switches the KV pool to **paged** storage
        (:mod:`repro.serve.paged`): fixed-size pages + per-request block
        tables, refcounted prompt-prefix sharing with copy-on-write, and
        page-granular DFXP exponents.  Forces chunked prefill (``C``
        defaults to ``P``) and requires the dense attention family with
        global (non-windowed) attention; ``None``/0 takes
        ``policy.page_size``.  Prefix sharing is disabled under
        stochastic rounding (a shared page cannot replay two requests'
        PRNG streams) — pages and copy-on-write still apply.
    n_pages: paged-pool page budget (default: full residency — every
        slot can map its whole ``max_len`` — plus the null page).  A
        smaller budget recycles freed/evicted pages; exhaustion
        mid-step **preempts** the youngest decoding request (released
        pages recycle, the victim requeues and resumes) instead of
        raising.
    queue_cap: bound on the waiting queue; a submit finding it full
        resolves the new request ``REJECTED`` (empty result, terminal
        status) instead of queueing or raising.  ``None`` = unbounded.
    deadline_ms: default per-request deadline, measured from submit;
        expired requests — queued or in-flight — resolve ``TIMED_OUT``
        with the tokens harvested so far.  ``None`` = no deadline.
    runaway_ovf: §5 overflow-rate runaway threshold.  Each decode step
        harvests every slot's cumulative cache overflow rate
        (``kv_pool.slot_overflow_rates``, computed in-jit) with the
        tokens; an active slot whose rate exceeds this quarantines as
        ``FAILED``.  ``None`` disables the sentinel.
    max_preempts: a request evicted this many times resolves ``FAILED``
        on the next eviction attempt instead of requeueing (bounds
        preemption ping-pong on pathologically small arenas).
    faults: optional deterministic fault harness
        (:class:`repro.serve.faults.FaultHarness`) — injects NaN logits,
        KV bit flips, forced page exhaustion, and admission delays for
        chaos testing.  ``None`` in production.
    tracer: optional :class:`repro.obs.Tracer` — records every engine
        phase (submit/admit/prefill-chunk/decode-step/preempt/finish)
        as span/instant events plus queue-depth counters, exportable as
        Chrome-trace JSON.  ``None`` (the default) records nothing: all
        hooks are guarded by a single ``is not None`` check — no device
        syncs, no extra per-token host work, token streams bit-identical.
    numerics_log: optional :class:`repro.obs.NumericsLog` (or a path
        string) receiving the §5 numeric-health timeline: per-layer/
        per-slot K/V exponents, overflow rates, and controller up/down
        moves, sampled every ``numerics_every`` steps via one batched
        jit + device fetch (``kv_pool.numerics_snapshot``) — a single
        device sync per sample, nothing added to undisturbed steps.
        Packed pools only (float32 pools have no controller to watch).
    numerics_every: sampling cadence in engine steps; default: the
        packed pool's controller ``update_interval`` (one sample per
        controller decision window).
    """

    def __init__(self, cfg: T.ModelConfig, policy: PrecisionPolicy, params,
                 *, max_slots: int, max_len: int,
                 options: Optional[EngineOptions] = None,
                 dist: Optional[DistCtx] = None, mesh=None, **legacy):
        if legacy:
            unknown = sorted(set(legacy) - _LEGACY_ENGINE_KWARGS)
            if unknown:
                raise TypeError(
                    f"ServeEngine got unexpected keyword arguments "
                    f"{unknown}")
            warnings.warn(
                "passing ServeEngine configuration as loose keyword "
                "arguments is deprecated; pass options=EngineOptions(...)",
                DeprecationWarning, stacklevel=2)
            options = dataclasses.replace(options or EngineOptions(),
                                          **legacy)
        opts = options or EngineOptions()
        if cfg.input_mode != "tokens" or cfg.encoder_layers:
            raise ValueError("ServeEngine serves token-in decoder models")
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if dist is None and mesh is not None:
            # derive the serving context from the mesh's axis sizes
            dist = serve_pod_ctx(tp=int(mesh.shape.get("model", 1)),
                                 cp=int(mesh.shape.get("data", 1)))
        self.dist = dist or DistCtx()
        self.mesh = mesh
        if self.dist.active and mesh is None:
            raise MeshConfigError(
                "an active DistCtx needs the mesh it names; pass "
                "mesh=launch.mesh.make_serve_mesh(...)")
        self.cfg, self.policy, self.params = cfg, policy, params
        self.max_slots, self.max_len = max_slots, max_len
        self.options = opts
        self.sampler_cfg = opts.sampler_cfg
        self.seed = opts.seed
        self.queue_cap = opts.queue_cap
        self.deadline_ms = opts.deadline_ms
        self.runaway_ovf = opts.runaway_ovf
        self.max_preempts = opts.max_preempts
        self._faults = opts.faults
        gs = T.group_shapes(cfg)
        self.exps = ScaleState.create(gs, opts.init_exp).exps
        self.sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                      for n, s in gs.items() if n.startswith("g:")}

        # pool construction is factory-owned: layout choice, codec
        # capabilities, validation, and (mesh runs) sharded placement
        kvp = kv_pool.make_kv_pool(
            cfg, policy, self.dist, max_slots=max_slots, max_len=max_len,
            cache_bits=opts.cache_bits, cache_cfg=opts.cache_cfg,
            page_size=opts.page_size, n_pages=opts.n_pages, mesh=mesh)
        self.kv = kvp
        self.codec = kvp.codec
        self.cache_cfg = kvp.cache_cfg
        self.page_size = kvp.page_size
        self._paged = kvp.paged
        self._packed = kvp.packed
        self._pool = kvp.pool
        self._pool_shardings = kvp.shardings
        if self.dist.active:
            # params/exps/sinks stay REPLICATED: every contraction that
            # could reorder partial sums runs identically on all devices,
            # which is what keeps sharded greedy streams bit-identical
            rep = jax.sharding.NamedSharding(mesh,
                                             jax.sharding.PartitionSpec())
            self.params = jax.device_put(self.params, rep)
            self.exps = jax.device_put(self.exps, rep)
            self.sinks = jax.device_put(self.sinks, rep)
        if self._paged:
            self._alloc = paged.PageAllocator(kvp.total_pages,
                                              self.page_size, kvp.nblocks)
            # a shared page cannot replay two requests' stochastic PRNG
            # chains — sharing off, COW/paging still on
            self._share_prefix = not (self._packed
                                      and self.cache_cfg.stochastic)
            self._reset_slot = jax.jit(paged.reset_slot,
                                       donate_argnums=(0,))
            self._cow = jax.jit(paged.cow_page, donate_argnums=(0,))
            self._set_block = jax.jit(paged.set_block, donate_argnums=(0,))

        # per-slot host state
        B = max_slots
        self._tok = np.zeros(B, np.int32)
        self._pos = np.zeros(B, np.int32)
        self._active = np.zeros(B, bool)
        self._reqs: List[Optional[Request]] = [None] * B
        self._gen: List[List[int]] = [[] for _ in range(B)]
        self._keys = np.zeros((B, 2), np.uint32)
        self._seq = np.zeros(B, np.int64)     # admission order (victim pick)
        self._queue: collections.deque = collections.deque()
        self._results: Dict[int, np.ndarray] = {}
        self._status: Dict[int, RequestStatus] = {}
        self._next_uid = 0
        self._admit_counter = 0
        self._step_idx = 0
        self._budget = 1 << 62                # run() tightens this
        self._auto_budget = True
        self._ovf = np.zeros(3, np.float64)   # harvested at request finish
        self.metrics = metrics.ServeMetrics()

        # observability (every hook below guards on `is not None`; with
        # all three unset the step loop is bit-identical to an unobserved
        # engine — no spans, no samples, no extra syncs)
        tracer = opts.tracer
        numerics_log = opts.numerics_log
        self._tracer = tracer
        if tracer is not None and self._faults is not None and \
                getattr(self._faults, "tracer", None) is None:
            self._faults.tracer = tracer  # fault injections land on trace
        if isinstance(numerics_log, str):
            from repro.obs import NumericsLog
            numerics_log = NumericsLog(numerics_log)
        self._numerics = numerics_log if self._packed else None
        if opts.numerics_every is not None:
            self._num_every = max(int(opts.numerics_every), 1)
        elif self._packed:
            self._num_every = max(int(self.cache_cfg.update_interval), 1)
        else:
            self._num_every = 1
        self._num_prev: Optional[dict] = None
        self._num_snap = None         # jitted numerics_snapshot, on demand

        # chunked prefill: attention-family only (MoE capacity and SSM
        # state couple a whole prompt; they keep the whole-prompt path)
        pc = opts.prefill_chunk if opts.prefill_chunk is not None else \
            int(getattr(policy, "prefill_chunk", 0))
        if self._paged and not pc:
            pc = self.page_size   # paged mode always prefills in chunks
        chunkable = (cfg.family == "dense" and not cfg.num_experts
                     and not cfg.encoder_layers)
        self.prefill_chunk = pc if (pc and chunkable) else 0
        self._pfill = np.zeros(B, np.int32)       # prefill frontier per slot
        self._pstarted = np.zeros(B, bool)        # paged: block table mapped
        self._prefilling: collections.deque = collections.deque()  # slot FIFO

        # the pool argument is donated: decode/insert rewrite it in place
        # instead of holding two full copies live (the packed pool exists
        # to shrink cache HBM — doubling it back would defeat the point)
        self._prefill = jax.jit(self._prefill_impl)   # per (g, L) shape
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        if self.prefill_chunk:
            # ONE compile for any prompt length / slot: chunk shape is
            # static, slot index / start / valid count are traced
            self._chunk = jax.jit(self._chunk_impl, donate_argnums=(0,))
            self._seed_keys = jax.jit(kv_pool.seed_slot_keys,
                                      donate_argnums=(0,))
            self._decode = jax.jit(self._decode_masked_impl,
                                   donate_argnums=(0,))
        else:
            self._decode = jax.jit(self._decode_impl, donate_argnums=(0,))
        self._slot_tot = jax.jit(kv_pool.slot_totals)
        # MoE prefill routes with a capacity computed over the whole batch,
        # so batching prompts would couple their routing — admit one at a
        # time to keep the solo == shared token guarantee exact
        self._admit_group_cap = 1 if cfg.num_experts else max_slots

    # -- jitted device steps ----------------------------------------------
    def _constrain_pool(self, pool):
        """Pin the donated pool to its canonical sharded layout.

        Applied at every jit's pool output on mesh runs, so GSPMD cannot
        drift the resident layout between steps; identity single-device.
        """
        if self._pool_shardings is None:
            return pool
        return jax.lax.with_sharding_constraint(pool, self._pool_shardings)

    def _prefill_impl(self, tokens, keys):
        logits, _, cache = T.prefill(self.cfg, self.policy, self.params,
                                     {"tokens": tokens}, self.exps,
                                     self.sinks, self.dist,
                                     max_cache_len=self.max_len)
        # first generated token sits at absolute position L = prompt length
        pos = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        safe, bad = sampler.guard_logits(logits)
        first = sampler.sample(safe, sampler.position_keys(keys, pos),
                               self.sampler_cfg)
        return first, bad, cache

    def _insert_impl(self, pool, entry, slots, keys):
        return self._constrain_pool(
            kv_pool.insert(pool, entry, slots, self.codec, keys))

    def _sample_guarded(self, logits, pos, keys, nan_mask):
        """Shared decode tail: fault mask → sentinel → sample."""
        logits = jnp.where(nan_mask[:, None], jnp.float32(jnp.nan), logits)
        safe, bad = sampler.guard_logits(logits)
        nxt = sampler.sample(safe, sampler.position_keys(keys, pos + 1),
                             self.sampler_cfg)
        return nxt, bad

    def _decode_impl(self, pool, tok, pos, keys, nan_mask):
        logits, _, pool = T.decode_step(self.cfg, self.policy, self.params,
                                        pool, tok, pos, self.exps,
                                        self.sinks, self.dist,
                                        kv_codec=self.codec)
        nxt, bad = self._sample_guarded(logits, pos, keys, nan_mask)
        rate = kv_pool.slot_overflow_rates(pool, self.max_slots)
        return nxt, bad, rate, self._constrain_pool(pool)

    def _decode_masked_impl(self, pool, tok, pos, keys, mask, nan_mask):
        # chunked mode: slots mid-prefill (or free) decode garbage whose
        # cache append must be dropped — their pool rows and controller
        # state must stay byte-identical to a solo run
        logits, _, pool = T.decode_step(self.cfg, self.policy, self.params,
                                        pool, tok, pos, self.exps,
                                        self.sinks, self.dist,
                                        kv_codec=self.codec,
                                        append_mask=mask)
        nxt, bad = self._sample_guarded(logits, pos, keys, nan_mask)
        rate = kv_pool.slot_overflow_rates(pool, self.max_slots)
        return nxt, bad, rate, self._constrain_pool(pool)

    def _chunk_impl(self, pool, tokens, slot, p0, n_valid, keys):
        """One prefill chunk for one slot. ``tokens``: [1, C] (padded);
        ``slot``/``p0``/``n_valid``: traced scalars; ``keys``: [1, 2]."""
        # paged-aware slicing: slot-indexed leaves narrow to B=1, page
        # arenas pass through whole (the chunk scatters into its own
        # slot's pages); reduces to the plain tree_map for slot-major
        sub = paged.slice_slot(pool, slot)
        logits, _, sub = T.prefill_chunk_step(
            self.cfg, self.policy, self.params, sub, tokens, p0[None],
            n_valid[None], self.exps, self.sinks, self.dist,
            kv_codec=self.codec)
        pool = self._constrain_pool(paged.merge_slot(pool, sub, slot))
        # the first generated token sits at absolute position p0 + n_valid
        # (== prompt length when this is the final chunk) — the same key
        # fold as whole-prompt _prefill_impl
        safe, bad = sampler.guard_logits(logits)
        tok = sampler.sample(safe,
                             sampler.position_keys(keys, (p0 + n_valid)[None]),
                             self.sampler_cfg)
        return tok, bad, pool

    # -- request lifecycle -------------------------------------------------
    def submit(self, prompt, max_new: int = 16,
               eos_id: Optional[int] = None,
               deadline_ms: Optional[float] = None) -> int:
        """Queue one request; returns its uid.

        Malformed requests (empty prompt, zero budget, over capacity)
        still raise — those are caller bugs, not load.  Load shedding is
        status-typed: a full queue resolves the request ``REJECTED``
        immediately (empty result, no exception); ``deadline_ms``
        (default: the engine's) stamps an expiry the scheduler enforces.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt_len {prompt.size} + max_new {max_new} exceeds "
                f"max_len {self.max_len}")
        # ssm/hybrid prompts need NOT align to ssm_chunk: ssm_forward pads
        # the final chunk and masks the pad positions' dt, so the decode
        # cache is exactly the state after the real tokens
        uid = self._next_uid
        self._next_uid += 1
        self.metrics.on_submit(uid, prompt.size)
        if self._tracer is not None:
            self._tracer.instant("submit", tid="requests", uid=uid,
                                 prompt_len=int(prompt.size))
        if self.queue_cap is not None and len(self._queue) >= self.queue_cap:
            self._results[uid] = np.zeros(0, np.int32)
            self._status[uid] = RequestStatus.REJECTED
            self.metrics.on_reject(uid)
            if self._tracer is not None:
                self._tracer.instant("reject", tid="requests", uid=uid)
            return uid
        dl = deadline_ms if deadline_ms is not None else self.deadline_ms
        deadline = metrics._now() + dl / 1e3 if dl is not None else None
        self._queue.append(Request(uid, prompt, max_new, eos_id,
                                   deadline=deadline))
        self.metrics.observe_queue_depth(len(self._queue))
        return uid

    def status(self, uid: int) -> Optional[RequestStatus]:
        """Terminal status of ``uid`` (None while queued / in flight)."""
        return self._status.get(uid)

    @property
    def statuses(self) -> Dict[int, RequestStatus]:
        return dict(self._status)

    def _release_slot(self, slot: int) -> None:
        """Drop the slot's host state and (paged) its page references."""
        if self._paged:
            # registered prefix pages stay resident for reuse; everything
            # else decrefs back to the free list
            self._alloc.free_slot(slot)
            self._pstarted[slot] = False
        if slot in self._prefilling:
            self._prefilling.remove(slot)
        self._active[slot] = False
        self._reqs[slot] = None
        self._gen[slot] = []

    def _finish(self, slot: int,
                status: RequestStatus = RequestStatus.OK) -> None:
        req = self._reqs[slot]
        self._results[req.uid] = np.asarray(
            list(req.carry) + self._gen[slot], np.int32)
        self._status[req.uid] = status
        self.metrics.on_finish(req.uid, status.value)
        # harvest BEFORE the page release below makes the reads stale —
        # but only if this request actually wrote the slot (a request
        # resolved before its first chunk would harvest the previous
        # occupant's counters twice)
        started = not self.prefill_chunk or (
            self._pstarted[slot] if self._paged else self._pfill[slot] > 0)
        if self._packed and started:
            self._ovf += np.asarray(self._slot_tot(self._pool, slot),
                                    np.float64)
        if self._tracer is not None:
            self._tracer.instant("finish", tid="requests", uid=req.uid,
                                 slot=slot, status=status.value,
                                 new_tokens=len(self._gen[slot]))
        self._release_slot(slot)

    def _finish_queued(self, req: Request, status: RequestStatus) -> None:
        """Resolve a request that never (re)reached a slot."""
        self._results[req.uid] = np.asarray(list(req.carry), np.int32)
        self._status[req.uid] = status
        self.metrics.on_finish(req.uid, status.value)
        if self._tracer is not None:
            self._tracer.instant("finish", tid="requests", uid=req.uid,
                                 status=status.value)

    def _maybe_finish(self, slot: int, tok: int) -> bool:
        """Finish the slot if its budget is spent or ``tok`` is its EOS."""
        req = self._reqs[slot]
        if len(self._gen[slot]) >= req.max_new or \
                (req.eos_id is not None and tok == req.eos_id):
            self._finish(slot)
            return True
        return False

    # -- preemption --------------------------------------------------------
    def _preempt(self, victim: int) -> None:
        """Evict ``victim`` to the queue front, tokens-so-far carried.

        The requeued request's prompt is ``original prompt + generated
        tokens``: re-admission chunk-prefills it (sharing any still
        registered prefix pages), and the first token it samples sits at
        absolute position ``len(prompt) + len(carry)`` — exactly the key
        fold the uninterrupted decode would have used, so greedy and
        sampled streams resume bit-identically.  A request past
        ``max_preempts`` resolves FAILED instead (thrash bound).
        """
        req = self._reqs[victim]
        if self._tracer is not None:
            self._tracer.begin("preempt", uid=req.uid, slot=victim,
                               n_preempt=req.n_preempt)
            try:
                self._preempt_impl(victim, req)
            finally:
                self._tracer.end()
        else:
            self._preempt_impl(victim, req)

    def _preempt_impl(self, victim: int, req: Request) -> None:
        if req.n_preempt >= self.max_preempts:
            self._finish(victim, RequestStatus.FAILED)
            return
        gen = self._gen[victim]
        tokens = np.concatenate(
            [req.tokens, np.asarray(gen, np.int32)]) if gen else req.tokens
        nr = Request(req.uid, tokens, req.max_new - len(gen), req.eos_id,
                     deadline=req.deadline,
                     carry=tuple(req.carry) + tuple(gen),
                     n_preempt=req.n_preempt + 1)
        self._release_slot(victim)
        self._queue.appendleft(nr)
        self._status[req.uid] = RequestStatus.PREEMPTED
        self.metrics.on_preempt(req.uid)
        if self._auto_budget and self.prefill_chunk:
            # the requeue re-prefills and re-decodes: extend the drain
            # budget so an auto-budgeted run() still terminates cleanly
            self._budget += (-(-int(tokens.size) // self.prefill_chunk)
                             + nr.max_new + 2)

    def _handle_exhaustion(self, slot: int) -> bool:
        """Free pages for ``slot`` by preempting a sibling.

        Victim order: youngest *decoding* request first (most recent
        admission — least sunk cost, shortest re-prefill), then youngest
        prefilling one.  Never the requester itself: its re-admission
        would need at least the pages it already holds, so
        self-preemption cannot make progress.  Returns False when no
        sibling exists (the caller resolves the requester FAILED).
        """
        cands = [s for s in range(self.max_slots)
                 if s != slot and self._reqs[s] is not None
                 and self._active[s]]
        if not cands:
            cands = [s for s in range(self.max_slots)
                     if s != slot and self._reqs[s] is not None]
        if not cands:
            return False
        victim = max(cands, key=lambda s: self._seq[s])
        self._preempt(victim)
        return True

    def _ensure_blocks_safe(self, slot: int, start: int, n: int) -> bool:
        """`_ensure_blocks` that converts exhaustion into preemption.

        Retries after each preemption (freed pages recycle immediately;
        ``ensure_block`` is idempotent for blocks already made private).
        When no victim remains the requester resolves FAILED with its
        harvested tokens.  Never raises ``PageExhausted``.
        """
        while True:
            try:
                self._ensure_blocks(slot, start, n)
                return True
            except paged.PageExhausted:
                if not self._handle_exhaustion(slot):
                    self._finish(slot, RequestStatus.FAILED)
                    return False

    # -- deadlines ---------------------------------------------------------
    def _expire_queue(self) -> None:
        if not self._queue:
            return
        now = metrics._now()
        kept: collections.deque = collections.deque()
        for r in self._queue:
            if r.deadline is not None and now > r.deadline:
                self._finish_queued(r, RequestStatus.TIMED_OUT)
            else:
                kept.append(r)
        self._queue = kept

    def _expire_inflight(self) -> None:
        stamped = [s for s in range(self.max_slots)
                   if self._reqs[s] is not None
                   and self._reqs[s].deadline is not None]
        if not stamped:
            return
        now = metrics._now()
        for s in stamped:
            if self._reqs[s] is not None and now > self._reqs[s].deadline:
                self._finish(s, RequestStatus.TIMED_OUT)

    # -- admission ---------------------------------------------------------
    def _mark_admitted(self, slot: int, req: Request) -> None:
        self._admit_counter += 1
        self._seq[slot] = self._admit_counter
        self.metrics.on_admit(req.uid)
        if self._tracer is not None:
            self._tracer.instant("admitted", tid="requests", uid=req.uid,
                                 slot=slot)

    def _admit(self) -> None:
        """Fill free slots from the queue, grouping equal prompt lengths."""
        free = list(np.where(~self._active)[0])
        while self._queue and free:
            if self._faults is not None and not self._faults.admit_ok(
                    self._queue[0].uid, self._step_idx):
                break
            plen = self._queue[0].tokens.size
            cap = min(len(free), self._admit_group_cap)
            group: List[Request] = []
            while (self._queue and len(group) < cap
                   and self._queue[0].tokens.size == plen):
                group.append(self._queue.popleft())
            slots = [int(free.pop(0)) for _ in group]
            tokens = jnp.asarray(np.stack([r.tokens for r in group]))
            keys = jnp.stack([sampler.request_key(self.seed, r.uid)
                              for r in group])
            first, bad, entry = self._prefill(tokens, keys)
            self._pool = self._insert(self._pool, entry,
                                      jnp.asarray(slots, jnp.int32), keys)
            first = np.asarray(first)
            bad = np.asarray(bad)
            for r, s, tok, b in zip(group, slots, first, bad):
                self._mark_admitted(s, r)
                self._reqs[s], self._gen[s] = r, []
                self._tok[s], self._pos[s] = tok, plen
                self._keys[s] = np.asarray(
                    sampler.request_key(self.seed, r.uid))
                self._active[s] = True
                if b:   # NaN/Inf prefill logits: quarantine at admission
                    self._finish(s, RequestStatus.FAILED)
                    free.append(s)
                    continue
                self.metrics.on_token(r.uid)
                self._gen[s] = [int(tok)]
                if self._maybe_finish(s, int(tok)):
                    free.append(s)

    def _admit_chunked(self) -> None:
        """Assign queued requests to free slots immediately (no grouping,
        no prefill compute yet — chunks run one per engine step)."""
        free = [s for s in range(self.max_slots) if self._reqs[s] is None]
        i = 0
        while self._queue and free and i < len(self._queue):
            r = self._queue[i]
            if self._faults is not None and not self._faults.admit_ok(
                    r.uid, self._step_idx):
                i += 1          # held back: later requests may still admit
                continue
            del self._queue[i]
            s = free.pop(0)
            self._reqs[s] = r
            self._pfill[s] = 0
            self._pstarted[s] = False
            self._pos[s] = 0
            self._gen[s] = []
            self._active[s] = False
            key = sampler.request_key(self.seed, r.uid)
            self._keys[s] = np.asarray(key)
            if self._packed and self.cache_cfg.stochastic:
                # seed the slot's cache PRNG chains before its first chunk
                self._pool = self._seed_keys(self._pool, jnp.int32(s), key)
            self._prefilling.append(s)
            self._mark_admitted(s, r)

    def _ensure_blocks(self, slot: int, start: int, n: int) -> None:
        """Paged mode: make the blocks covering rows ``[start, start+n)``
        privately writable — allocate fresh pages at block boundaries and
        fork (copy-on-write) shared pages the slot is about to write."""
        P = self.page_size
        for b in range(start // P, (start + n - 1) // P + 1):
            act = self._alloc.ensure_block(slot, b)
            if act is None:
                continue
            kind, src, dst = act
            if kind == "cow":
                self._pool = self._cow(self._pool, jnp.int32(src),
                                       jnp.int32(dst))
            self._pool = self._set_block(self._pool, jnp.int32(slot),
                                         jnp.int32(b), jnp.int32(dst))

    def _step_prefill_chunk(self) -> None:
        """Run ONE chunk for the oldest prefilling slot (FIFO)."""
        if not self._prefilling:
            return
        s = self._prefilling[0]
        r = self._reqs[s]
        if self._paged and not self._pstarted[s]:
            # first chunk for this request: map its block table, reusing
            # any registered prefix pages (refcounted, read-only until a
            # write forces a copy-on-write fork).  FIFO chunk order means
            # an earlier request registers its prefix before a later
            # request's first chunk looks it up.
            pages, shared = (self._alloc.match_prefix(r.tokens)
                             if self._share_prefix else ([], 0))
            row = self._alloc.new_slot(s, pages)
            self._pool = self._reset_slot(
                self._pool, jnp.int32(s), jnp.int32(shared),
                jnp.asarray(row), jnp.float32(shared))
            self._pfill[s] = shared   # shared rows are already written
            self._pstarted[s] = True
        f = int(self._pfill[s])
        C = self.prefill_chunk
        n = min(C, r.tokens.size - f)
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = r.tokens[f:f + n]
        if self._paged and not self._ensure_blocks_safe(s, f, n):
            return                    # requester quarantined (no victim)
        first, bad, self._pool = self._chunk(
            self._pool, jnp.asarray(toks), jnp.int32(s), jnp.int32(f),
            jnp.int32(n), jnp.asarray(self._keys[s:s + 1]))
        self._pfill[s] = f + n
        self._pos[s] = f + n          # frontier (RoPE-safe while masked)
        self.metrics.on_prefill_chunk(r.uid)
        if f + n == r.tokens.size:    # final chunk: first token sampled
            self._prefilling.popleft()
            if self._paged and self._share_prefix:
                self._alloc.register_prefix(s, r.tokens)
            if bool(np.asarray(bad)[0]):
                # NaN/Inf prefill logits: quarantine before the poisoned
                # token enters the stream (carried tokens survive)
                self._active[s] = True
                self._finish(s, RequestStatus.FAILED)
                return
            tok = int(np.asarray(first)[0])
            self.metrics.on_token(r.uid)
            self._gen[s] = [tok]
            self._tok[s] = tok
            self._active[s] = True
            self._maybe_finish(s, tok)

    def step(self) -> None:
        """Admit what fits, run one prefill chunk (chunked mode), then
        decode one token on every active slot."""
        if self.mesh is not None:
            # mesh runs trace their jits under the ambient mesh: the
            # fused kernels' shard_map, the CP merge, and the attention
            # output gather all resolve axis names against it
            with jax.set_mesh(self.mesh):
                return self._step_body()
        return self._step_body()

    def _step_body(self) -> None:
        self._step_idx += 1
        tr = self._tracer
        if self._faults is not None:
            self._faults.on_step(self)
        self._expire_queue()
        if self.prefill_chunk:
            if tr is None:
                self._admit_chunked()
                self._step_prefill_chunk()
            else:
                tr.begin("admit", queued=len(self._queue))
                self._admit_chunked()
                tr.end()
                if self._prefilling:
                    s = self._prefilling[0]
                    tr.begin("prefill_chunk", uid=self._reqs[s].uid,
                             slot=int(s), p0=int(self._pfill[s]))
                    try:
                        self._step_prefill_chunk()
                    finally:
                        tr.end()
        else:
            if tr is None:
                self._admit()
            else:
                tr.begin("admit", queued=len(self._queue))
                self._admit()
                tr.end()
        if self._active.any():
            nan_mask = np.zeros(self.max_slots, bool)
            if self._faults is not None:
                nan_mask = self._faults.nan_mask(self)
            if self.prefill_chunk and self._paged:
                # each active slot appends one row at _pos this step —
                # fresh page at a block boundary, COW if still shared;
                # exhaustion preempts the youngest sibling, never raises
                for s in np.where(self._active)[0]:
                    s = int(s)
                    if self._active[s]:   # earlier preemption may clear it
                        self._ensure_blocks_safe(s, int(self._pos[s]), 1)
        if self._active.any():
            if tr is not None:
                tr.begin("decode_step", n_active=int(self._active.sum()))
            if self.prefill_chunk:
                nxt, bad, rate, self._pool = self._decode(
                    self._pool, jnp.asarray(self._tok),
                    jnp.asarray(self._pos), jnp.asarray(self._keys),
                    jnp.asarray(self._active), jnp.asarray(nan_mask))
            else:
                nxt, bad, rate, self._pool = self._decode(
                    self._pool, jnp.asarray(self._tok),
                    jnp.asarray(self._pos), jnp.asarray(self._keys),
                    jnp.asarray(nan_mask))
            nxt, bad, rate = (np.asarray(nxt), np.asarray(bad),
                              np.asarray(rate))
            self.metrics.on_decode_step()
            for s in np.where(self._active)[0]:
                s = int(s)
                if bad[s]:
                    # NaN/Inf decode logits: drop the poisoned token,
                    # quarantine the request, keep siblings untouched
                    self._finish(s, RequestStatus.FAILED)
                    continue
                if self.runaway_ovf is not None and \
                        rate[s] > self.runaway_ovf:
                    # §5 overflow runaway: the controller lost the race
                    self._finish(s, RequestStatus.FAILED)
                    continue
                tok = int(nxt[s])
                self._gen[s].append(tok)
                self._pos[s] += 1
                self._tok[s] = tok
                self.metrics.on_token(self._reqs[s].uid)
                self._maybe_finish(s, tok)
            if tr is not None:
                tr.end()
        self._expire_inflight()
        if tr is not None:
            tr.counter("queue", {"queue_depth": len(self._queue),
                                 "active_slots": int(self._active.sum())})
        if self._numerics is not None and \
                self._step_idx % self._num_every == 0:
            self._sample_numerics()

    def _sample_numerics(self) -> None:
        """One §5 numeric-health sample: a single batched device fetch of
        the packed pool's exponents + overflow counters, diffed against
        the previous sample into per-slot JSONL records (controller
        up/down moves).  Runs only on the sampling cadence with a
        ``numerics_log`` attached — never on an unobserved step."""
        from repro.obs import serve_records
        if self._num_snap is None:
            self._num_snap = jax.jit(
                lambda pool: kv_pool.numerics_snapshot(pool, self.max_slots))
        snap = jax.device_get(self._num_snap(self._pool))
        uids = {s: self._reqs[s].uid for s in range(self.max_slots)
                if self._reqs[s] is not None and self._active[s]}
        if uids:
            recs = serve_records(snap, self._num_prev, step=self._step_idx,
                                 t=metrics._now(), slot_uids=uids)
            for rec in recs:
                self._numerics.record(rec)
            if self._tracer is not None and recs:
                rates = [r for rec in recs for r in rec["ovf_rate"]]
                exps = [e for rec in recs for e in rec["k_e"]]
                self._tracer.counter(
                    "numerics", {"ovf_rate_max": max(rates),
                                 "k_e_mean": sum(exps) / len(exps)},
                    tid="numerics")
        self._num_prev = snap

    def _drain_timeout(self) -> None:
        """Out of steps: resolve everything in flight instead of raising.

        In-flight slots resolve TIMED_OUT with every harvested token;
        queued requests resolve TIMED_OUT, except preempted ones which
        keep their terminal PREEMPTED (they had a slot and lost it)."""
        for s in range(self.max_slots):
            if self._reqs[s] is not None:
                self._finish(s, RequestStatus.TIMED_OUT)
        while self._queue:
            r = self._queue.popleft()
            self._finish_queued(r, RequestStatus.PREEMPTED if r.n_preempt
                                else RequestStatus.TIMED_OUT)

    def run(self, max_steps: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Drive until the queue drains; returns ``{uid: generated ids}``.

        Never raises for scheduling reasons: when the step budget runs
        out (``max_steps``, or the auto budget on a wedged engine) every
        in-flight request resolves ``TIMED_OUT`` with its harvested
        tokens and the partial results are returned — check
        :meth:`status` / :attr:`statuses` for per-request outcomes.
        """
        if max_steps is not None:
            self._budget = max_steps
            self._auto_budget = False
        else:
            pending = list(self._queue) + [r for r in self._reqs
                                           if r is not None]
            chunks = 0
            if self.prefill_chunk:
                chunks = sum(-(-r.tokens.size // self.prefill_chunk)
                             for r in pending)
            self._budget = (sum(r.max_new for r in pending) + chunks
                            + len(self._queue) + self.max_slots + 4)
            self._auto_budget = True
        steps = 0
        while self._queue or self._prefilling or self._active.any():
            if steps >= self._budget:
                self._drain_timeout()
                break
            self.step()
            steps += 1
        return dict(self._results)

    # -- introspection -----------------------------------------------------
    def reset_metrics(self) -> None:
        """Start a fresh measurement window (latency/throughput/overflow).

        Aggregates otherwise span the engine's whole lifetime — on an
        engine reused across waves, ``wall_s`` includes host idle time
        between ``run()`` calls, so reset before a wave you want to
        measure in isolation.
        """
        self.metrics = metrics.ServeMetrics()
        self._ovf = np.zeros(3, np.float64)

    def cache_stats(self) -> dict:
        """Append overflow rate over finished requests + in-flight slots."""
        live = kv_pool.overflow_summary(self._pool, self._active)
        ovf = self._ovf[0] + live["cache_overflow_rate"] * \
            live["cache_appends_quantized"]
        tot = self._ovf[2] + live["cache_appends_quantized"]
        return {"cache_overflow_rate": float(ovf / tot) if tot else 0.0,
                "cache_appends_quantized": float(tot)}

    def stats(self) -> dict:
        extra = self.cache_stats()
        if self._paged:
            extra.update(self._alloc.stats())
        return self.metrics.summary(extra=extra)
