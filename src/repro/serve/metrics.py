"""Serving metrics: per-request latency traces + engine aggregates.

Host-side and allocation-free on the hot path: the engine calls the
``on_*`` hooks with ``time.perf_counter`` stamps; ``summary()`` reduces to
the numbers a serving dashboard wants — TTFT, queue wait, aggregate
decode throughput — plus the packed pool's cumulative cache overflow rate
(see ``kv_pool.overflow_summary``) and the robustness counters the
admission-control/preemption/quarantine layer feeds (rejected, timed
out, preempted, failed, queue-depth high-water mark).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional


def _now() -> float:
    return time.perf_counter()


@dataclasses.dataclass
class RequestTrace:
    uid: int
    prompt_len: int
    t_submit: float
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_finish: Optional[float] = None
    new_tokens: int = 0
    prefill_chunks: int = 0
    preempts: int = 0
    status: Optional[str] = None      # terminal RequestStatus.value

    @property
    def queue_wait(self) -> Optional[float]:
        return None if self.t_admit is None else self.t_admit - self.t_submit

    @property
    def ttft(self) -> Optional[float]:
        return None if self.t_first is None else self.t_first - self.t_submit


class ServeMetrics:
    """Collects request traces; ``summary()`` aggregates them."""

    def __init__(self):
        self.traces: Dict[int, RequestTrace] = {}
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None
        self.decode_steps: int = 0
        self.rejected: int = 0
        self.timed_out: int = 0
        self.preemptions: int = 0     # preemption EVENTS (one uid may repeat)
        self.failed: int = 0          # quarantined (numeric sentinel) + OOM
        self.queue_depth_peak: int = 0

    # -- engine hooks -----------------------------------------------------
    def on_submit(self, uid: int, prompt_len: int) -> None:
        self.traces[uid] = RequestTrace(uid, prompt_len, _now())

    def on_admit(self, uid: int) -> None:
        tr = self.traces[uid]
        if tr.t_admit is None:        # re-admission after preemption keeps
            tr.t_admit = _now()       # the first admit stamp (true wait)
        if self.t_start is None:
            self.t_start = _now()

    def on_token(self, uid: int) -> None:
        tr = self.traces[uid]
        tr.new_tokens += 1
        if tr.t_first is None:
            tr.t_first = _now()

    def on_prefill_chunk(self, uid: int) -> None:
        """Chunked-prefill mode: one chunk of this request's prompt ran.

        TTFT semantics are unchanged — the first token still stamps
        ``t_first`` via :meth:`on_token` when the *final* chunk's logits
        are sampled — but the chunk count makes a long prompt's TTFT
        interpretable (chunks × step time, interleaved with decode).
        """
        self.traces[uid].prefill_chunks += 1

    def on_finish(self, uid: int, status: str = "ok") -> None:
        tr = self.traces[uid]
        tr.t_finish = self.t_end = _now()
        tr.status = status
        if status == "timed_out":
            self.timed_out += 1
        elif status == "failed":
            self.failed += 1

    def on_reject(self, uid: int) -> None:
        """Admission control bounced the request (queue full)."""
        tr = self.traces[uid]
        tr.t_finish = _now()
        tr.status = "rejected"
        self.rejected += 1

    def on_preempt(self, uid: int) -> None:
        """The request lost its slot/pages and went back to the queue."""
        self.traces[uid].preempts += 1
        self.preemptions += 1

    def on_decode_step(self) -> None:
        self.decode_steps += 1

    def observe_queue_depth(self, depth: int) -> None:
        self.queue_depth_peak = max(self.queue_depth_peak, depth)

    # -- aggregates -------------------------------------------------------
    def summary(self, extra: Optional[dict] = None) -> dict:
        done = [t for t in self.traces.values() if t.t_finish is not None]
        finished_ok = [t for t in done if t.status in (None, "ok")]
        new_tokens = sum(t.new_tokens for t in self.traces.values())
        wall = ((self.t_end or _now()) - self.t_start
                if self.t_start is not None else 0.0)
        ttfts = [t.ttft for t in self.traces.values() if t.ttft is not None]
        waits = [t.queue_wait for t in self.traces.values()
                 if t.queue_wait is not None]
        out = {
            "requests_submitted": len(self.traces),
            "requests_finished": len(finished_ok),
            "requests_rejected": self.rejected,
            "requests_timed_out": self.timed_out,
            "requests_failed": self.failed,
            "preemptions": self.preemptions,
            "queue_depth_peak": self.queue_depth_peak,
            "new_tokens": new_tokens,
            "decode_steps": self.decode_steps,
            "wall_s": wall,
            "tok_per_s": new_tokens / wall if wall > 0 else 0.0,
            "ttft_mean_s": sum(ttfts) / len(ttfts) if ttfts else 0.0,
            "ttft_max_s": max(ttfts) if ttfts else 0.0,
            "queue_wait_mean_s": sum(waits) / len(waits) if waits else 0.0,
            "queue_wait_max_s": max(waits) if waits else 0.0,
            "prefill_chunks": sum(t.prefill_chunks
                                  for t in self.traces.values()),
        }
        if extra:
            out.update(extra)
        return out
