"""End-to-end training behaviour: convergence, calibration, resume, packed."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecisionPolicy
from repro.data import SyntheticImages, SyntheticLM
from repro.models import maxout as MX
from repro.models import transformer as T
from repro.optim.opt import OptConfig, sgd_init
from repro.train import init_train_state, make_train_step
from repro.train.calibrate import calibrate
from repro.train.state import unpack_tree

CFG = MX.MaxoutConfig(hidden=(48, 48), pieces=3)
GS = MX.group_shapes(CFG)
OPT = OptConfig(kind="sgd", lr=0.1, lr_decay_steps=2000, max_col_norm=1.9365)
DATA = SyntheticImages()


def _loss_fn(policy):
    def loss_fn(p, b, s, exps):
        return MX.loss_fn(CFG, policy, p, b, exps, s,
                          rng=jax.random.PRNGKey(1))
    return loss_fn


def _train(policy, init_exp, steps=60, microbatches=1):
    params = MX.init_params(CFG, jax.random.PRNGKey(7))
    state = init_train_state(params, sgd_init(params), GS, policy,
                             init_exp=init_exp)
    step = jax.jit(make_train_step(_loss_fn(policy), GS, policy, OPT,
                                   microbatches=microbatches))
    losses = []
    for i in range(steps):
        b = DATA.batch(i, 64)
        state, m = step(state, {"x": jnp.asarray(b["x"]),
                                "y": jnp.asarray(b["y"])},
                        jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    return losses, state


def _calibrated(policy, steps=6):
    obs = dataclasses.replace(policy, arithmetic="observe", storage="sim")
    params0 = MX.init_params(CFG, jax.random.PRNGKey(7))

    def obs_loss(p, b, s, exps):
        return MX.loss_fn(CFG, obs, p, b, exps, s, rng=jax.random.PRNGKey(1))

    batches = ({"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}
               for b in (DATA.batch(i, 64) for i in range(steps + 1)))
    return calibrate(obs_loss, params0, GS, policy, OPT, batches, steps=steps)


def test_fp32_converges():
    losses, _ = _train(PrecisionPolicy("float32"), -8.0)
    assert losses[-1] < losses[0] * 0.3


def test_dfxp_10_12_matches_fp32():
    """The paper's headline: DFXP 10/12 trains as well as fp32."""
    pol = PrecisionPolicy("dfxp", comp_width=10, update_width=12,
                          update_interval=10)
    l32, _ = _train(PrecisionPolicy("float32"), -8.0)
    ldf, st = _train(pol, _calibrated(pol))
    assert ldf[-1] < l32[0] * 0.3
    assert ldf[-1] < l32[-1] + 0.5
    # scales actually moved from calibration values during training
    assert any(float(jnp.ravel(v)[0]) != 0.0 for v in st.scale.exps.values())


def test_packed_storage_trains_and_stays_on_grid():
    pol = PrecisionPolicy("dfxp", comp_width=10, update_width=12,
                          update_interval=10, storage="packed")
    losses, st = _train(pol, _calibrated(pol), steps=40)
    assert losses[-1] < losses[0] * 0.6
    from repro.core.packed import PackedArray
    leaves = [x for x in jax.tree.leaves(
        st.params, is_leaf=lambda n: isinstance(n, PackedArray))
        if isinstance(x, PackedArray)]
    assert leaves and all(l.mantissa.dtype == jnp.int16 for l in leaves)


def test_microbatched_equals_full_batch_fp32():
    """Grad accumulation is exact for the mean-loss objective (dropout off —
    the mask is shape-dependent, a documented semantic of microbatching)."""
    cfg = dataclasses.replace(CFG, dropout_input=0.0, dropout_hidden=0.0)
    pol = PrecisionPolicy("float32")

    def loss_fn(p, b, s, exps):
        return MX.loss_fn(cfg, pol, p, b, exps, s, rng=None)

    def train(microbatches):
        params = MX.init_params(cfg, jax.random.PRNGKey(7))
        state = init_train_state(params, sgd_init(params), GS, pol, -8.0)
        step = jax.jit(make_train_step(loss_fn, GS, pol, OPT,
                                       microbatches=microbatches))
        losses = []
        for i in range(5):
            b = DATA.batch(i, 64)
            state, m = step(state, {"x": jnp.asarray(b["x"]),
                                    "y": jnp.asarray(b["y"])},
                            jax.random.PRNGKey(i))
            losses.append(float(m["loss"]))
        return losses, state

    l1, s1 = train(1)
    l4, s4 = train(4)
    np.testing.assert_allclose(l1, l4, rtol=2e-4, atol=2e-4)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_sim_vs_packed_close():
    """Packed int16 storage matches the f32-container simulation closely
    (identical grids; packed only changes the container)."""
    pol_s = PrecisionPolicy("dfxp", comp_width=10, update_width=12,
                            update_interval=10, storage="sim")
    pol_p = dataclasses.replace(pol_s, storage="packed")
    init = _calibrated(pol_s)
    ls, ss = _train(pol_s, init, steps=20)
    lp, sp = _train(pol_p, init, steps=20)
    np.testing.assert_allclose(ls, lp, rtol=0.05, atol=0.05)
    w_s = ss.params["fc0"]["w"]
    w_p = unpack_tree(sp.params)["fc0"]["w"]
    assert float(jnp.mean(jnp.abs(w_s - w_p))) < 0.01


def test_lm_tiny_learns():
    """A tiny transformer LM under DFXP (calibrated, paper §9.3) learns the
    synthetic bigram chart."""
    cfg = T.ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=2, head_dim=16, d_ff=128,
                        vocab_size=128)
    gs = T.group_shapes(cfg)
    pol = PrecisionPolicy("dfxp", comp_width=10, update_width=12,
                          update_interval=10)
    opt = OptConfig(kind="adamw", lr=3e-3, lr_decay_steps=10_000)
    data = SyntheticLM(cfg.vocab_size, 32, 16, seed=0)

    obs = dataclasses.replace(pol, arithmetic="observe")

    def obs_loss(p, b, s, exps):
        return T.loss_fn(cfg, obs, p, b, exps, s)

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batches = ({"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}
               for b in (data.batch(i) for i in range(10)))
    init_exp = calibrate(obs_loss, params, gs, pol, opt, batches, steps=5)

    def loss_fn(p, b, s, exps):
        return T.loss_fn(cfg, pol, p, b, exps, s)

    from repro.optim.opt import adamw_init
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    state = init_train_state(params, adamw_init(params), gs, pol,
                             init_exp=init_exp)
    step = jax.jit(make_train_step(loss_fn, gs, pol, opt))
    first = None
    for i in range(80):
        b = data.batch(i)
        state, m = step(state, {"tokens": jnp.asarray(b["tokens"]),
                                "labels": jnp.asarray(b["labels"])},
                        jax.random.PRNGKey(i))
        if first is None:
            first = float(m["loss"])
    # unigram entropy of the zipf marginal is ~4.0; bigram structure lower
    assert float(m["loss"]) < first - 0.5, (first, float(m["loss"]))
