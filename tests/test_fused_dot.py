"""Differentiable fused DFXP matmul: dispatch layer + QTape.dot + train step.

Bit-equality contract (interpret mode): the fused custom-VJP path —
forward, input gradient (dgrad kernel), weight gradient (wgrad kernel) —
produces exactly the bits of the jnp composite / ``jax.grad`` of the
differentiable oracle, across widths, non-128-aligned and batched shapes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import DFXP_10_12
from repro.core.quant import new_sink
from repro.core.tape import QTape
from repro.kernels import dispatch
from repro.kernels.qmatmul.ops import qmm
from repro.kernels.qmatmul.ref import qmatmul_ref

WIDTHS = [8, 10, 12, 16]
MKN = [(64, 128, 256), (100, 130, 50), (8, 128, 128), (33, 65, 7)]


def _abr(key, M, K, N):
    ka, kb, kr = jax.random.split(jax.random.PRNGKey(key), 3)
    return (jax.random.normal(ka, (M, K)), jax.random.normal(kb, (K, N)) * 0.5,
            jax.random.normal(kr, (M, N)))


# ---------------------------------------------------------------------------
# kernel level: fused_dot vs jax.grad of the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", WIDTHS)
@pytest.mark.parametrize("mkn", MKN)
def test_fused_dot_fwd_and_grads_bit_equal(width, mkn):
    M, K, N = mkn
    a, b, r = _abr(0, M, K, N)
    e_a, e_b, e_g = jnp.float32(-6), jnp.float32(-7), jnp.float32(-5)

    def fused(a, b):
        return jnp.vdot(dispatch.fused_dot(
            a, b, e_a, e_b, width=width, grad_width=width, e_g=e_g,
            interpret=True), r)

    def ref(a, b):
        return jnp.vdot(qmatmul_ref(
            a, b, e_a, e_b, width=width, grad_width=width, e_g=e_g), r)

    yf = dispatch.fused_dot(a, b, e_a, e_b, width=width, interpret=True)
    yr = qmatmul_ref(a, b, e_a, e_b, width=width)
    np.testing.assert_array_equal(np.asarray(yf), np.asarray(yr))

    (da_f, db_f) = jax.grad(fused, (0, 1))(a, b)
    (da_r, db_r) = jax.grad(ref, (0, 1))(a, b)
    np.testing.assert_array_equal(np.asarray(da_f), np.asarray(da_r))
    np.testing.assert_array_equal(np.asarray(db_f), np.asarray(db_r))


def test_fused_dot_batched_and_transposed():
    B, S, D, V = 3, 37, 72, 56
    kx, kw, kr = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(kx, (B, S, D))
    w = jax.random.normal(kw, (V, D))
    r = jax.random.normal(kr, (B, S, V))
    e = jnp.float32(-6)

    def fused(x, w):
        return jnp.vdot(dispatch.fused_dot(
            x, w, e, e, width=10, grad_width=10, e_g=e, transpose_b=True,
            interpret=True), r)

    def ref(x, w):
        return jnp.vdot(qmatmul_ref(
            x.reshape(-1, D), w, e, e, width=10, grad_width=10, e_g=e,
            transpose_b=True), r.reshape(-1, V))

    yf = dispatch.fused_dot(x, w, e, e, width=10, transpose_b=True,
                            interpret=True)
    assert yf.shape == (B, S, V)
    yr = qmatmul_ref(x.reshape(-1, D), w, e, e, width=10, transpose_b=True)
    np.testing.assert_array_equal(np.asarray(yf).reshape(-1, V),
                                  np.asarray(yr))
    (dx_f, dw_f) = jax.grad(fused, (0, 1))(x, w)
    (dx_r, dw_r) = jax.grad(ref, (0, 1))(x, w)
    np.testing.assert_array_equal(np.asarray(dx_f), np.asarray(dx_r))
    np.testing.assert_array_equal(np.asarray(dw_f), np.asarray(dw_r))


def test_blocked_reduction_accumulator():
    """Multi-step reduction grid (VMEM accumulator path), quantized operands:
    the integer-grid products make blocked accumulation exact."""
    M, K, N = 48, 256, 64
    a, b, _ = _abr(3, M, K, N)
    e = jnp.float32(-5)
    c = qmm(a, b, e, e, kind="nn", width_a=10, width_b=10,
            blocks=(16, 64, 64), interpret=True)
    cr = qmatmul_ref(a, b, e, e, width=10)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))


# ---------------------------------------------------------------------------
# dispatch: backend detection + autotune cache
# ---------------------------------------------------------------------------

def test_backend_detection_interpret_on_cpu():
    assert dispatch.default_interpret() is True  # CI/dev containers: no TPU


def test_blocks_interpret_mode_exact_shapes():
    assert dispatch.blocks_for("nn", 100, 50, 130, interpret=True) \
        == (100, 50, 130)


def test_autotune_cache_bucketing():
    dispatch.reset_autotune()
    try:
        dispatch.set_autotune(measure=False)
        b1 = dispatch.blocks_for("nn", 120, 250, 70, interpret=False)
        assert dispatch.autotune_cache() == {("nn", 128, 256, 128): b1}
        # same bucket → cache hit; an injected entry wins
        dispatch.autotune_cache()[("nn", 128, 256, 128)] = (8, 128, 128)
        assert dispatch.blocks_for("nn", 100, 140, 100, interpret=False) \
            == (8, 128, 128)
        # different bucket → new entry
        dispatch.blocks_for("tn", 120, 250, 70, interpret=False)
        assert len(dispatch.autotune_cache()) == 2
    finally:
        dispatch.reset_autotune()
        dispatch.set_autotune(measure=True)


# ---------------------------------------------------------------------------
# QTape.dot: fused vs jnp composite, bit-identical
# ---------------------------------------------------------------------------

POL_C = DFXP_10_12
POL_F = dataclasses.replace(DFXP_10_12, fused_matmul=True)


def _tape_run(pol, x, w, r, transpose_b):
    def loss(x, w):
        tape = QTape(pol, {"w:d": jnp.float32(-5)}, {"g:d": new_sink()})
        y = tape.dot("d", x, w, transpose_b=transpose_b)
        return jnp.vdot(y, r), (y, tape.stats)

    (_, (y, stats)), (dx, dw) = jax.value_and_grad(
        loss, (0, 1), has_aux=True)(x, w)
    return y, dx, dw, stats


@pytest.mark.parametrize("shape,n,transpose_b", [
    ((6, 40, 72), 56, False),
    ((6, 40, 72), 56, True),
    ((2, 500, 64), 64, False),
    ((13, 130), 100, False),
])
def test_tape_dot_fused_bit_identical(shape, n, transpose_b):
    kx, kw, kr = jax.random.split(jax.random.PRNGKey(4), 3)
    K = shape[-1]
    x = jax.random.normal(kx, shape)
    w = jax.random.normal(kw, (n, K) if transpose_b else (K, n))
    r = jax.random.normal(kr, shape[:-1] + (n,))
    yc, dxc, dwc, stc = _tape_run(POL_C, x, w, r, transpose_b)
    yf, dxf, dwf, stf = _tape_run(POL_F, x, w, r, transpose_b)
    np.testing.assert_array_equal(np.asarray(yc), np.asarray(yf))
    np.testing.assert_array_equal(np.asarray(dxc), np.asarray(dxf))
    np.testing.assert_array_equal(np.asarray(dwc), np.asarray(dwf))
    np.testing.assert_array_equal(np.asarray(stc["w:d"]),
                                  np.asarray(stf["w:d"]))


def test_maxout_fused_matches_per_piece_loop():
    """The single [d_in, k·d_out] maxout matmul reproduces the k-loop bits."""
    from repro.models import layers as L
    pol = POL_C
    km, kx = jax.random.split(jax.random.PRNGKey(5))
    p = L.init_maxout(km, 72, 24, 3)
    x = jax.random.normal(kx, (5, 72))
    scales = {"w:m/w": jnp.float32(-5)}
    tape = QTape(pol, scales, {})
    h = L.maxout(p, x, tape, "m")
    tape2 = QTape(pol, scales, {})
    outs = [tape2.dot("m/w", x, p["w"][j]) + p["b"][j] for j in range(3)]
    h_ref = tape2.act("m/out", jnp.max(jnp.stack(outs, 0), axis=0))
    np.testing.assert_array_equal(np.asarray(h), np.asarray(h_ref))
    np.testing.assert_array_equal(np.asarray(tape.stats["w:m/w"]),
                                  np.asarray(tape2.stats["w:m/w"]))


# ---------------------------------------------------------------------------
# train step: 2-step loss bit-identity, fused on vs off (DFXP-10 policy)
# ---------------------------------------------------------------------------

def _two_step_losses(policy):
    from benchmarks.kernels_bench import (make_tiny_maxout_step,
                                          tiny_maxout_batch)

    step, state = make_tiny_maxout_step(policy)
    losses = []
    for i in range(2):
        state, m = step(state, tiny_maxout_batch(i), jax.random.PRNGKey(i))
        losses.append(np.asarray(m["loss"]))
    return losses, state


def test_train_step_loss_bit_identity_fused_on_off():
    losses_c, state_c = _two_step_losses(POL_C)
    losses_f, state_f = _two_step_losses(POL_F)
    np.testing.assert_array_equal(losses_c[0], losses_f[0])
    np.testing.assert_array_equal(losses_c[1], losses_f[1])
    # parameters after two updates agree bit-for-bit too
    flat_c = jax.tree_util.tree_leaves(state_c.params)
    flat_f = jax.tree_util.tree_leaves(state_f.params)
    for c, f in zip(flat_c, flat_f):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(f))
