"""Serving metrics: per-request latency traces + engine aggregates.

Host-side and allocation-free on the hot path: the engine calls the
``on_*`` hooks with ``time.perf_counter`` stamps; ``summary()`` reduces to
the numbers a serving dashboard wants — TTFT, queue wait, aggregate
decode throughput — plus the packed pool's cumulative cache overflow rate
(see ``kv_pool.overflow_summary``) and the robustness counters the
admission-control/preemption/quarantine layer feeds (rejected, timed
out, preempted, failed, queue-depth high-water mark).

Timestamps come from ``time.perf_counter()`` — monotonic, so TTFT and
queue-wait survive NTP steps and wall-clock slews (stamps are deltas
against other stamps from the same process, never absolute times).

Every hook also records into a :class:`repro.obs.metrics.MetricsRegistry`
(``self.registry``): counters for the robustness events, a queue-depth
gauge, and log-bucketed histograms (TTFT, queue wait, inter-decode-step
latency, per-request tok/s) — the series ``launch.serve --metrics-port``
exposes as Prometheus text and ``--metrics-out`` snapshots as JSONL.
``summary()`` still aggregates from the per-request traces, so its
schema and values are unchanged by the registry.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

from repro.obs.metrics import MetricsRegistry


def _now() -> float:
    return time.perf_counter()


@dataclasses.dataclass
class RequestTrace:
    uid: int
    prompt_len: int
    t_submit: float
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_finish: Optional[float] = None
    new_tokens: int = 0
    prefill_chunks: int = 0
    preempts: int = 0
    status: Optional[str] = None      # terminal RequestStatus.value

    @property
    def queue_wait(self) -> Optional[float]:
        return None if self.t_admit is None else self.t_admit - self.t_submit

    @property
    def ttft(self) -> Optional[float]:
        return None if self.t_first is None else self.t_first - self.t_submit


class ServeMetrics:
    """Collects request traces; ``summary()`` aggregates them.

    Event counts live in ``self.registry`` (shared with the CLI's
    Prometheus endpoint when one is passed in); the legacy attribute
    names (``decode_steps``, ``rejected``...) remain as read-only
    properties over the registry.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.traces: Dict[int, RequestTrace] = {}
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None
        self._t_last_step: Optional[float] = None
        r = self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._c_submitted = r.counter(
            "serve_requests_submitted", "requests entered via submit()")
        self._c_finished = r.counter(
            "serve_requests_finished", "requests resolved OK")
        self._c_rejected = r.counter(
            "serve_requests_rejected", "admission control bounces")
        self._c_timed_out = r.counter(
            "serve_requests_timed_out", "deadline / drain expiries")
        self._c_failed = r.counter(
            "serve_requests_failed", "quarantined / exhausted requests")
        self._c_preempt = r.counter(
            "serve_preemptions", "page-pressure eviction events")
        self._c_tokens = r.counter(
            "serve_new_tokens", "generated tokens across requests")
        self._c_steps = r.counter(
            "serve_decode_steps", "batched decode steps run")
        self._c_chunks = r.counter(
            "serve_prefill_chunks", "prefill chunks run")
        self._g_queue = r.gauge(
            "serve_queue_depth", "waiting queue length at last submit")
        self._h_ttft = r.histogram(
            "serve_ttft_seconds", "submit -> first token")
        self._h_wait = r.histogram(
            "serve_queue_wait_seconds", "submit -> first admission")
        self._h_step = r.histogram(
            "serve_decode_step_seconds", "inter-decode-step latency")
        self._h_tps = r.histogram(
            "serve_request_tok_per_s", "per-request decode throughput",
            lo=0.25)

    # -- legacy attribute views over the registry --------------------------
    @property
    def decode_steps(self) -> int:
        return int(self._c_steps.value)

    @property
    def rejected(self) -> int:
        return int(self._c_rejected.value)

    @property
    def timed_out(self) -> int:
        return int(self._c_timed_out.value)

    @property
    def preemptions(self) -> int:
        # preemption EVENTS (one uid may repeat)
        return int(self._c_preempt.value)

    @property
    def failed(self) -> int:
        # quarantined (numeric sentinel) + OOM
        return int(self._c_failed.value)

    @property
    def queue_depth_peak(self) -> int:
        return int(self._g_queue.peak)

    # -- engine hooks -----------------------------------------------------
    def on_submit(self, uid: int, prompt_len: int) -> None:
        self.traces[uid] = RequestTrace(uid, prompt_len, _now())
        self._c_submitted.inc()

    def on_admit(self, uid: int) -> None:
        tr = self.traces[uid]
        if tr.t_admit is None:        # re-admission after preemption keeps
            tr.t_admit = _now()       # the first admit stamp (true wait)
            self._h_wait.observe(tr.queue_wait)
        if self.t_start is None:
            self.t_start = _now()

    def on_token(self, uid: int) -> None:
        tr = self.traces[uid]
        tr.new_tokens += 1
        self._c_tokens.inc()
        if tr.t_first is None:
            tr.t_first = _now()
            self._h_ttft.observe(tr.ttft)

    def on_prefill_chunk(self, uid: int) -> None:
        """Chunked-prefill mode: one chunk of this request's prompt ran.

        TTFT semantics are unchanged — the first token still stamps
        ``t_first`` via :meth:`on_token` when the *final* chunk's logits
        are sampled — but the chunk count makes a long prompt's TTFT
        interpretable (chunks × step time, interleaved with decode).
        """
        self.traces[uid].prefill_chunks += 1
        self._c_chunks.inc()

    def on_finish(self, uid: int, status: str = "ok") -> None:
        tr = self.traces[uid]
        tr.t_finish = self.t_end = _now()
        tr.status = status
        if status == "timed_out":
            self._c_timed_out.inc()
        elif status == "failed":
            self._c_failed.inc()
        elif status == "ok":
            self._c_finished.inc()
        if tr.t_admit is not None and tr.new_tokens:
            span = tr.t_finish - tr.t_admit
            if span > 0:
                self._h_tps.observe(tr.new_tokens / span)

    def on_reject(self, uid: int) -> None:
        """Admission control bounced the request (queue full)."""
        tr = self.traces[uid]
        tr.t_finish = _now()
        tr.status = "rejected"
        self._c_rejected.inc()

    def on_preempt(self, uid: int) -> None:
        """The request lost its slot/pages and went back to the queue."""
        self.traces[uid].preempts += 1
        self._c_preempt.inc()

    def on_decode_step(self) -> None:
        self._c_steps.inc()
        t = _now()
        if self._t_last_step is not None:
            self._h_step.observe(t - self._t_last_step)
        self._t_last_step = t

    def observe_queue_depth(self, depth: int) -> None:
        self._g_queue.set(depth)

    # -- aggregates -------------------------------------------------------
    def summary(self, extra: Optional[dict] = None) -> dict:
        done = [t for t in self.traces.values() if t.t_finish is not None]
        finished_ok = [t for t in done if t.status in (None, "ok")]
        new_tokens = sum(t.new_tokens for t in self.traces.values())
        wall = ((self.t_end or _now()) - self.t_start
                if self.t_start is not None else 0.0)
        ttfts = [t.ttft for t in self.traces.values() if t.ttft is not None]
        waits = [t.queue_wait for t in self.traces.values()
                 if t.queue_wait is not None]
        out = {
            "requests_submitted": len(self.traces),
            "requests_finished": len(finished_ok),
            "requests_rejected": self.rejected,
            "requests_timed_out": self.timed_out,
            "requests_failed": self.failed,
            "preemptions": self.preemptions,
            "queue_depth_peak": self.queue_depth_peak,
            "new_tokens": new_tokens,
            "decode_steps": self.decode_steps,
            "wall_s": wall,
            "tok_per_s": new_tokens / wall if wall > 0 else 0.0,
            "ttft_mean_s": sum(ttfts) / len(ttfts) if ttfts else 0.0,
            "ttft_max_s": max(ttfts) if ttfts else 0.0,
            "queue_wait_mean_s": sum(waits) / len(waits) if waits else 0.0,
            "queue_wait_max_s": max(waits) if waits else 0.0,
            "prefill_chunks": sum(t.prefill_chunks
                                  for t in self.traces.values()),
        }
        if extra:
            out.update(extra)
        return out
