"""Emit the EXPERIMENTS.md §Dry-run and §Roofline tables from results.

``--serve-json BENCH_serve.json`` switches to the serve-observability
report instead: renders the TTFT and per-request tok/s histograms that
``benchmarks/serve_bench.py`` snapshots into the file's ``"obs"`` key
(one ``repro.obs`` metrics-registry snapshot per timed row).
"""
from __future__ import annotations

import argparse
import json

_SERVE_HISTS = [("serve_ttft_seconds", "TTFT (s)"),
                ("serve_request_tok_per_s", "per-request tok/s")]


def _ascii_hist(state: dict, width: int = 36) -> list:
    """Render one obs histogram snapshot as `[lo, hi) bar count` lines.

    ``state`` is ``repro.obs.Histogram.state()``: ``counts`` has an
    underflow bucket at [0], overflow at [-1], and ``counts[i + 1]``
    covering ``[edges[i], edges[i + 1])``.
    """
    edges, counts = state["edges"], state["counts"]
    rows = []
    if counts[0]:
        rows.append((f"< {edges[0]:.4g}", counts[0]))
    for i, c in enumerate(counts[1:-1]):
        if c:
            rows.append((f"[{edges[i]:.4g}, {edges[i + 1]:.4g})", c))
    if counts[-1]:
        rows.append((f">= {edges[-1]:.4g}", counts[-1]))
    if not rows:
        return ["  (empty)"]
    peak = max(c for _, c in rows)
    label_w = max(len(lbl) for lbl, _ in rows)
    return [f"  {lbl:<{label_w}} {'#' * max(1, c * width // peak):<{width}}"
            f" {c}" for lbl, c in rows]


def serve_report(path: str) -> None:
    payload = json.load(open(path))
    obs = payload.get("obs")
    if not obs:
        raise SystemExit(f"{path} has no 'obs' key — re-record with a "
                         "benchmarks/run.py that snapshots serve metrics")
    for row_name in sorted(obs):
        snap = obs[row_name]
        print(f"\n### {row_name}")
        for metric, title in _SERVE_HISTS:
            st = snap.get(metric)
            if st is None or st.get("type") != "histogram":
                continue
            mean = st["sum"] / st["count"] if st["count"] else 0.0
            print(f"{title}: n={st['count']} mean={mean:.4g} "
                  f"min={st['min']} max={st['max']}")
            print("\n".join(_ascii_hist(st)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.jsonl")
    ap.add_argument("--serve-json", default="",
                    help="render TTFT/tok-s histograms from a "
                         "BENCH_serve.json recorded with obs snapshots")
    args = ap.parse_args()

    if args.serve_json:
        serve_report(args.serve_json)
        return

    from .roofline import NOTES, analyse

    seen = {}
    for line in open(args.results):
        r = json.loads(line)
        if r.get("ok"):
            seen[(r["arch"], r["shape"], r["mesh"])] = r

    recs = sorted(seen.values(), key=lambda r: (r["arch"], r["shape"],
                                                r["mesh"]))
    print("### Dry-run (per-device, from the compiled artifact)\n")
    print("| arch | shape | mesh | compile_s | args_GB | temp_GB | "
          "flops/dev | HBM_GB/dev | coll_GB/dev | a2a | ag | ar |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        la = r.get("loop_aware", {})
        kinds = la.get("collective_by_kind", {})
        pd = r["per_device"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r['compile_s']:.0f} "
              f"| {pd['argument_bytes']/2**30:.2f} "
              f"| {pd['temp_bytes']/2**30:.2f} "
              f"| {la.get('flops', 0):.2e} "
              f"| {la.get('traffic_bytes', 0)/1e9:.1f} "
              f"| {la.get('collective_bytes', 0)/1e9:.2f} "
              f"| {kinds.get('all-to-all', 0)/1e9:.1f} "
              f"| {kinds.get('all-gather', 0)/1e9:.1f} "
              f"| {kinds.get('all-reduce', 0)/1e9:.1f} |")

    print("\n### Roofline (v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    print("| arch | shape | mesh | compute_s | memory_s | coll_s | "
          "bottleneck | useful | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        a = analyse(r)
        print(f"| {a['arch']} | {a['shape']} | {a['mesh']} "
              f"| {a['compute_s']:.3g} | {a['memory_s']:.3g} "
              f"| {a['coll_s']:.3g} | **{a['bottleneck']}** "
              f"| {a['useful_ratio']:.2f} "
              f"| {NOTES[a['bottleneck']].split(':')[0]} |")


if __name__ == "__main__":
    main()
