"""Packed int-mantissa storage for parameters/optimizer state (beyond paper).

The paper *simulates* narrow storage inside float32 containers (§7). On real
hardware the 12-bit parameter store is the point: a 400B-parameter model's
masters + momentum shrink from 3.2 TB (f32) to 1.6 TB (int16) — the
difference between fitting a 256-chip v5e pod or not.

``PackedArray`` is a pytree holding an int8/int16 mantissa tensor plus its
group's log2-step. ``pack``/``unpack`` are elementwise and fuse with the
surrounding optimizer math, so wide intermediates never materialize at full
model size.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .quant import exact_pow2

Array = jax.Array


def container_dtype(width: int):
    if width <= 8:
        return jnp.int8
    if width <= 16:
        return jnp.int16
    return jnp.int32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedArray:
    """int mantissa + log2-step; represents ``mantissa * 2**exp``."""

    mantissa: Array                     # int8/int16/int32
    exp: Array                          # f32 scalar (integer-valued)
    width: int = dataclasses.field(metadata=dict(static=True), default=16)

    @property
    def shape(self):
        return self.mantissa.shape

    @property
    def size(self):
        return self.mantissa.size


def pack(x: Array, width: int, e: Array, *, stochastic_key=None) -> PackedArray:
    e = jnp.asarray(e, jnp.float32)
    step = exact_pow2(e)
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))
    m = x.astype(jnp.float32) / step
    if stochastic_key is not None:
        u = jax.random.uniform(stochastic_key, m.shape, jnp.float32)
        m = jnp.floor(m + u)
    else:
        m = jnp.round(m)
    m = jnp.clip(m, qmin, qmax)
    return PackedArray(m.astype(container_dtype(width)), e, width)


def unpack(p: PackedArray, dtype=jnp.float32) -> Array:
    return (p.mantissa.astype(jnp.float32) * exact_pow2(p.exp)).astype(dtype)


def pack_overflow_stats(x: Array, width: int, e: Array) -> Array:
    """Same (ovf, ovf_half, total) triple as quant.fixed_round, for packing."""
    e = jnp.asarray(e, jnp.float32)
    qmax = float(2 ** (width - 1) - 1)
    m = jnp.round(x.astype(jnp.float32) / exact_pow2(e))
    ovf = jnp.sum(jnp.abs(m) > qmax, dtype=jnp.float32)
    ovfh = jnp.sum(jnp.abs(m) > qmax / 2, dtype=jnp.float32)
    return jnp.stack([ovf, ovfh, jnp.float32(x.size)])
