"""jit'd wrapper around the DFXP quantize kernel: any-shape in, padded tiles.

``dfxp_quantize(x, e, width)`` accepts any shape/f32-f16-bf16 dtype; it
reshapes to 2D, pads to tile multiples (pad values quantize to 0 and are
excluded from overflow counts by construction — 0 never overflows), runs
the Pallas kernel, and unpads.

On CPU (no TPU available) ``interpret=True`` executes the kernel body in
Python — numerically identical, used by tests/benchmarks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import exact_pow2

from .dfxp_kernel import dfxp_quantize_2d


def _pick_blocks(M: int, N: int):
    bn = 128
    while bn * 2 <= min(N, 512):
        bn *= 2
    bm = 8
    while bm * 2 <= min(M, 256):
        bm *= 2
    return bm, bn


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def dfxp_quantize(x, e, *, width: int, interpret: bool = True):
    """Fused quantize+stats. Returns (y, stats[2])."""
    orig_shape = x.shape
    n = x.size
    if x.ndim >= 2 and orig_shape[-1] % 128 == 0:
        # keep the natural lane dim when it's already aligned
        N = orig_shape[-1]
        M = n // N
        x2 = x.reshape(M, N)
        bm, bn = _pick_blocks(M, N)
        pm, pn = (-M) % bm, (-N) % bn
        if pm or pn:
            x2 = jnp.pad(x2, ((0, pm), (0, pn)))
    else:
        # flatten + pad (pads quantize to 0 and never overflow)
        N = 128 if n < 512 * 8 else 512
        M = -(-n // N)
        bm, bn = _pick_blocks(M, N)
        M = (M + bm - 1) // bm * bm
        flat = jnp.pad(x.reshape(-1), (0, M * N - n))
        x2 = flat.reshape(M, N)
        pm = pn = 0

    step = exact_pow2(e)
    inv_step = exact_pow2(-jnp.asarray(e, jnp.float32))
    y, stats = dfxp_quantize_2d(x2, step, inv_step, width=width,
                                block_m=bm, block_n=bn, interpret=interpret)
    if x.ndim >= 2 and orig_shape[-1] % 128 == 0:
        if pm or pn:
            y = y[:y.shape[0] - pm if pm else None, :N]
            y = y[: (n // N), :N]
        return y.reshape(orig_shape), stats
    return y.reshape(-1)[:n].reshape(orig_shape), stats
