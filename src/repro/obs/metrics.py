"""Metrics registry: counters, gauges, log-bucketed histograms.

Dependency-free (stdlib only) and host-side.  Three instrument types:

* :class:`Counter` — monotonically increasing total;
* :class:`Gauge` — last-set value, with a high-water mark (``peak``);
* :class:`Histogram` — log-bucketed (powers of ``base`` from ``lo``):
  the right shape for latency/throughput series whose interesting range
  spans orders of magnitude (TTFT, queue wait, step latency, tok/s).
  Bucket ``i`` covers ``[lo * base**i, lo * base**(i+1))``; values below
  ``lo`` land in an underflow bucket, values at/above the last edge in an
  overflow bucket.  ``sum``/``count``/``min``/``max`` ride along so means
  stay exact.

A :class:`MetricsRegistry` is a named collection with three outputs:

* :meth:`snapshot` — a JSON-able dict of every instrument's state;
* :meth:`snapshot_jsonl` — appends one timestamped snapshot line to a
  file (the periodic series ``launch.serve --metrics-out`` records);
* :meth:`prometheus_text` — the Prometheus text exposition format,
  served by :func:`start_http_server` over a stdlib ``http.server``
  endpoint (``launch.serve --metrics-port``) — no client library needed,
  ``curl localhost:PORT/metrics`` or point a Prometheus scraper at it.

Instruments are cheap enough for per-token paths (a float add / compare;
histogram observe is a ``log`` + list index), but the serve engine still
only calls them from host-side bookkeeping it already does — the
zero-cost-when-disabled contract of :mod:`repro.obs` is about device
syncs, which nothing in this module performs.
"""
from __future__ import annotations

import json
import math
import threading
import time
from typing import Dict, List, Optional


class Counter:
    __slots__ = ("name", "help", "_v")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._v = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self._v += n

    @property
    def value(self) -> float:
        return self._v


class Gauge:
    __slots__ = ("name", "help", "_v", "_peak")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._v = 0.0
        self._peak = 0.0

    def set(self, v: float) -> None:
        self._v = v
        if v > self._peak:
            self._peak = v

    @property
    def value(self) -> float:
        return self._v

    @property
    def peak(self) -> float:
        return self._peak


class Histogram:
    """Log-bucketed histogram over ``[lo, lo * base**n_buckets)``.

    ``edges`` are the ``n_buckets + 1`` bucket boundaries; ``counts`` has
    ``n_buckets + 2`` entries — ``counts[0]`` is the underflow bucket
    (``v < lo``), ``counts[-1]`` the overflow bucket (``v >= edges[-1]``),
    and ``counts[i + 1]`` covers ``[edges[i], edges[i + 1])``.
    """

    __slots__ = ("name", "help", "lo", "base", "edges", "counts",
                 "sum", "count", "min", "max")

    def __init__(self, name: str, help: str = "", *, lo: float = 1e-4,
                 n_buckets: int = 24, base: float = 2.0):
        if lo <= 0 or base <= 1 or n_buckets < 1:
            raise ValueError("need lo > 0, base > 1, n_buckets >= 1")
        self.name, self.help = name, help
        self.lo, self.base = float(lo), float(base)
        self.edges: List[float] = [lo * base ** i
                                   for i in range(n_buckets + 1)]
        self.counts: List[int] = [0] * (n_buckets + 2)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v < self.lo:
            self.counts[0] += 1
        else:
            n = len(self.edges) - 1
            i = min(int(math.log(v / self.lo) / math.log(self.base)), n)
            # float log can land one bucket off at exact edges — fix up
            if i < n and v >= self.edges[i + 1]:
                i += 1
            elif v < self.edges[i]:
                i -= 1
            if i >= n:
                self.counts[-1] += 1
            else:
                self.counts[i + 1] += 1

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (geometric-mid of the
        target bucket; exact min/max for q=0/1)."""
        if not self.count:
            return 0.0
        if q <= 0:
            return self.min
        if q >= 1:
            return self.max
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                if i == 0:
                    return min(self.lo, self.max)
                if i == len(self.counts) - 1:
                    return self.max
                return math.sqrt(self.edges[i - 1] * self.edges[i])
        return self.max

    def state(self) -> dict:
        return {"type": "histogram", "lo": self.lo, "base": self.base,
                "edges": list(self.edges), "counts": list(self.counts),
                "sum": self.sum, "count": self.count,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None}


class MetricsRegistry:
    """Named instrument collection with JSONL + Prometheus outputs."""

    def __init__(self):
        self._m: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._m.get(name)
        if m is None:
            m = cls(name, help, **kw) if kw else cls(name, help)
            self._m[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "", *, lo: float = 1e-4,
                  n_buckets: int = 24, base: float = 2.0) -> Histogram:
        return self._get(Histogram, name, help, lo=lo, n_buckets=n_buckets,
                         base=base)

    def __contains__(self, name: str) -> bool:
        return name in self._m

    # -- outputs ----------------------------------------------------------
    def snapshot(self) -> dict:
        out: Dict[str, object] = {}
        for name, m in sorted(self._m.items()):
            if isinstance(m, Counter):
                out[name] = {"type": "counter", "value": m.value}
            elif isinstance(m, Gauge):
                out[name] = {"type": "gauge", "value": m.value,
                             "peak": m.peak}
            else:
                out[name] = m.state()
        return out

    def snapshot_jsonl(self, path_or_file, extra: Optional[dict] = None,
                       ) -> None:
        """Append one ``{"t": ..., **extra, "metrics": snapshot}`` line."""
        rec = {"t": time.time()}
        if extra:
            rec.update(extra)
        rec["metrics"] = self.snapshot()
        line = json.dumps(rec) + "\n"
        if hasattr(path_or_file, "write"):
            path_or_file.write(line)
        else:
            with open(path_or_file, "a") as f:
                f.write(line)

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (histograms cumulative)."""
        lines: List[str] = []
        for name, m in sorted(self._m.items()):
            if isinstance(m, Counter):
                lines += [f"# HELP {name} {m.help}".rstrip(),
                          f"# TYPE {name} counter",
                          f"{name} {_fmt(m.value)}"]
            elif isinstance(m, Gauge):
                lines += [f"# HELP {name} {m.help}".rstrip(),
                          f"# TYPE {name} gauge",
                          f"{name} {_fmt(m.value)}",
                          f"{name}_peak {_fmt(m.peak)}"]
            else:
                lines += [f"# HELP {name} {m.help}".rstrip(),
                          f"# TYPE {name} histogram"]
                cum = m.counts[0]
                for e, c in zip(m.edges[1:], m.counts[1:-1]):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{_fmt(e)}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {m.count}')
                lines += [f"{name}_sum {_fmt(m.sum)}",
                          f"{name}_count {m.count}"]
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def start_http_server(registry: MetricsRegistry, port: int = 0,
                      host: str = "127.0.0.1"):
    """Serve ``registry.prometheus_text()`` at ``/metrics`` (stdlib only).

    Runs a daemon thread; returns the ``HTTPServer`` (read the bound port
    from ``server.server_address[1]`` — ``port=0`` picks an ephemeral
    one; call ``server.shutdown()`` to stop).
    """
    from http.server import BaseHTTPRequestHandler, HTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):   # noqa: N802 (stdlib API name)
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_error(404)
                return
            body = registry.prometheus_text().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):   # keep the serve CLI's stdout clean
            pass

    server = HTTPServer((host, port), Handler)
    t = threading.Thread(target=server.serve_forever, daemon=True,
                         name="repro-obs-metrics")
    t.start()
    return server


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "start_http_server"]
