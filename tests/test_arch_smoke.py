"""Per-architecture smoke tests: reduced same-family config, one train (or
forward) step on CPU, asserting output shapes and no NaNs — as required for
every assigned architecture."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import PrecisionPolicy
from repro.data import SyntheticLM
from repro.models import transformer as T
from repro.optim.opt import OptConfig, sgd_init
from repro.train import init_train_state, make_train_step

POLICY = PrecisionPolicy("dfxp", comp_width=10, update_width=12,
                         update_interval=5)
B, S = 2, 32


def _batch(cfg, key):
    if cfg.input_mode == "tokens":
        lm = SyntheticLM(cfg.vocab_size, S, B, seed=0)
        b = lm.batch(0)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
    else:
        batch = {"embeds": jax.random.normal(key, (B, S, cfg.d_model)) * 0.1,
                 "labels": jax.random.randint(key, (B, S), 0,
                                              cfg.vocab_size)}
        if cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
    if cfg.encoder_layers:
        batch["src_embeds"] = jax.random.normal(key, (B, 16, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    gs = T.group_shapes(cfg)
    opt_cfg = OptConfig(kind="sgd", lr=0.01, lr_decay_steps=100)
    state = init_train_state(params, sgd_init(params), gs, POLICY,
                             init_exp=-12.0)

    def loss_fn(p, b, s, exps):
        return T.loss_fn(cfg, POLICY, p, b, exps, s)

    step = jax.jit(make_train_step(loss_fn, gs, POLICY, opt_cfg))
    batch = _batch(cfg, key)
    state2, metrics = step(state, batch, key)
    assert jnp.isfinite(metrics["loss"]), f"{arch}: non-finite loss"
    assert jnp.isfinite(metrics["grad_norm"]), f"{arch}: non-finite grads"
    assert int(state2.step) == 1
    # params changed and stayed finite
    moved = jax.tree.map(lambda a, b: jnp.any(a != b), state.params,
                         state2.params)
    assert any(bool(x) for x in jax.tree.leaves(moved)), f"{arch}: no update"
    for leaf in jax.tree.leaves(state2.params):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch}: non-finite param"


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_forward_shapes(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    gs = T.group_shapes(cfg)
    from repro.core import ScaleState
    st = ScaleState.create(gs, -6.0)
    sinks = {n: jnp.zeros(s + (3,), jnp.float32) for n, s in gs.items()
             if n.startswith("g:")}
    batch = _batch(cfg, key)
    batch.pop("labels")
    logits, stats, _ = T.forward(cfg, PrecisionPolicy("float32"), params,
                                 batch, st.exps, sinks, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ["llama3_8b", "mamba2_370m",
                                  "granite_moe_1b", "zamba2_1p2b",
                                  "seamless_m4t_medium"])
def test_arch_smoke_decode(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    gs = T.group_shapes(cfg)
    from repro.core import ScaleState
    st = ScaleState.create(gs, -6.0)
    sinks = {n: jnp.zeros(s + (3,), jnp.float32) for n, s in gs.items()
             if n.startswith("g:")}
    pol = PrecisionPolicy("float32")
    batch = _batch(cfg, key)
    batch.pop("labels")
    _, _, cache = T.prefill(cfg, pol, params, batch, st.exps, sinks,
                            max_cache_len=S + 8)
    tok = jnp.zeros((B,), jnp.int32)
    logits, _, cache2 = T.decode_step(cfg, pol, params, cache, tok, S,
                                      st.exps, sinks)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
