"""Numeric formats from the paper (+ beyond-paper float8).

Three arithmetic families (paper §3-§5):
  * ``FloatFormat``   — float with ``exp_bits``/``man_bits`` (fp32 reference,
    fp16/bf16, fp8 beyond-paper). Emulated by value-rounding in f32.
  * ``FixedPoint``    — one *global, never-updated* power-of-two scale.
    Parameterized by total ``width`` (incl. sign) and ``int_bits`` (bits left
    of the radix point; paper Fig.1 optimum: 5 → range ≈ ±32).
  * ``DynamicFixedPoint`` — per-group scales updated online from overflow
    statistics (paper §5). The scale is carried *outside* the format (in
    :class:`repro.core.scale.ScaleState`); the format only fixes the width.

All formats are frozen/hashable so they can be static args under ``jit``.

Conventions:
  * A fixed-point grid with log2-step ``e`` represents ``k * 2**e`` for
    integer ``k`` in ``[-2**(width-1), 2**(width-1) - 1]`` (two's-complement,
    like the paper's signed mantissa).
  * "scaling factor × 2" in the paper == ``e + 1`` here (wider range,
    coarser step).
"""
from __future__ import annotations

import dataclasses
from typing import Union


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """IEEE-like float with given exponent/mantissa widths (sign implied)."""

    name: str
    exp_bits: int
    man_bits: int

    @property
    def width(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    @property
    def emax(self) -> int:
        return 2 ** (self.exp_bits - 1) - 1

    @property
    def emin(self) -> int:
        return 1 - self.emax

    @property
    def maxval(self) -> float:
        return float((2.0 - 2.0 ** (-self.man_bits)) * 2.0 ** self.emax)


@dataclasses.dataclass(frozen=True)
class FixedPoint:
    """Static fixed point: global radix position, never updated (paper §4)."""

    width: int          # total bits incl. sign
    int_bits: int = 5   # bits left of the radix point (paper Fig.1: 5)

    @property
    def exp(self) -> int:
        """log2 of the quantization step for this radix position."""
        # width-1 magnitude bits; int_bits of them left of the radix point.
        return self.int_bits - (self.width - 1)

    @property
    def qmax(self) -> int:
        return 2 ** (self.width - 1) - 1


@dataclasses.dataclass(frozen=True)
class DynamicFixedPoint:
    """Dynamic fixed point: width only; scale lives in ScaleState (paper §5)."""

    width: int

    @property
    def qmax(self) -> int:
        return 2 ** (self.width - 1) - 1


@dataclasses.dataclass(frozen=True)
class Observe:
    """Calibration pseudo-format: values pass through untouched; statistics
    record per-group max magnitudes instead of overflow counts. Implements
    the paper's §9.3 "find the initial scaling factors by training with a
    higher precision format"."""


Format = Union[FloatFormat, FixedPoint, DynamicFixedPoint, Observe, None]

# Named float formats (paper Table 1 + beyond-paper fp8).
FLOAT32 = FloatFormat("float32", 8, 23)
FLOAT16 = FloatFormat("float16", 5, 10)
BFLOAT16 = FloatFormat("bfloat16", 8, 7)
FLOAT8_E4M3 = FloatFormat("float8_e4m3", 4, 3)
FLOAT8_E5M2 = FloatFormat("float8_e5m2", 5, 2)

FLOAT_FORMATS = {
    f.name: f for f in (FLOAT32, FLOAT16, BFLOAT16, FLOAT8_E4M3, FLOAT8_E5M2)
}


def container_exact_bits(container: str) -> int:
    """Max DFXP width a float container holds exactly (incl. sign)."""
    return {"float32": 25, "float16": 12, "bfloat16": 9}[container]
