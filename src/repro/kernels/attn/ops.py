"""jit'd wrapper for the fused decode-attention kernel: shapes + dispatch.

Owns everything the kernel body stays agnostic of: backend detection
(compiled Pallas on TPU, interpret elsewhere —
:func:`repro.kernels._tiling.resolve_interpret`), split-size selection
through the dispatch layer's shape-bucketed autotune cache
(:func:`repro.kernels.dispatch.attn_blocks_for`), and the dequant-step
packing (``2**e`` built with the bit-exact
:func:`repro.core.quant.exact_pow2`, the same grid the codec's quantizer
used on append).  The K/V buffers are handed to the kernel **as stored**
— never padded or copied; a ragged last split is masked in-kernel by
slot index, because any host-side reshape of the pool would re-spend the
HBM round-trip the fusion saves.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro._jax_compat import ambient_mesh
from repro.core.quant import exact_pow2
from repro.kernels import dispatch
from repro.kernels._tiling import resolve_interpret

from .attn_kernel import flash_decode_call, flash_decode_paged_call
from .prefill_kernel import flash_prefill_call, flash_prefill_paged_call

Array = jax.Array


def _tp_size(tp_axis: Optional[str], n_kv_heads: int) -> int:
    """Live TP degree for the fused kernels.

    Returns the ambient-mesh size of ``tp_axis`` when the axis exists,
    is larger than 1, and evenly divides the kv-head count; 0 otherwise
    — the caller then runs the unsharded kernel (same numerics, pool
    replicated by the sharding guard under the same condition).
    """
    if not tp_axis:
        return 0
    mesh = ambient_mesh()
    if mesh is None or tp_axis not in mesh.shape:
        return 0
    size = int(mesh.shape[tp_axis])
    return size if size > 1 and n_kv_heads % size == 0 else 0


def flash_decode(q: Array, k: Array, v: Array, pos: Array, q_pos: Array,
                 k_exp=None, v_exp=None, *, width: Optional[int] = None,
                 scale: float, window: Optional[int] = None,
                 causal: bool = True, block_w: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 tp_axis: Optional[str] = None) -> Array:
    """Fused single-query GQA attention over a (packed) KV ring buffer.

    ``q``: [B, K, G, hd] (kv-head-major query groups, i.e.
    ``q4.reshape(B, K, G, hd)``) · ``k``/``v``: [B, W, K, hd] int8/int16
    mantissas (``width=8|16``) or raw floats (``width=None``) · ``pos``:
    int32 [B, W] ring positions (-1 = empty) · ``q_pos``: int32 [B] query
    positions · ``k_exp``/``v_exp``: f32 [B] log2-steps of the packed
    entries.  Returns f32 [B, K, G, hd]; numerics are the
    :func:`repro.kernels.attn.ref.decode_attention_ref` composite
    (bit-identical in interpret mode).

    With ``tp_axis`` naming a live ambient-mesh axis that divides ``K``,
    the call shard_maps itself over the kv-head axis — each shard runs
    this same function on its head slice, so per-head numerics are
    untouched (GQA never contracts across kv heads).
    """
    B, K, G, hd = q.shape
    tp = _tp_size(tp_axis, K)
    if tp:
        kw = dict(width=width, scale=scale, window=window, causal=causal,
                  block_w=block_w, interpret=interpret)
        h = PartitionSpec(None, tp_axis)
        kv = PartitionSpec(None, None, tp_axis)
        r = PartitionSpec()
        if width is None:
            return jax.shard_map(
                lambda q, k, v, pos, qp: flash_decode(q, k, v, pos, qp,
                                                      **kw),
                in_specs=(h, kv, kv, r, r), out_specs=h,
                check_vma=False)(q, k, v, pos, jnp.asarray(q_pos))
        return jax.shard_map(
            lambda q, k, v, pos, qp, ke, ve: flash_decode(
                q, k, v, pos, qp, ke, ve, **kw),
            in_specs=(h, kv, kv, r, r, r, r), out_specs=h,
            check_vma=False)(q, k, v, pos, jnp.asarray(q_pos),
                             jnp.asarray(k_exp, jnp.float32),
                             jnp.asarray(v_exp, jnp.float32))
    W = k.shape[1]
    interpret = resolve_interpret(interpret)
    if block_w is None:
        block_w = dispatch.attn_blocks_for(W, G, hd, width=width,
                                           interpret=interpret)
    block_w = min(block_w, W)

    if width is None:
        steps = jnp.ones((B, 2), jnp.float32)
    else:
        steps = jnp.stack([exact_pow2(jnp.asarray(k_exp, jnp.float32)),
                           exact_pow2(jnp.asarray(v_exp, jnp.float32))],
                          axis=-1)
    qpos = jnp.asarray(q_pos, jnp.int32).reshape(B, 1)

    return flash_decode_call(q.astype(jnp.float32), k, v,
                             pos.astype(jnp.int32), qpos, steps, width=width,
                             block_w=block_w, scale=scale, window=window,
                             causal=causal, interpret=interpret)


def flash_prefill(q: Array, k_new: Array, v_new: Array, k: Array, v: Array,
                  pos: Array, p0: Array, n_valid: Array, k_exp=None,
                  v_exp=None, *, width: Optional[int] = None, scale: float,
                  window: Optional[int] = None, causal: bool = True,
                  block_w: Optional[int] = None,
                  interpret: Optional[bool] = None,
                  tp_axis: Optional[str] = None) -> Array:
    """Fused chunked-prefill GQA attention over a (packed) KV ring buffer.

    ``q``: [B, C, K, G, hd] kv-head-major query groups for a chunk of
    ``C`` positions starting at ``p0`` [B] · ``k_new``/``v_new``: f32
    [B, C, K, hd] the chunk's own fresh K/V (attended causally from
    registers, never from the pool) · ``k``/``v``: [B, W, K, hd]
    int8/int16 mantissas (``width=8|16``) or raw floats (``width=None``)
    — the pool's history, masked to ``0 <= pos < p0`` · ``n_valid``: [B]
    valid chunk rows (ragged final chunk).  Returns f32 [B, C, K, G, hd];
    numerics are :func:`repro.kernels.attn.ref.prefill_attention_ref`
    (bit-identical in interpret mode).

    ``tp_axis`` shard_maps over the kv-head axis exactly as in
    :func:`flash_decode`.
    """
    B, C, K, G, hd = q.shape
    tp = _tp_size(tp_axis, K)
    if tp:
        kw = dict(width=width, scale=scale, window=window, causal=causal,
                  block_w=block_w, interpret=interpret)
        h = PartitionSpec(None, None, tp_axis)
        r = PartitionSpec()
        args = (q, k_new, v_new, k, v, pos, jnp.asarray(p0),
                jnp.asarray(n_valid))
        if width is None:
            return jax.shard_map(
                lambda q, kn, vn, k, v, pos, p0, nv: flash_prefill(
                    q, kn, vn, k, v, pos, p0, nv, **kw),
                in_specs=(h, h, h, h, h, r, r, r), out_specs=h,
                check_vma=False)(*args)
        return jax.shard_map(
            lambda q, kn, vn, k, v, pos, p0, nv, ke, ve: flash_prefill(
                q, kn, vn, k, v, pos, p0, nv, ke, ve, **kw),
            in_specs=(h, h, h, h, h, r, r, r, r, r), out_specs=h,
            check_vma=False)(*args, jnp.asarray(k_exp, jnp.float32),
                             jnp.asarray(v_exp, jnp.float32))
    W = k.shape[1]
    interpret = resolve_interpret(interpret)
    if block_w is None:
        block_w = dispatch.prefill_blocks_for(W, C, G, hd, width=width,
                                              interpret=interpret)
    block_w = min(block_w, W)

    if width is None:
        steps = jnp.ones((B, 2), jnp.float32)
    else:
        steps = jnp.stack([exact_pow2(jnp.asarray(k_exp, jnp.float32)),
                           exact_pow2(jnp.asarray(v_exp, jnp.float32))],
                          axis=-1)
    p0 = jnp.asarray(p0, jnp.int32).reshape(B, 1)
    nv = jnp.asarray(n_valid, jnp.int32).reshape(B, 1)

    return flash_prefill_call(q.astype(jnp.float32),
                              k_new.astype(jnp.float32),
                              v_new.astype(jnp.float32), k, v,
                              pos.astype(jnp.int32), p0, nv, steps,
                              width=width, block_w=block_w, scale=scale,
                              window=window, causal=causal,
                              interpret=interpret)


def _paged_steps(n_pages: int, k_exp, v_exp, width: Optional[int]) -> Array:
    """Per-page dequant steps [n_pages, 2] (ones for ``width=None``)."""
    if width is None:
        return jnp.ones((n_pages, 2), jnp.float32)
    return jnp.stack([exact_pow2(jnp.asarray(k_exp, jnp.float32)),
                      exact_pow2(jnp.asarray(v_exp, jnp.float32))], axis=-1)


def flash_decode_paged(q: Array, k: Array, v: Array, bt: Array, pos: Array,
                       q_pos: Array, k_exp=None, v_exp=None, *,
                       width: Optional[int] = None, scale: float,
                       window: Optional[int] = None, causal: bool = True,
                       interpret: Optional[bool] = None,
                       force_split: bool = False,
                       tp_axis: Optional[str] = None) -> Array:
    """Fused single-query GQA attention through a per-request block table.

    ``q``: [B, K, G, hd] kv-head-major query groups · ``k``/``v``:
    [n_pages, P, K, hd] page arenas (int8/int16 mantissas or raw floats)
    · ``bt``: int32 [B, nblocks] block tables (0 = null page) · ``pos``:
    int32 [B, nblocks·P] logical positions (-1 = empty) · ``k_exp``/
    ``v_exp``: f32 [n_pages] per-PAGE log2-steps.  Returns f32
    [B, K, G, hd]; numerics are
    :func:`repro.kernels.attn.ref.paged_decode_attention_ref`
    (bit-identical in interpret mode).

    ``tp_axis`` shard_maps over the kv-head axis (page arenas carry it at
    axis 2) exactly as in :func:`flash_decode`; block tables, positions
    and per-page exponents stay replicated.
    """
    B, K, G, hd = q.shape
    tp = _tp_size(tp_axis, K)
    if tp:
        kw = dict(width=width, scale=scale, window=window, causal=causal,
                  interpret=interpret, force_split=force_split)
        h = PartitionSpec(None, tp_axis)
        arena = PartitionSpec(None, None, tp_axis)
        r = PartitionSpec()
        args = (q, k, v, bt, pos, jnp.asarray(q_pos))
        if width is None:
            return jax.shard_map(
                lambda q, k, v, bt, pos, qp: flash_decode_paged(
                    q, k, v, bt, pos, qp, **kw),
                in_specs=(h, arena, arena, r, r, r), out_specs=h,
                check_vma=False)(*args)
        return jax.shard_map(
            lambda q, k, v, bt, pos, qp, ke, ve: flash_decode_paged(
                q, k, v, bt, pos, qp, ke, ve, **kw),
            in_specs=(h, arena, arena, r, r, r, r, r), out_specs=h,
            check_vma=False)(*args, jnp.asarray(k_exp, jnp.float32),
                             jnp.asarray(v_exp, jnp.float32))
    n_pages, P = k.shape[:2]
    interpret = resolve_interpret(interpret)
    dispatch.paged_attn_blocks_for(P, G, hd, width=width,
                                   interpret=interpret)
    steps = _paged_steps(n_pages, k_exp, v_exp, width)
    qpos = jnp.asarray(q_pos, jnp.int32).reshape(B, 1)
    return flash_decode_paged_call(q.astype(jnp.float32), k, v,
                                   bt.astype(jnp.int32),
                                   pos.astype(jnp.int32), qpos, steps,
                                   width=width, scale=scale, window=window,
                                   causal=causal, interpret=interpret,
                                   force_split=force_split)


def flash_prefill_paged(q: Array, k_new: Array, v_new: Array, k: Array,
                        v: Array, bt: Array, pos: Array, p0: Array,
                        n_valid: Array, k_exp=None, v_exp=None, *,
                        width: Optional[int] = None, scale: float,
                        window: Optional[int] = None, causal: bool = True,
                        interpret: Optional[bool] = None,
                        force_split: bool = False,
                        tp_axis: Optional[str] = None) -> Array:
    """Fused chunked-prefill GQA attention through a block table.

    ``q``: [B, C, K, G, hd] chunk query groups starting at ``p0`` [B] ·
    ``k_new``/``v_new``: f32 [B, C, K, hd] the chunk's own fresh K/V ·
    ``k``/``v``: [n_pages, P, K, hd] page arenas · ``bt``: int32
    [B, nblocks] · ``pos``: int32 [B, nblocks·P] · ``k_exp``/``v_exp``:
    f32 [n_pages] per-PAGE log2-steps.  Returns f32 [B, C, K, G, hd];
    numerics are
    :func:`repro.kernels.attn.ref.paged_prefill_attention_ref`
    (bit-identical in interpret mode).

    ``tp_axis`` shard_maps over the kv-head axis exactly as in
    :func:`flash_decode_paged`.
    """
    B, C, K, G, hd = q.shape
    tp = _tp_size(tp_axis, K)
    if tp:
        kw = dict(width=width, scale=scale, window=window, causal=causal,
                  interpret=interpret, force_split=force_split)
        h = PartitionSpec(None, None, tp_axis)
        arena = PartitionSpec(None, None, tp_axis)
        r = PartitionSpec()
        args = (q, k_new, v_new, k, v, bt, pos, jnp.asarray(p0),
                jnp.asarray(n_valid))
        if width is None:
            return jax.shard_map(
                lambda q, kn, vn, k, v, bt, pos, p0, nv:
                flash_prefill_paged(q, kn, vn, k, v, bt, pos, p0, nv, **kw),
                in_specs=(h, h, h, arena, arena, r, r, r, r), out_specs=h,
                check_vma=False)(*args)
        return jax.shard_map(
            lambda q, kn, vn, k, v, bt, pos, p0, nv, ke, ve:
            flash_prefill_paged(q, kn, vn, k, v, bt, pos, p0, nv, ke, ve,
                                **kw),
            in_specs=(h, h, h, arena, arena, r, r, r, r, r, r), out_specs=h,
            check_vma=False)(*args, jnp.asarray(k_exp, jnp.float32),
                             jnp.asarray(v_exp, jnp.float32))
    n_pages, P = k.shape[:2]
    interpret = resolve_interpret(interpret)
    dispatch.paged_prefill_blocks_for(P, C, G, hd, width=width,
                                      interpret=interpret)
    steps = _paged_steps(n_pages, k_exp, v_exp, width)
    p0 = jnp.asarray(p0, jnp.int32).reshape(B, 1)
    nv = jnp.asarray(n_valid, jnp.int32).reshape(B, 1)
    return flash_prefill_paged_call(q.astype(jnp.float32),
                                    k_new.astype(jnp.float32),
                                    v_new.astype(jnp.float32), k, v,
                                    bt.astype(jnp.int32),
                                    pos.astype(jnp.int32), p0, nv, steps,
                                    width=width, scale=scale, window=window,
                                    causal=causal, interpret=interpret,
                                    force_split=force_split)
