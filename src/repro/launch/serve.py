"""Serving CLI over the ``repro.serve`` continuous-batching engine.

Mixed-length prompts, per-request budgets, greedy/temperature/top-k
sampling, an optionally DFXP-packed KV-cache pool, the fused
flash-decode attention kernel (``--fused-decode``: dequantize in the
attention tile loads, no per-layer f32 K/V materialization), and
chunked prefill (``--prefill-chunk C``: immediate admission, one
C-token chunk per engine step interleaved with decode, one prefill jit
for any prompt length):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke \
      --num-requests 4 --prompt-len 8,16,32 --max-new 16 --cache-bits 8 \
      --fused-decode --prefill-chunk 8

Robustness controls: ``--queue-cap`` (reject-on-full admission),
``--deadline-ms`` (queued and in-flight expiry), and ``--chaos [SEED]``
(seeded fault-injection sweep — logit NaNs, KV bit flips, admission
delays, page squeezes — with the event log printed and optionally
written to ``--fault-log``).  A per-request status table prints at exit
either way; see ``repro.serve.engine.RequestStatus``.

``Engine`` below is the *lockstep reference*: batched prefill, then every
sequence decodes the same number of steps at one shared position. It frees
no slots and admits nothing mid-decode — kept (batch is implied by the
prompts' shape) because its greedy tokens are the bit-for-bit anchor the
float32-mode ``repro.serve.ServeEngine`` is tested against.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import ScaleState
from repro.core.policy import PrecisionPolicy
from repro.models import transformer as T
from repro.serve import FaultHarness, SamplerConfig, ServeEngine, chaos_plan


class Engine:
    """Lockstep reference: batched prefill + fixed-step greedy decode."""

    def __init__(self, cfg, policy, params, *, max_len: int):
        self.cfg, self.policy, self.params = cfg, policy, params
        self.max_len = max_len
        gs = T.group_shapes(cfg)
        self.exps = ScaleState.create(gs, -6.0).exps
        self.sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                      for n, s in gs.items() if n.startswith("g:")}
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    def _prefill_impl(self, tokens):
        batch = {"tokens": tokens}
        logits, _, cache = T.prefill(self.cfg, self.policy, self.params,
                                     batch, self.exps, self.sinks,
                                     max_cache_len=self.max_len)
        return logits, cache

    def _decode_impl(self, cache, tok, pos):
        logits, _, cache = T.decode_step(self.cfg, self.policy, self.params,
                                         cache, tok, pos, self.exps,
                                         self.sinks)
        return logits, cache

    def generate(self, prompts: jnp.ndarray, max_new: int):
        """``prompts``: [B, S] token ids. Returns [B, max_new] (greedy)."""
        B, S = prompts.shape
        logits, cache = self._prefill(prompts)
        outs = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(max_new):
            outs.append(tok)
            logits, cache = self._decode(cache, tok, jnp.int32(S + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return jnp.stack(outs, axis=1)


def _parse_lens(spec: str):
    return [int(x) for x in spec.split(",") if x]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arithmetic", default="dfxp")
    ap.add_argument("--num-requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=0,
                    help="concurrent slots (default: min(num-requests, 4))")
    ap.add_argument("--prompt-len", default="32",
                    help="prompt length, or comma list cycled over requests "
                         "(mixed lengths prefill as separate length groups)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-bits", type=int, default=0, choices=(0, 8, 16),
                    help="KV-cache storage: 0=float32, 8/16=DFXP-packed "
                         "mantissas with per-slot controller-managed scales")
    ap.add_argument("--fused-decode", action="store_true",
                    help="run decode attention as the fused Pallas "
                         "flash-decode kernel directly on the KV pool's "
                         "storage (packed pools dequantize int mantissas "
                         "in the tile loads; no f32 K/V materialization)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: admit any request into any free "
                         "slot immediately and prefill C tokens per engine "
                         "step interleaved with decode (one jit for any "
                         "prompt length; chunk K/V quantized straight into "
                         "the packed pool). 0 = whole-prompt prefill (the "
                         "bit-for-bit reference). Attention-family archs "
                         "only; MoE/SSM stay on the whole-prompt path")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV pool: page size P in tokens (0 = "
                         "slot-major rings). Pages carry their own DFXP "
                         "exponents; requests sharing a prompt prefix map "
                         "the same pages (refcounted, copy-on-write on "
                         "divergence). Implies --prefill-chunk P unless "
                         "set. Dense global-attention archs only")
    ap.add_argument("--sampler", default="greedy",
                    choices=("greedy", "temperature", "top_k"))
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="admission control: bound the waiting queue; a "
                         "submit finding it full resolves REJECTED (empty "
                         "result, terminal status) instead of queueing. "
                         "0 = unbounded")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline from submit; expired "
                         "requests (queued or mid-decode) resolve "
                         "TIMED_OUT with the tokens harvested so far. "
                         "0 = no deadline")
    ap.add_argument("--chaos", type=int, nargs="?", const=0, default=None,
                    metavar="SEED",
                    help="fault-injection sweep: drive a seeded random mix "
                         "of logit NaNs, KV bit flips, admission delays, "
                         "and (paged pools) a page squeeze through the "
                         "run, then print the fault log. The engine must "
                         "drain with terminal statuses either way")
    ap.add_argument("--fault-log", default="",
                    help="with --chaos: write the harness event log (JSON) "
                         "to this path")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    policy = PrecisionPolicy(args.arithmetic, fused_decode=args.fused_decode,
                             prefill_chunk=args.prefill_chunk,
                             page_size=args.page_size)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    lens = _parse_lens(args.prompt_len)
    slots = args.slots or min(args.num_requests, 4)
    scfg = SamplerConfig(kind=args.sampler, temperature=args.temperature,
                         top_k=args.top_k if args.sampler == "top_k" else 0)
    harness = None
    if args.chaos is not None:
        harness = FaultHarness(
            chaos_plan(args.chaos, list(range(args.num_requests)),
                       n_steps=4 * args.max_new,
                       squeeze_pages=4 if args.page_size else 0),
            seed=args.chaos)
    eng = ServeEngine(cfg, policy, params, max_slots=slots,
                      max_len=max(lens) + args.max_new,
                      cache_bits=args.cache_bits, sampler_cfg=scfg,
                      seed=args.seed,
                      queue_cap=args.queue_cap or None,
                      deadline_ms=args.deadline_ms or None,
                      faults=harness)
    uids = []
    for i in range(args.num_requests):
        plen = lens[i % len(lens)]
        prompt = jax.random.randint(jax.random.PRNGKey(1000 + i), (plen,), 0,
                                    cfg.vocab_size)
        uids.append(eng.submit(prompt, max_new=args.max_new))
    out = eng.run()
    stats = eng.stats()
    print(f"served {stats['requests_finished']} requests, "
          f"{stats['new_tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s, "
          f"ttft mean {stats['ttft_mean_s'] * 1e3:.0f}ms)")
    print("stats:", json.dumps({k: round(v, 4) if isinstance(v, float) else v
                                for k, v in stats.items()}))
    print("sample:", out[0][:8].tolist())
    print(f"{'uid':>5} {'status':>10} {'tokens':>7} {'preempts':>9}")
    for u in uids:
        st = eng.status(u)
        tr = eng.metrics.traces[u]
        print(f"{u:>5} {st.value if st else '?':>10} {out[u].size:>7} "
              f"{tr.preempts:>9}")
    if harness is not None:
        print("faults:", json.dumps(harness.summary()["event_counts"]))
        if args.fault_log:
            with open(args.fault_log, "w") as f:
                json.dump(harness.summary(), f, indent=2)
            print(f"fault log written to {args.fault_log}")
    return out


if __name__ == "__main__":
    main()
