"""Roofline analysis over the dry-run results (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell, from the compiled dry-run artifact:

    compute_s    = HLO_FLOPs_per_device / 197e12      (v5e bf16 peak)
    memory_s     = HLO_bytes_per_device / 819e9       (v5e HBM bw)
    collective_s = collective_bytes_per_device / 50e9 (per-link ICI bw)

FLOPs/bytes/collective-bytes come from the **loop-aware** HLO cost model
(benchmarks/hlo_cost — XLA's cost_analysis counts `while` bodies once; we
multiply by known_trip_count).

MODEL_FLOPS (the "useful" numerator) follows the MFU convention:
  * parameter flops: 6·N_active·tokens (train) / 2·N_active·tokens (serve);
  * attention matmul flops: causal 2·2·B·S·(S/2)·H·hd fwd (windowed: S·W;
    decode: S per new token), ×3 for training (bwd ≈ 2× fwd);
  * SSD (mamba2) chunked-scan matmul flops analogously.
The ratio MODEL_FLOPS / (HLO_FLOPs × chips) then exposes remat recompute,
quantization-sim overhead, and masked-out attention compute.

The decode-attention KV model (``--kv-report``) prices the serve hot
loop's biggest HBM consumer per cache width: each decoded token re-reads
the whole KV window of every attention layer, so bytes/token/layer =
``2 · S_kv · K · hd · elem_bytes`` — 4 B/elem for a float32 pool, 2/1 for
int16/int8 mantissas.  The *unfused* packed path (``codec.load``) widens
first: it additionally writes the f32 K/V copy and reads it back through
the scores/AV einsums, so an int8 cache costs MORE traffic than f32
until the dequantize is fused into the attention tile loads
(``--fused-decode``, ``repro.kernels.attn``).  The report prints those
expected ratios next to the measured ``BENCH_serve.json`` fused/unfused
tok/s pairs (CPU rows measure interpret-mode Pallas overhead, not HBM —
the expected column is the TPU story).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--results f.jsonl]
       PYTHONPATH=src python -m benchmarks.roofline --kv-report \
           [--arch llama3_8b] [--decode-s 32768] [--serve-json BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import functools
import json
import os

PEAK_FLOPS = 197e12     # v5e bf16 / chip
HBM_BW = 819e9          # B/s per chip
ICI_BW = 50e9           # B/s per link

SHAPE_BS = {
    "train_4k": (256, 4096),
    "prefill_32k": (32, 32768),
    "decode_32k": (128, 32768),
    "long_500k": (1, 524288),
}


@functools.lru_cache(maxsize=None)
def _arch_info(arch: str):
    import jax

    from repro import configs
    from repro.models import transformer as T
    cfg = configs.get(arch)
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", "")))
                        for p in path)
        n = leaf.size
        total += n
        if ":moe/w_" in name:
            active += n * cfg.top_k / cfg.num_experts
        else:
            active += n
    # layer census from the stage structure
    attn_layers = []   # (window or 0, shared)
    mamba_layers = 0
    for stage in T.build_stages(cfg):
        for blk in stage.blocks:
            if blk.kind in ("attn", "xattn"):
                attn_layers += [blk.window] * stage.count
            elif blk.kind == "mamba":
                mamba_layers += stage.count
    return cfg, int(total), int(active), tuple(attn_layers), mamba_layers


def model_flops(arch: str, shape: str) -> float:
    cfg, total, active, attn_layers, n_mamba = _arch_info(arch)
    B, S = SHAPE_BS[shape]
    train = shape == "train_4k"
    tokens = B * S if shape in ("train_4k", "prefill_32k") else B
    mult = 6 if train else 2
    flops = mult * active * tokens

    hd = cfg.head_dim * cfg.num_heads
    for w in attn_layers:
        if shape == "train_4k" or shape == "prefill_32k":
            skv = min(w, S) if w else S / 2          # causal avg
            f = 4 * B * S * skv * hd                 # scores + AV fwd
        else:  # decode: one token against the cache
            skv = min(w, S) if w else S
            f = 4 * B * skv * hd
        flops += f * (3 if train else 1)
    if n_mamba:
        sp = cfg.ssm_spec
        per_tok = 4 * sp.chunk / 2 * sp.heads * sp.headdim \
            + 8 * sp.heads * sp.headdim * sp.state
        if shape in ("train_4k", "prefill_32k"):
            f = per_tok * B * S * n_mamba
        else:
            f = 8 * sp.heads * sp.headdim * sp.state * B * n_mamba
        flops += f * (3 if train else 1)
    return flops


def analyse(rec: dict) -> dict:
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    chips = 512 if mesh == "2x16x16" else 256
    la = rec.get("loop_aware") or {}
    flops_dev = la.get("flops", rec["flops"])
    bytes_dev = la.get("traffic_bytes", rec["bytes_accessed"])
    coll_dev = la.get("collective_bytes",
                      rec["collectives"]["total_bytes"])
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    hlo_global = flops_dev * chips
    useful = mf / hlo_global if hlo_global else 0.0
    ideal_s = mf / (chips * PEAK_FLOPS)
    bound_s = max(terms.values())
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s, "coll_s": coll_s,
        "bottleneck": bottleneck, "model_flops": mf,
        "useful_ratio": useful,
        "roofline_frac": (ideal_s / bound_s if bound_s else 0.0),
        "temp_gb": rec["per_device"]["temp_bytes"] / 2 ** 30,
        "arg_gb": rec["per_device"]["argument_bytes"] / 2 ** 30,
        "coll_by_kind": la.get("collective_by_kind", {}),
    }


def kv_decode_bytes(arch: str, S: int, bits: int, fused: bool) -> float:
    """HBM bytes per decoded token spent reading the KV cache, all layers.

    ``bits``: 0 = float32 pool, 8/16 = packed mantissas. The unfused
    packed path models ``PackedKVCodec.load``: mantissa read + f32 K/V
    materialization (write) + f32 re-read by the attention einsums.
    Windowed (local) layers only re-read ``min(window, S)`` slots.
    """
    cfg, _, _, attn_layers, _ = _arch_info(arch)
    K, hd = cfg.num_kv_heads, cfg.head_dim
    elem = {0: 4, 8: 1, 16: 2}[bits]
    total = 0.0
    for w in attn_layers:
        skv = min(w, S) if w else S
        per = 2 * skv * K * hd * elem           # K + V storage read
        if bits and not fused:
            per += 2 * 2 * skv * K * hd * 4     # f32 copy: write + re-read
        total += per
    return total


def _serve_ratio(rows: dict, bits: int):
    """Measured fused/unfused tok/s ratio for one cache width, if present."""
    suffix = {0: "f32", 8: "int8", 16: "int16"}[bits]
    base = rows.get(f"serve_batched_{suffix}")
    fused = rows.get(f"serve_batched_{suffix}_fused")
    if base and fused:
        return fused / base
    return None


def kv_report(arch: str, S: int, serve_json: str, markdown: bool) -> None:
    """Expected vs measured fused-decode win per cache width."""
    rows = {}
    if serve_json and os.path.exists(serve_json):
        d = json.load(open(serve_json))
        rows = {r["name"]: r["derived"] for r in d.get("rows", [])}
        backend = d.get("meta", {}).get("backend", "?")
    else:
        backend = "none"
    f32 = kv_decode_bytes(arch, S, 0, False)
    print(f"# decode-attention KV traffic: arch={arch} S={S} "
          f"(measured rows: backend={backend})")
    hdr = ("cache", "path", "kv_bytes/tok", "vs_f32", "hbm_s/tok",
           "measured_tok_s_ratio")
    sep = " | " if markdown else ","
    if markdown:
        print("| " + sep.join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(",".join(hdr))
    for bits in (0, 16, 8):
        for fused in (False, True):
            b = kv_decode_bytes(arch, S, bits, fused)
            ratio = _serve_ratio(rows, bits) if fused else None
            vals = ({0: "f32", 8: "int8", 16: "int16"}[bits],
                    "fused" if fused else "load+einsum",
                    f"{b:.3e}", f"{f32 / b:.2f}x", f"{b / HBM_BW:.3e}",
                    f"{ratio:.2f}x" if ratio else "-")
            print(("| " + sep.join(vals) + " |") if markdown
                  else ",".join(vals))


NOTES = {
    "compute": "compute-bound: cut remat recompute, eliminate masked-out "
               "attention flops (chunked causal attention), map DFXP "
               "products to int8 MXU paths",
    "memory": "HBM-bound: fuse quantize sites (Pallas dfxp kernel), narrow "
              "containers (f32→f16), flash/chunked train attention, leaner "
              "remat policy",
    "collective": "ICI-bound: DFXP-compress gradient reduction, int8 "
                  "all-to-all payloads, overlap FSDP gathers with compute",
}


def load(results: str):
    seen = {}
    for line in open(results):
        r = json.loads(line)
        if r.get("ok"):
            seen[(r["arch"], r["shape"], r["mesh"])] = r
    return [analyse(r) for r in seen.values()]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.jsonl")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--kv-report", action="store_true",
                    help="decode-attention KV HBM traffic per cache width "
                         "(expected vs measured fused-decode win)")
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--decode-s", type=int, default=32768,
                    help="KV window length for --kv-report")
    ap.add_argument("--serve-json", default="BENCH_serve.json")
    args = ap.parse_args()

    if args.kv_report:
        kv_report(args.arch, args.decode_s, args.serve_json, args.markdown)
        return

    rows = sorted(load(args.results),
                  key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    hdr = ("arch", "shape", "mesh", "compute_s", "memory_s", "coll_s",
           "bottleneck", "useful", "roofline")
    sep = " | " if args.markdown else ","
    if args.markdown:
        print("| " + sep.join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(",".join(hdr))
    for r in rows:
        vals = (r["arch"], r["shape"], r["mesh"], f"{r['compute_s']:.3e}",
                f"{r['memory_s']:.3e}", f"{r['coll_s']:.3e}",
                r["bottleneck"], f"{r['useful_ratio']:.3f}",
                f"{r['roofline_frac']:.3f}")
        print(("| " + sep.join(vals) + " |") if args.markdown
              else ",".join(vals))

    single = [r for r in rows if r["mesh"] == "16x16"]
    if single:
        worst = min(single, key=lambda r: r["roofline_frac"])
        most_coll = max(single, key=lambda r: (r["coll_s"] /
                                               max(r["compute_s"],
                                                   r["memory_s"], 1e-12)))
        print("\n# hillclimb candidates")
        print(f"# worst roofline: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_frac']:.4f}) — {NOTES[worst['bottleneck']]}")
        print(f"# most collective-bound: {most_coll['arch']}/"
              f"{most_coll['shape']} (coll/max = "
              f"{most_coll['coll_s']/max(most_coll['compute_s'],most_coll['memory_s']):.2f})")


if __name__ == "__main__":
    main()
