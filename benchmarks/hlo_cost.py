"""Loop-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**, but
scan-over-layers / microbatch loops execute it ``trip_count`` times — for an
80-layer model at 16 microbatches that undercounts FLOPs by >1000×. This
module re-derives per-device costs from the partitioned HLO text, using the
``known_trip_count`` backend_config XLA attaches to every counted loop:

  * FLOPs: every ``dot`` (including inside fusion bodies):
      2 × prod(result_shape) × prod(contracting dim sizes)
  * HBM traffic: operands + results of every *materializing* top-level
    instruction (fusions count their boundary tensors only — body
    intermediates live in registers/VMEM, the fusion contract);
  * collective bytes per device: all-gather → result−operand, all-reduce →
    2×operand×(N−1)/N ≈ 2×operand, reduce-scatter/all-to-all/permute →
    operand bytes;
  * every cost is multiplied by the product of enclosing loop trip counts.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DT_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
             "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "c64": 8, "c128": 16, "f8e4m3": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z]*\d*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_ATTR_RE = re.compile(r"(body|condition|calls|to_apply)=%?([\w.\-]+)")

# instructions that don't touch HBM on their own
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "iota", "partition-id", "replica-id", "domain",
         "opt-barrier"}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}


def _shapes_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def _first_shape(type_str: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


class Instr:
    __slots__ = ("name", "type_str", "op", "rest")

    def __init__(self, name, type_str, op, rest):
        self.name, self.type_str, self.op, self.rest = name, type_str, op, rest


def parse_module(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    entry = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and line.rstrip().endswith("{"):
            cur = mc.group(1)
            if line.startswith("ENTRY"):
                entry = cur
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            comps[cur].append(Instr(mi.group(1), mi.group(2), mi.group(3),
                                    mi.group(4)))
    comps["__entry__"] = comps.get(entry, [])
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands are inside the first (...) — up to the matching paren
    depth, out, cur_name = 1, [], None
    i = 0
    names = []
    while i < len(rest) and depth > 0:
        c = rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        elif c == "%":
            j = i + 1
            while j < len(rest) and (rest[j].isalnum() or rest[j] in "._-"):
                j += 1
            names.append(rest[i + 1:j])
            i = j
            continue
        i += 1
    return names


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out = _first_shape(instr.type_str)
    if out is None:
        return 0.0
    out_elems = math.prod(out[1]) if out[1] else 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    ops = _operand_names(instr.rest)
    if not ops:
        return 0.0
    lhs_type = shapes.get(ops[0], "")
    lhs = _first_shape(lhs_type)
    if lhs is None:
        return 0.0
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            if int(d) < len(lhs[1]):
                contract *= lhs[1][int(d)]
    return 2.0 * out_elems * contract


def _collective_bytes(instr: Instr, shapes: Dict[str, str]) -> float:
    ops = _operand_names(instr.rest)
    in_bytes = sum(_shapes_bytes(shapes.get(o, "")) for o in ops)
    out_bytes = _shapes_bytes(instr.type_str)
    op = instr.op
    if op.startswith("all-gather"):
        return max(out_bytes - in_bytes, out_bytes * 0.5)
    if op.startswith("all-reduce"):
        return 2.0 * in_bytes
    return float(in_bytes)


class ModuleCost:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: Dict[str, Tuple[float, float, float, dict]] = {}
        self._dus_roots: Dict[str, bool] = {}

    def _root_is_dus(self, comp: str) -> bool:
        if comp not in self._dus_roots:
            instrs = self.comps.get(comp, [])
            self._dus_roots[comp] = bool(
                instrs and instrs[-1].op == "dynamic-update-slice")
        return self._dus_roots[comp]

    def _fusion_param_bytes(self, callee: str) -> Dict[int, float]:
        """Real read bytes per fusion parameter: a parameter consumed only
        by (dynamic-)slice ops reads the slice, not the whole buffer (scan
        xs indexing lowers to exactly this pattern)."""
        instrs = self.comps.get(callee, [])
        out: Dict[int, float] = {}
        params: Dict[str, int] = {}
        for ins in instrs:
            if ins.op == "parameter":
                m = re.match(r"(\d+)\)", ins.rest)
                if m:
                    params[ins.name] = int(m.group(1))
                    out[int(m.group(1))] = _shapes_bytes(ins.type_str)
        for pname, idx in params.items():
            consumers = [i for i in instrs
                         if pname in _operand_names(i.rest)]
            if consumers and all(c.op in ("dynamic-slice", "slice")
                                 for c in consumers):
                out[idx] = sum(_shapes_bytes(c.type_str) for c in consumers)
        return out

    def cost(self, comp: str = "__entry__"):
        """Returns (flops, traffic_bytes, collective_bytes, coll_by_kind)."""
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = (0.0, 0.0, 0.0, {})  # cycle guard
        instrs = self.comps.get(comp, [])
        shapes = {i.name: i.type_str for i in instrs}
        flops = traffic = coll = 0.0
        coll_kind: Dict[str, float] = {}
        for ins in instrs:
            op = ins.op
            attrs = dict(_ATTR_RE.findall(ins.rest))
            if op == "while":
                trips = 1
                mt = _TRIP_RE.search(ins.rest)
                if mt:
                    trips = int(mt.group(1))
                bf, bt, bc, bk = self.cost(attrs.get("body", ""))
                cf, ct, cc, ck = self.cost(attrs.get("condition", ""))
                flops += trips * (bf + cf)
                traffic += trips * (bt + ct)
                coll += trips * (bc + cc)
                for k, v in {**bk, **ck}.items():
                    coll_kind[k] = coll_kind.get(k, 0.0) + trips * (
                        bk.get(k, 0.0) + ck.get(k, 0.0))
                continue
            if op == "fusion":
                callee = attrs.get("calls")
                if callee:
                    cf, _, cc, ck = self.cost(callee)
                    flops += cf
                    coll += cc
                    for k, v in ck.items():
                        coll_kind[k] = coll_kind.get(k, 0.0) + v
                ops = _operand_names(ins.rest)
                if callee and self._root_is_dus(callee) and ops:
                    # in-place update fusion: the big buffer (operand 0)
                    # aliases the output; real traffic ≈ 2 × the update
                    traffic += 2.0 * sum(
                        _shapes_bytes(shapes.get(o, "")) for o in ops[1:])
                elif callee:
                    pb = self._fusion_param_bytes(callee)
                    traffic += _shapes_bytes(ins.type_str) + sum(
                        pb.get(i, _shapes_bytes(shapes.get(o, "")))
                        for i, o in enumerate(ops))
                else:
                    traffic += _shapes_bytes(ins.type_str) + sum(
                        _shapes_bytes(shapes.get(o, "")) for o in ops)
                continue
            if op == "dynamic-update-slice":
                ops = _operand_names(ins.rest)
                traffic += 2.0 * sum(
                    _shapes_bytes(shapes.get(o, "")) for o in ops[1:2])
                continue
            if op in ("dynamic-slice", "gather", "slice", "pad"):
                traffic += 2.0 * _shapes_bytes(ins.type_str)
                continue
            if op in ("call", "custom-call", "map", "reduce", "sort",
                      "reduce-window", "select-and-scatter", "scatter",
                      "conditional"):
                callee = attrs.get("to_apply") or attrs.get("calls")
                if callee:
                    cf, ct, cc, ck = self.cost(callee)
                    flops += cf
                    traffic += ct
                    coll += cc
                    for k, v in ck.items():
                        coll_kind[k] = coll_kind.get(k, 0.0) + v
                traffic += _shapes_bytes(ins.type_str) + sum(
                    _shapes_bytes(shapes.get(o, ""))
                    for o in _operand_names(ins.rest))
                continue
            if op in _COLLECTIVES:
                b = _collective_bytes(ins, shapes)
                key = op.replace("-start", "")
                coll += b
                coll_kind[key] = coll_kind.get(key, 0.0) + b
                traffic += _shapes_bytes(ins.type_str)
                continue
            if op == "dot":
                flops += _dot_flops(ins, shapes)
                traffic += _shapes_bytes(ins.type_str) + sum(
                    _shapes_bytes(shapes.get(o, ""))
                    for o in _operand_names(ins.rest))
                continue
            if op in _FREE or op.endswith("-done"):
                continue
            traffic += _shapes_bytes(ins.type_str) + sum(
                _shapes_bytes(shapes.get(o, ""))
                for o in _operand_names(ins.rest))
        self._memo[comp] = (flops, traffic, coll, coll_kind)
        return self._memo[comp]


def analyze_text(text: str) -> dict:
    mc = ModuleCost(text)
    flops, traffic, coll, kinds = mc.cost()
    return {"flops": flops, "traffic_bytes": traffic,
            "collective_bytes": coll, "collective_by_kind": kinds}
