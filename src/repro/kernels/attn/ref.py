"""Reference composite for the fused decode-attention kernel.

This is the numerics contract of :mod:`repro.kernels.attn`: a single-query
GQA attention over a (possibly DFXP-packed) KV ring buffer, written as
plain jnp on the full ``[B, ...]`` shapes.  The Pallas kernel's
interpret-mode path executes :func:`attend` *verbatim* on its loaded
tiles (one grid step, full-shape blocks, dequantize first), which is what
lets CPU tests assert **bit**-equality between the fused kernel and this
composite — the same guarantee the qmatmul family gives against its
``ste_quant + jnp.matmul`` composite.

Masking semantics match ``repro.models.layers.attention_decode``:

* ``pos < 0`` marks an empty ring slot (never attended);
* causal: the query at ``q_pos`` sees keys with ``pos <= q_pos``;
* ``window``: only keys with ``q_pos - pos < window`` (None = global).

The softmax is the flash form — masked lanes contribute an exact ``0.0``
(``jnp.where`` before and after the exp), the max is subtracted per
(batch, kv-head, group) row, and the normalizer divides the *output*
(``o / l``), which is the order the split-K kernel reproduces.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import exact_pow2

Array = jax.Array


def valid_mask(pos: Array, q_pos: Array, *, window: Optional[int],
               causal: bool) -> Array:
    """[B, W] bool: which ring slots the query at ``q_pos`` [B] may see."""
    d = q_pos[:, None] - pos
    valid = pos >= 0
    if causal:
        valid = valid & (d >= 0)
    if window:
        valid = valid & (d < window)
    return valid


def attend(qf: Array, kf: Array, vf: Array, pos: Array, q_pos: Array, *,
           scale: float, window: Optional[int] = None,
           causal: bool = True) -> Array:
    """Single-query GQA attention on dequantized (f32) operands.

    ``qf``: [B, K, G, hd] · ``kf``/``vf``: [B, W, K, hd] · ``pos``: [B, W]
    int32 · ``q_pos``: [B] int32.  Returns [B, K, G, hd] float32.
    """
    s = jnp.einsum("bkgh,bwkh->bkgw", qf, kf,
                   preferred_element_type=jnp.float32) * scale
    v4 = valid_mask(pos, q_pos, window=window, causal=causal)[:, None, None, :]
    s = jnp.where(v4, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(v4, jnp.exp(s - m), 0.0)
    el = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgw,bwkh->bkgh", p, vf,
                   preferred_element_type=jnp.float32)
    return o / jnp.maximum(el, 1e-30)


def dequant(m: Array, e: Array) -> Array:
    """[B, W, K, hd] mantissas × per-row exponents [B] → f32 values."""
    return m.astype(jnp.float32) * exact_pow2(e)[:, None, None, None]


def decode_attention_ref(q: Array, k: Array, v: Array, pos: Array,
                         q_pos: Array, *, k_exp=None, v_exp=None,
                         width: Optional[int] = None, scale: float,
                         window: Optional[int] = None,
                         causal: bool = True) -> Array:
    """The full composite: dequantize (when ``width``) then :func:`attend`.

    ``width=None`` takes ``k``/``v`` as raw float K/V (the f32-pool path);
    otherwise they are int8/int16 mantissas with ``k_exp``/``v_exp`` [B]
    log2-steps, exactly the :class:`repro.serve.kv_pool.PackedKVCodec`
    entry layout (one layer, leading layer dim stripped).
    """
    qf = q.astype(jnp.float32)
    if width is None:
        kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    else:
        kf, vf = dequant(k, k_exp), dequant(v, v_exp)
    return attend(qf, kf, vf, pos, q_pos, scale=scale, window=window,
                  causal=causal)
