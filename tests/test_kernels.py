"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dfxp.ops import dfxp_quantize
from repro.kernels.dfxp.ref import dfxp_quantize_ref
from repro.kernels.qmatmul.ops import qmatmul
from repro.kernels.qmatmul.ref import qmatmul_ref

SHAPES_Q = [(8, 128), (256, 512), (3, 7), (1000,), (4, 33, 65), (2, 2, 2, 130)]
WIDTHS = [4, 8, 10, 12, 16]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]


@pytest.mark.parametrize("shape", SHAPES_Q)
@pytest.mark.parametrize("width", [8, 10])
def test_dfxp_quantize_matches_ref_shapes(shape, width):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 4.0
    e = jnp.float32(-4)
    y, st = dfxp_quantize(x, e, width=width, interpret=True)
    yr, str_ = dfxp_quantize_ref(x, e, width=width)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(st), np.asarray(str_))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("width", WIDTHS)
def test_dfxp_quantize_dtypes(dtype, width):
    x = (jax.random.normal(jax.random.PRNGKey(1), (64, 256)) * 10).astype(dtype)
    e = jnp.float32(-3)
    y, st = dfxp_quantize(x, e, width=width, interpret=True)
    yr, str_ = dfxp_quantize_ref(x, e, width=width)
    assert y.dtype == dtype
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(yr, np.float32))
    np.testing.assert_array_equal(np.asarray(st), np.asarray(str_))


def test_dfxp_quantize_extreme_exponents():
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 128)) * 1e-6
    for e in (-30.0, -20.0, 0.0, 10.0):
        y, st = dfxp_quantize(x, jnp.float32(e), width=10, interpret=True)
        yr, sr = dfxp_quantize_ref(x, jnp.float32(e), width=10)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
        np.testing.assert_array_equal(np.asarray(st), np.asarray(sr))


MM_SHAPES = [(128, 128, 128), (256, 384, 128), (64, 128, 256), (100, 130, 50),
             (8, 128, 128)]


@pytest.mark.parametrize("mkn", MM_SHAPES)
def test_qmatmul_matches_ref(mkn):
    M, K, N = mkn
    ka, kb = jax.random.split(jax.random.PRNGKey(3))
    a = jax.random.normal(ka, (M, K))
    b = jax.random.normal(kb, (K, N)) * 0.5
    e_a, e_b = jnp.float32(-6), jnp.float32(-7)
    c = qmatmul(a, b, e_a, e_b, width=10, interpret=True)
    cr = qmatmul_ref(a, b, e_a, e_b, width=10)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("width", [4, 8, 12])
def test_qmatmul_widths(width):
    ka, kb = jax.random.split(jax.random.PRNGKey(4))
    a = jax.random.normal(ka, (64, 128)) * 8
    b = jax.random.normal(kb, (128, 128))
    c = qmatmul(a, b, jnp.float32(-2), jnp.float32(-5), width=width,
                interpret=True)
    cr = qmatmul_ref(a, b, jnp.float32(-2), jnp.float32(-5), width=width)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr),
                               rtol=1e-6, atol=1e-5)


def test_qmatmul_quantization_actually_applied():
    # identity scales wide enough that quantization is a no-op vs exact matmul
    a = jnp.round(jax.random.normal(jax.random.PRNGKey(5), (64, 128)) * 4)
    b = jnp.round(jax.random.normal(jax.random.PRNGKey(6), (128, 128)) * 4)
    c = qmatmul(a, b, jnp.float32(0), jnp.float32(0), width=16,
                interpret=True)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a @ b), rtol=1e-6)
    # and with a coarse grid it differs (quantization visible)
    c2 = qmatmul(a * 0.1, b, jnp.float32(0), jnp.float32(0), width=16,
                 interpret=True)
    assert not np.allclose(np.asarray(c2), np.asarray((a * 0.1) @ b))
