"""Kernel microbenchmarks: fused Pallas quantize / matmul family vs jnp chains.

Rows come in jnp/fused pairs for each op the dispatch layer owns —
quantize, qmatmul forward, dgrad (``ct @ qB^T``), wgrad (``qA^T @ ct``) —
plus a full-train-step pair (composite vs ``PrecisionPolicy.fused_matmul``).

On this CPU container the Pallas kernels run in interpret mode, so their
absolute times measure the *reference semantics*, not TPU perf; the
jnp-chain rows are the ones that time real XLA-compiled code.  The same
rows recorded on a compiled TPU backend are the perf trajectory proper
(`benchmarks/run.py` persists them to ``BENCH_kernels.json``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.quant import fixed_round
from repro.kernels._tiling import default_interpret
from repro.kernels.dfxp.ops import dfxp_quantize
from repro.kernels.qmatmul.ops import qmatmul, qmm

WIDTH = 10


def _time(fn, *args, reps=5, budget_s=0.25, cap=25):
    """Best-of-N microseconds, N adaptive: at least ``reps`` calls, and for
    cheap ops keep repeating until ``budget_s`` of measured time (capped at
    ``cap`` calls).  The *min* is what the regression gate diffs — on
    shared CI machines the mean folds in scheduler noise that a 25%
    tolerance band cannot absorb, and sub-ms rows need many samples
    before their min stabilizes."""
    fn(*args)  # warmup/compile
    best, spent, n = float("inf"), 0.0, 0
    while n < reps or (spent < budget_s and n < cap):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        best, spent, n = min(best, dt), spent + dt, n + 1
    return best * 1e6


def _q(x, e):
    y, _ = fixed_round(x, WIDTH, e)
    return y


def make_tiny_maxout_step(policy):
    """(jitted step, initial state) for a tiny maxout DFXP train loop.

    Shared harness: the train-step bench rows below and the fused-on/off
    loss-bit-identity test (tests/test_fused_dot.py) must exercise the
    *same* step construction."""
    from repro.models import maxout as MX
    from repro.optim.opt import OptConfig, sgd_init
    from repro.train import init_train_state, make_train_step

    cfg = MX.MaxoutConfig(input_dim=20, hidden=(16,), pieces=2,
                          dropout_input=0.0, dropout_hidden=0.0)
    gs = MX.group_shapes(cfg)
    params = MX.init_params(cfg, jax.random.PRNGKey(7))
    state = init_train_state(params, sgd_init(params), gs, policy,
                             init_exp=-6.0)

    def loss_fn(p, b, s, exps):
        return MX.loss_fn(cfg, policy, p, b, exps, s)

    step = jax.jit(make_train_step(
        loss_fn, gs, policy, OptConfig(kind="sgd", lr=0.1)))
    return step, state


def tiny_maxout_batch(i: int = 0):
    kx, ky = jax.random.split(jax.random.PRNGKey(8))
    return {"x": jax.random.normal(kx, (16, 20)) + i,
            "y": jax.random.randint(ky, (16,), 0, 10)}


def _train_step_row(fused: bool, steps: int):
    """Seconds-per-step of the tiny maxout DFXP train loop."""
    import dataclasses

    from repro.core.policy import DFXP_10_12

    policy = dataclasses.replace(DFXP_10_12, fused_matmul=fused)
    step, state = make_tiny_maxout_step(policy)
    batch = tiny_maxout_batch()
    state, m = step(state, batch, jax.random.PRNGKey(2))   # warmup/compile
    jax.block_until_ready(m["loss"])
    best, spent, n = float("inf"), 0.0, 0
    while n < steps or (spent < 0.25 and n < 25):   # see _time
        t0 = time.perf_counter()
        state, m = step(state, batch, jax.random.PRNGKey(3 + n))
        jax.block_until_ready(m["loss"])
        dt = time.perf_counter() - t0
        best, spent, n = min(best, dt), spent + dt, n + 1
    return best * 1e6


def run(tiny: bool = False):
    """``tiny=True``: CI-smoke shapes — asserts the paths execute, not perf."""
    out = []
    mode = "interp" if default_interpret() else "tpu"
    # tiny shapes are the regression-gate baseline: more reps, less noise
    reps = 5
    e = jnp.float32(-6)

    # -- quantize -----------------------------------------------------------
    QM, QN = (128, 256) if tiny else (1024, 1024)
    x = jax.random.normal(jax.random.PRNGKey(0), (QM, QN))
    jnp_q = jax.jit(lambda x, e: fixed_round(x, WIDTH, e))
    tag = f"{QM}x{QN}"
    out.append((f"kernels/quantize_jnp_{tag}", _time(jnp_q, x, e, reps=reps),
                1.0))
    out.append((f"kernels/quantize_fused_{mode}_{tag}",
                _time(lambda x, e: dfxp_quantize(x, e, width=WIDTH),
                      x, e, reps=reps), 1.0))

    # -- matmul family: fwd / dgrad / wgrad ---------------------------------
    M, K, N = (32, 64, 32) if tiny else (256, 512, 256)
    ka, kb, kc = jax.random.split(jax.random.PRNGKey(1), 3)
    a = jax.random.normal(ka, (M, K))
    b = jax.random.normal(kb, (K, N)) * 0.5
    ct = jax.random.normal(kc, (M, N))
    mflop = 2 * M * K * N / 1e6
    tag = f"{M}x{K}x{N}"

    # forward: C = q(a) @ q(b)
    fwd_jnp = jax.jit(lambda a, b: jnp.dot(
        _q(a, e), _q(b, e), preferred_element_type=jnp.float32))
    out.append((f"kernels/qmatmul_fwd_jnp_{tag}",
                _time(fwd_jnp, a, b, reps=reps), mflop))
    out.append((f"kernels/qmatmul_fwd_fused_{mode}_{tag}",
                _time(lambda a, b: qmatmul(a, b, e, e, width=WIDTH),
                      a, b, reps=reps), mflop))

    # dgrad: dA = q(ct) @ q(b)^T  (layout nt)
    dgrad_jnp = jax.jit(lambda ct, b: jax.lax.dot_general(
        _q(ct, e), _q(b, e), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32))
    out.append((f"kernels/qmatmul_dgrad_jnp_{tag}",
                _time(dgrad_jnp, ct, b, reps=reps), mflop))
    out.append((f"kernels/qmatmul_dgrad_fused_{mode}_{tag}",
                _time(lambda ct, b: qmm(ct, b, e, e, kind="nt",
                                        width_a=WIDTH, width_b=WIDTH),
                      ct, b, reps=reps), mflop))

    # wgrad: dB = q(a)^T @ q(ct)  (layout tn)
    wgrad_jnp = jax.jit(lambda a, ct: jax.lax.dot_general(
        _q(a, e), _q(ct, e), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32))
    out.append((f"kernels/qmatmul_wgrad_jnp_{tag}",
                _time(wgrad_jnp, a, ct, reps=reps), mflop))
    out.append((f"kernels/qmatmul_wgrad_fused_{mode}_{tag}",
                _time(lambda a, ct: qmm(a, ct, e, e, kind="tn",
                                        width_a=WIDTH, width_b=WIDTH),
                      a, ct, reps=reps), mflop))

    # -- decode attention: packed-pool composite vs fused flash-decode ------
    B, W, K_kv, G, hd = (2, 16, 2, 2, 8) if tiny else (4, 256, 4, 4, 64)
    kq, kk, kv2 = jax.random.split(jax.random.PRNGKey(2), 3)
    q4 = jax.random.normal(kq, (B, K_kv, G, hd))
    km = jax.random.randint(kk, (B, W, K_kv, hd), -127, 128, jnp.int8)
    vm = jax.random.randint(kv2, (B, W, K_kv, hd), -127, 128, jnp.int8)
    exps = jnp.full((B,), -7.0, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(W), (B, W)).astype(jnp.int32)
    qpos = jnp.full((B,), W - 1, jnp.int32)
    scale = 1.0 / hd ** 0.5
    mflop = 4 * B * W * K_kv * G * hd / 1e6
    tag = f"{B}x{W}x{K_kv * G}x{hd}"

    def attn_jnp(q4, km, vm, exps, pos, qpos):
        # the unfused serve path: codec.load dequant, then masked einsum
        from repro.core.quant import exact_pow2
        kf = km.astype(jnp.float32) * exact_pow2(exps)[:, None, None, None]
        vf = vm.astype(jnp.float32) * exact_pow2(exps)[:, None, None, None]
        s = jnp.einsum("bkgh,bwkh->bkgw", q4, kf,
                       preferred_element_type=jnp.float32) * scale
        valid = (pos >= 0) & (qpos[:, None] - pos >= 0)
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgw,bwkh->bkgh", p, vf,
                          preferred_element_type=jnp.float32)

    out.append((f"kernels/attn_decode_jnp_{tag}",
                _time(jax.jit(attn_jnp), q4, km, vm, exps, pos, qpos,
                      reps=reps), mflop))
    from repro.kernels.attn.ops import flash_decode
    out.append((f"kernels/attn_decode_fused_{mode}_{tag}",
                _time(lambda *a: flash_decode(*a, width=8, scale=scale),
                      q4, km, vm, pos, qpos, exps, exps, reps=reps), mflop))

    # -- chunked prefill: dequant composite vs fused flash-prefill ----------
    C = 4 if tiny else 32
    kq2, kn2 = jax.random.split(jax.random.PRNGKey(3))
    qc = jax.random.normal(kq2, (B, C, K_kv, G, hd))
    knew = jax.random.normal(kn2, (B, C, K_kv, hd))
    p0 = jnp.full((B,), W // 2, jnp.int32)       # half the pool is history
    nv = jnp.full((B,), C, jnp.int32)
    mflop = 4 * B * C * (W + C) * K_kv * G * hd / 1e6
    tag = f"{B}x{C}x{W}x{K_kv * G}x{hd}"

    from repro.kernels.attn import ref as AR
    from repro.kernels.attn.ops import flash_prefill
    prefill_jnp = jax.jit(lambda *a: AR.prefill_attention_ref(
        *a, k_exp=exps, v_exp=exps, width=8, scale=scale))
    out.append((f"kernels/attn_prefill_jnp_{tag}",
                _time(prefill_jnp, qc, km, vm, pos, knew, knew, p0, nv,
                      reps=reps), mflop))
    out.append((f"kernels/attn_prefill_fused_{mode}_{tag}",
                _time(lambda *a: flash_prefill(*a, width=8, scale=scale),
                      qc, knew, knew, km, vm, pos, p0, nv, exps, exps,
                      reps=reps), mflop))

    # -- full train step (fwd + dgrad + wgrad per dot site) -----------------
    steps = 3
    out.append(("kernels/train_step_jnp_maxout16",
                _train_step_row(False, steps), 1.0))
    out.append((f"kernels/train_step_fused_{mode}_maxout16",
                _train_step_row(True, steps), 1.0))
    return out
