"""Reproduce the paper's bit-width frontier (Figures 2-3) on a scaled task:
sweep DFXP computation and update widths independently, print the knee.

    PYTHONPATH=src python examples/precision_sweep.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy
from repro.data import SyntheticImages
from repro.models import maxout as MX
from repro.optim.opt import OptConfig, sgd_init
from repro.train import init_train_state, make_train_step
from repro.train.calibrate import calibrate

STEPS = 120
cfg = MX.MaxoutConfig(hidden=(48,), pieces=3)
opt_cfg = OptConfig(kind="sgd", lr=0.1, lr_decay_steps=2000)
data = SyntheticImages()
gs = MX.group_shapes(cfg)


def final_loss(policy, init_exp):
    params = MX.init_params(cfg, jax.random.PRNGKey(7))
    state = init_train_state(params, sgd_init(params), gs, policy,
                             init_exp=init_exp)

    def loss_fn(p, b, s, exps):
        return MX.loss_fn(cfg, policy, p, b, exps, s,
                          rng=jax.random.PRNGKey(1))

    step = jax.jit(make_train_step(loss_fn, gs, policy, opt_cfg))
    for i in range(STEPS):
        b = data.batch(i, 64)
        state, m = step(state, {"x": jnp.asarray(b["x"]),
                                "y": jnp.asarray(b["y"])},
                        jax.random.PRNGKey(i))
    return float(m["loss"])


def calibrated_exps(policy):
    obs = dataclasses.replace(policy, arithmetic="observe")
    params0 = MX.init_params(cfg, jax.random.PRNGKey(7))

    def obs_loss(p, b, s, exps):
        return MX.loss_fn(cfg, obs, p, b, exps, s, rng=jax.random.PRNGKey(1))

    batches = ({"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}
               for b in (data.batch(i, 64) for i in range(10)))
    return calibrate(obs_loss, params0, gs, policy, opt_cfg, batches, steps=6)


def main():
    base = final_loss(PrecisionPolicy("float32"), -8.0)
    print(f"float32 baseline loss: {base:.4f}\n")
    print("comp-width sweep (update=12):   [paper Fig.2: knee at 10]")
    for w in (14, 12, 10, 8, 6):
        pol = PrecisionPolicy("dfxp", comp_width=w, update_width=12,
                              update_interval=10)
        loss = final_loss(pol, calibrated_exps(pol))
        print(f"  comp={w:2d}: loss={loss:.4f} ({loss/base:.2f}x fp32)")
    print("update-width sweep (comp=10):   [paper Fig.3: knee at 12]")
    for w in (16, 12, 10, 8):
        pol = PrecisionPolicy("dfxp", comp_width=10, update_width=w,
                              update_interval=10)
        loss = final_loss(pol, calibrated_exps(pol))
        print(f"  update={w:2d}: loss={loss:.4f} ({loss/base:.2f}x fp32)")


if __name__ == "__main__":
    main()
