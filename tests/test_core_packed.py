"""core/packed.py: pack/unpack round-trips, clamping, stochastic rounding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packed import (
    PackedArray,
    container_dtype,
    pack,
    pack_overflow_stats,
    unpack,
)
from repro.core.quant import fixed_round


@pytest.mark.parametrize("width", [8, 12, 16])
def test_pack_unpack_roundtrip_on_grid(width):
    """Grid points ``m * 2**e`` with |m| <= qmax survive exactly."""
    e = -3.0
    qmax = 2 ** (width - 1) - 1
    rng = np.random.RandomState(width)
    m = rng.randint(-qmax, qmax + 1, size=(64,))
    x = jnp.asarray(m * 2.0 ** e, jnp.float32)
    p = pack(x, width, e)
    assert p.mantissa.dtype == container_dtype(width)
    np.testing.assert_array_equal(np.asarray(p.mantissa), m)
    np.testing.assert_array_equal(np.asarray(unpack(p)), np.asarray(x))


@pytest.mark.parametrize("width", [8, 12, 16])
def test_pack_rounding_error_bounded(width):
    """Off-grid values round to the nearest grid point (<= step/2)."""
    e = -5.0
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 0.5
    err = np.abs(np.asarray(unpack(pack(x, width, e)) - x))
    assert np.all(err <= 2.0 ** e / 2 + 1e-7)


@pytest.mark.parametrize("width", [8, 12, 16])
def test_pack_clamps_at_qmin_qmax(width):
    e = 0.0
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))
    x = jnp.asarray([1e9, -1e9, qmax + 10.0, qmin - 10.0], jnp.float32)
    p = pack(x, width, e)
    np.testing.assert_array_equal(np.asarray(p.mantissa, np.float64),
                                  [qmax, qmin, qmax, qmin])
    np.testing.assert_array_equal(np.asarray(unpack(p)),
                                  [qmax, qmin, qmax, qmin])


def test_unpack_dtype_cast():
    p = pack(jnp.asarray([0.5, -0.25]), 8, -4.0)
    assert unpack(p, jnp.bfloat16).dtype == jnp.bfloat16


def test_stochastic_pack_is_mean_preserving():
    """E[floor(m + u)] = m: averaging over many keys recovers the value
    to far better than the deterministic step/2 bound (Gupta et al. 2015)."""
    width, e = 8, -4.0
    x = jnp.asarray([0.3, -0.77, 1.01, 0.0, 3.0 * 2.0 ** e], jnp.float32)
    n_keys = 1500
    acc = np.zeros(x.shape, np.float64)
    for i, k in enumerate(jax.random.split(jax.random.PRNGKey(42), n_keys)):
        acc += np.asarray(unpack(pack(x, width, e, stochastic_key=k)))
    mean = acc / n_keys
    # mean converges to x; 3-sigma of a step-wide Bernoulli over n_keys
    tol = 3 * 2.0 ** e / 2 / np.sqrt(n_keys)
    assert np.all(np.abs(mean - np.asarray(x)) <= tol)
    # exact grid points have zero variance: every draw is exact
    np.testing.assert_allclose(mean[4], 3.0 * 2.0 ** e, rtol=0, atol=1e-9)


def test_stochastic_pack_still_clamps():
    p = pack(jnp.asarray([1e9, -1e9]), 8, 0.0,
             stochastic_key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(p.mantissa, np.float64),
                                  [127.0, -128.0])


def test_pack_overflow_stats_matches_fixed_round():
    """The packing stats triple agrees with quant.fixed_round's counters."""
    width, e = 8, -2.0
    x = jax.random.normal(jax.random.PRNGKey(7), (512,)) * 40.0
    stats = np.asarray(pack_overflow_stats(x, width, e))
    _, (ovf, ovfh) = fixed_round(x, width, jnp.float32(e))
    assert stats[2] == x.size
    assert stats[0] == pytest.approx(float(ovf))
    assert stats[1] == pytest.approx(float(ovfh))


def test_packed_array_pytree():
    p = pack(jnp.arange(4, dtype=jnp.float32), 12, -1.0)
    leaves, treedef = jax.tree_util.tree_flatten(p)
    p2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(p2, PackedArray) and p2.width == 12
    np.testing.assert_array_equal(np.asarray(unpack(p2)),
                                  np.asarray(unpack(p)))
