"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 v5e chips) or 2×16×16 two-pod (512) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for multi-device CPU tests (forced host device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
