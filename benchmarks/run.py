# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Paper artifacts (Table 3, Figures 1-4) train the maxout network under
# each arithmetic on the scaled synthetic task; ``derived`` is the final
# loss normalized by the fp32 baseline (the paper's normalized test error).
# Kernel rows report microseconds per call; ``derived`` is MFLOP for
# matmuls. Run with: PYTHONPATH=src python -m benchmarks.run [--quick]
#
# The kernels suite additionally persists its rows to ``BENCH_kernels.json``
# (jnp-composite vs fused Pallas pairs for quantize, qmatmul fwd, dgrad,
# wgrad, and the full train step) — the perf-trajectory record; ``--tiny``
# shrinks it to CI-smoke shapes that assert execution, not perf.
import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="table3 + kernels only")
    ap.add_argument("--only", default="")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-smoke shapes for the kernels suite")
    ap.add_argument("--json-out", default="BENCH_kernels.json",
                    help="where the kernels suite writes its JSON rows")
    args = ap.parse_args()

    from . import kernels_bench, paper_tables

    suites = [
        ("table3", paper_tables.table3_formats),
        ("fig1", paper_tables.fig1_radix),
        ("fig2", paper_tables.fig2_comp_width),
        ("fig3", paper_tables.fig3_update_width),
        ("fig4", paper_tables.fig4_overflow_rate),
        ("kernels", lambda: kernels_bench.run(tiny=args.tiny)),
    ]
    if args.quick:
        suites = [s for s in suites if s[0] in ("table3", "kernels")]
    if args.only:
        suites = [s for s in suites if s[0] in args.only.split(",")]

    print("name,us_per_call,derived")
    for name, fn in suites:
        try:
            rows = list(fn())
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]:.4f}", flush=True)
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0,0  # {e}", file=sys.stderr)
            raise
        if name == "kernels" and args.json_out:
            import jax
            payload = {
                "meta": {"backend": jax.default_backend(),
                         "tiny": args.tiny},
                "rows": [{"name": n, "us_per_call": round(us, 1),
                          "derived": d} for n, us, d in rows],
            }
            with open(args.json_out, "w") as f:
                json.dump(payload, f, indent=1)
            print(f"# wrote {len(rows)} kernel rows -> {args.json_out}",
                  file=sys.stderr)


if __name__ == '__main__':
    main()
