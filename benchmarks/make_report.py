"""Emit the EXPERIMENTS.md §Dry-run and §Roofline tables from results."""
from __future__ import annotations

import argparse
import json

from .roofline import NOTES, analyse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.jsonl")
    args = ap.parse_args()

    seen = {}
    for line in open(args.results):
        r = json.loads(line)
        if r.get("ok"):
            seen[(r["arch"], r["shape"], r["mesh"])] = r

    recs = sorted(seen.values(), key=lambda r: (r["arch"], r["shape"],
                                                r["mesh"]))
    print("### Dry-run (per-device, from the compiled artifact)\n")
    print("| arch | shape | mesh | compile_s | args_GB | temp_GB | "
          "flops/dev | HBM_GB/dev | coll_GB/dev | a2a | ag | ar |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        la = r.get("loop_aware", {})
        kinds = la.get("collective_by_kind", {})
        pd = r["per_device"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r['compile_s']:.0f} "
              f"| {pd['argument_bytes']/2**30:.2f} "
              f"| {pd['temp_bytes']/2**30:.2f} "
              f"| {la.get('flops', 0):.2e} "
              f"| {la.get('traffic_bytes', 0)/1e9:.1f} "
              f"| {la.get('collective_bytes', 0)/1e9:.2f} "
              f"| {kinds.get('all-to-all', 0)/1e9:.1f} "
              f"| {kinds.get('all-gather', 0)/1e9:.1f} "
              f"| {kinds.get('all-reduce', 0)/1e9:.1f} |")

    print("\n### Roofline (v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    print("| arch | shape | mesh | compute_s | memory_s | coll_s | "
          "bottleneck | useful | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        a = analyse(r)
        print(f"| {a['arch']} | {a['shape']} | {a['mesh']} "
              f"| {a['compute_s']:.3g} | {a['memory_s']:.3g} "
              f"| {a['coll_s']:.3g} | **{a['bottleneck']}** "
              f"| {a['useful_ratio']:.2f} "
              f"| {NOTES[a['bottleneck']].split(':')[0]} |")


if __name__ == "__main__":
    main()
