"""Elastic checkpointing: manifest + per-leaf arrays, restore-with-reshard."""
from .manager import CheckpointManager, restore_tree, save_tree  # noqa: F401
