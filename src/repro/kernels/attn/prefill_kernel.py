"""Pallas TPU kernel: fused chunked-prefill attention over a packed KV pool.

A fixed-size chunk of ``C`` query positions attends **directly on the
pool's storage containers** — tiles of int8/int16 K/V mantissas stream
from HBM and are dequantized in-register against the per-layer/per-slot
power-of-two step, exactly like the flash-decode kernel
(:mod:`repro.kernels.attn.attn_kernel`) — plus its **own** chunk K/V in
f32, taken from the fresh projections rather than the pool so ring
eviction by the chunk's own write can never hide in-window keys.

Grid layout (compiled path)::

        grid = (B, K, nsplit + 1)        nsplit = ceil(W / block_w)

        q         [B, C, K, G, hd] -> tile [C, G, hd]      (one kv-head)
        k_new/v_new [B, C, K, hd]  -> tile [C, hd]         (f32 chunk KV)
        k/v       [B, W, K, hd]    -> tile [block_w, hd]   (pool storage)
        pos       [B, W]           -> tile [1, block_w]
        out       [B, C, K, G, hd] <- written on the last grid step

Splits ``0 .. nsplit-1`` walk the pool history (mask: ``0 <= pos < p0``,
window, ragged-tail bounds — all in-kernel, the pool is never padded or
copied); the final step ``nsplit`` scores the chunk against its own K/V
(causal ``j <= c``, ragged rows ``>= n_valid`` masked) and performs the
``acc / l`` reduction.  VMEM scratch carries the running
``(m, l, acc)`` with rows flattened to ``C*G`` (query position major),
combined across steps with the standard flash correction.

Interpret mode (any non-TPU backend) runs ONE grid step on full-shape
blocks and executes :func:`repro.kernels.attn.ref.chunk_attend` verbatim
on the dequantized arrays — identical ops on identical shapes, making
the fused kernel **bit**-identical to the composite on CPU (the contract
every kernel family in this repo keeps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as R
from .attn_kernel import _VMEM, _dequant


def _batched_kernel(p0_ref, nv_ref, steps_ref, q_ref, kn_ref, vn_ref, k_ref,
                    v_ref, pos_ref, o_ref, *, width, scale: float, window,
                    causal: bool):
    """One grid step, full-shape blocks: ref.chunk_attend on loaded arrays."""
    exp = (slice(None), None, None, None)
    kf = _dequant(k_ref[...], steps_ref[...][:, 0][exp], width)
    vf = _dequant(v_ref[...], steps_ref[...][:, 1][exp], width)
    o_ref[...] = R.chunk_attend(q_ref[...], kf, vf, pos_ref[...],
                                kn_ref[...], vn_ref[...], p0_ref[:, 0],
                                nv_ref[:, 0], scale=scale, window=window,
                                causal=causal)


def _split_kernel(p0_ref, nv_ref, steps_ref, q_ref, kn_ref, vn_ref, k_ref,
                  v_ref, pos_ref, o_ref, m_ref, l_ref, acc_ref, *, width,
                  scale: float, window, causal: bool, nsplit: int, C: int,
                  G: int, hd: int, block_w: int, W: int):
    r = pl.program_id(2)
    rows = C * G

    @pl.when(r == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, -jnp.inf, m_ref.dtype)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qf = q_ref[...].reshape(rows, hd)           # row = c * G + g
    cidx = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // G
    p0 = p0_ref[0, 0]
    nv = nv_ref[0, 0]

    def _update(kf, vf, valid):
        s = jax.lax.dot_general(qf, kf, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, -1e30)
        m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_ref[...] - m_new)      # exp(-inf - m) == 0 on init
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, vf, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(r < nsplit)
    def _history():
        kf = _dequant(k_ref[...].reshape(block_w, hd), steps_ref[0, 0], width)
        vf = _dequant(v_ref[...].reshape(block_w, hd), steps_ref[0, 1], width)
        pos = pos_ref[...]                      # [1, block_w] int32
        lane = jax.lax.broadcasted_iota(jnp.int32, (1, block_w), 1)
        inb = r * block_w + lane < W            # ragged last split
        vf = jnp.where(inb.reshape(block_w, 1), vf, 0.0)
        d = (p0 + cidx) - pos                   # [rows, block_w]
        valid = inb & (pos >= 0) & (pos < p0) & (cidx < nv)
        if causal:
            valid = valid & (d >= 0)
        if window:
            valid = valid & (d < window)
        _update(kf, vf, valid)

    @pl.when(r == nsplit)
    def _self_and_done():
        knf = kn_ref[...].reshape(C, hd)
        vnf = vn_ref[...].reshape(C, hd)
        j = jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)
        dj = cidx - j                           # [rows, C]
        valid = (cidx < nv) & (j < nv)
        if causal:
            valid = valid & (dj >= 0)
        if window:
            valid = valid & (dj < window)
        _update(knf, vnf, valid)
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out.reshape(1, C, 1, G, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "width", "block_w", "scale", "window", "causal", "interpret"))
def flash_prefill_call(q, k_new, v_new, k, v, pos, p0, nv, steps, *, width,
                       block_w: int, scale: float, window, causal: bool,
                       interpret: bool):
    """Blocked chunked-prefill over the raw (unpadded) pool buffers.

    ``q``: f32 [B, C, K, G, hd] · ``k_new``/``v_new``: f32 [B, C, K, hd] ·
    ``k``/``v``: int8/int16/f32 [B, W, K, hd] · ``pos``: int32 [B, W] ·
    ``p0``/``nv``: int32 [B, 1] · ``steps``: f32 [B, 2] dequant steps.
    Returns f32 [B, C, K, G, hd].  ``W`` need not be a ``block_w``
    multiple; ``block_w >= W`` in interpret mode runs the single-step
    full-shape body (bit-identical to ``ref.chunk_attend``).
    """
    B, C, K, G, hd = q.shape
    W = k.shape[1]
    out_shape = jax.ShapeDtypeStruct((B, C, K, G, hd), jnp.float32)

    if interpret and (block_w >= W or _VMEM is None):
        return pl.pallas_call(
            functools.partial(_batched_kernel, width=width, scale=scale,
                              window=window, causal=causal),
            out_shape=out_shape,
            interpret=True,
        )(p0, nv, steps, q, k_new, v_new, k, v, pos)
    if _VMEM is None:  # pragma: no cover — compiled TPU implies pltpu
        raise RuntimeError(
            "split-K flash-prefill needs jax.experimental.pallas.tpu "
            "memory spaces for its VMEM scratch")

    nsplit = pl.cdiv(W, block_w)
    # history splits walk the pool; the last grid step re-reads split
    # nsplit-1's tile (clamped index) but only touches the chunk's own KV
    last = nsplit - 1
    return pl.pallas_call(
        functools.partial(_split_kernel, width=width, scale=scale,
                          window=window, causal=causal, nsplit=nsplit,
                          C=C, G=G, hd=hd, block_w=block_w, W=W),
        grid=(B, K, nsplit + 1),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, r: (b, 0)),            # p0
            pl.BlockSpec((1, 1), lambda b, h, r: (b, 0)),            # nv
            pl.BlockSpec((1, 2), lambda b, h, r: (b, 0)),            # steps
            pl.BlockSpec((1, C, 1, G, hd), lambda b, h, r: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, C, 1, hd), lambda b, h, r: (b, 0, h, 0)),  # kn
            pl.BlockSpec((1, C, 1, hd), lambda b, h, r: (b, 0, h, 0)),  # vn
            pl.BlockSpec((1, block_w, 1, hd),
                         lambda b, h, r: (b, jnp.minimum(r, last), h, 0)),
            pl.BlockSpec((1, block_w, 1, hd),
                         lambda b, h, r: (b, jnp.minimum(r, last), h, 0)),
            pl.BlockSpec((1, block_w),
                         lambda b, h, r: (b, jnp.minimum(r, last))),  # pos
        ],
        out_specs=pl.BlockSpec((1, C, 1, G, hd),
                               lambda b, h, r: (b, 0, h, 0, 0)),
        out_shape=out_shape,
        scratch_shapes=[_VMEM((C * G, 1), jnp.float32),    # running max
                        _VMEM((C * G, 1), jnp.float32),    # denominator
                        _VMEM((C * G, hd), jnp.float32)],  # numerator
        interpret=interpret,
    )(p0, nv, steps, q, k_new, v_new, k, v, pos)


# -- paged variant: one extra block-table indirection ---------------------
#
# History splits walk the request's mapped pages (split r streams page
# bt[b, r] via a scalar-prefetch index_map) instead of its ring rows;
# masking is unchanged — rows the request never wrote, including the
# whole null page 0, carry pos == -1 — and the chunk's own K/V block
# (grid step nblocks) is identical to the slot-major kernel.

try:  # pragma: no cover — exercised only where pltpu imports
    from jax.experimental.pallas import tpu as _pltpu
except Exception:
    _pltpu = None


def _paged_split_kernel(bt_ref, p0_ref, nv_ref, steps_ref, q_ref, kn_ref,
                        vn_ref, k_ref, v_ref, pos_ref, o_ref, m_ref, l_ref,
                        acc_ref, *, width, scale: float, window,
                        causal: bool, nblocks: int, C: int, G: int, hd: int,
                        P: int):
    r = pl.program_id(2)
    rows = C * G

    @pl.when(r == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, -jnp.inf, m_ref.dtype)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qf = q_ref[...].reshape(rows, hd)           # row = c * G + g
    cidx = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // G
    p0 = p0_ref[0, 0]
    nv = nv_ref[0, 0]

    def _update(kf, vf, valid):
        s = jax.lax.dot_general(qf, kf, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, -1e30)
        m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, vf, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(r < nblocks)
    def _history():
        kf = _dequant(k_ref[...].reshape(P, hd), steps_ref[0, 0], width)
        vf = _dequant(v_ref[...].reshape(P, hd), steps_ref[0, 1], width)
        pos = pos_ref[...]                      # [1, P] logical positions
        d = (p0 + cidx) - pos                   # [rows, P]
        valid = (pos >= 0) & (pos < p0) & (cidx < nv)
        if causal:
            valid = valid & (d >= 0)
        if window:
            valid = valid & (d < window)
        _update(kf, vf, valid)

    @pl.when(r == nblocks)
    def _self_and_done():
        knf = kn_ref[...].reshape(C, hd)
        vnf = vn_ref[...].reshape(C, hd)
        j = jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)
        dj = cidx - j                           # [rows, C]
        valid = (cidx < nv) & (j < nv)
        if causal:
            valid = valid & (dj >= 0)
        if window:
            valid = valid & (dj < window)
        _update(knf, vnf, valid)
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out.reshape(1, C, 1, G, hd).astype(o_ref.dtype)


def _paged_batched_kernel(bt_ref, p0_ref, nv_ref, steps_ref, q_ref, kn_ref,
                          vn_ref, k_ref, v_ref, pos_ref, o_ref, *, width,
                          scale: float, window, causal: bool):
    """One grid step, full shapes: the ref composite through the gather."""
    bt = bt_ref[...]
    kf = jnp.take(k_ref[...], bt, axis=0).astype(jnp.float32)
    vf = jnp.take(v_ref[...], bt, axis=0).astype(jnp.float32)
    if width is not None:
        kf = kf * jnp.take(steps_ref[...][:, 0], bt)[..., None, None, None]
        vf = vf * jnp.take(steps_ref[...][:, 1], bt)[..., None, None, None]
    B, nblocks, P = kf.shape[:3]
    shp = (B, nblocks * P) + kf.shape[3:]
    o_ref[...] = R.chunk_attend(q_ref[...], kf.reshape(shp), vf.reshape(shp),
                                pos_ref[...], kn_ref[...], vn_ref[...],
                                p0_ref[:, 0], nv_ref[:, 0], scale=scale,
                                window=window, causal=causal)


@functools.partial(jax.jit, static_argnames=(
    "width", "scale", "window", "causal", "interpret", "force_split"))
def flash_prefill_paged_call(q, k_new, v_new, k, v, bt, pos, p0, nv, steps,
                             *, width, scale: float, window, causal: bool,
                             interpret: bool, force_split: bool = False):
    """Blocked chunked-prefill through a per-request block table.

    ``q``: f32 [B, C, K, G, hd] · ``k_new``/``v_new``: f32 [B, C, K, hd] ·
    ``k``/``v``: int8/int16/f32 [n_pages, P, K, hd] page arenas · ``bt``:
    int32 [B, nblocks] · ``pos``: int32 [B, nblocks·P] · ``p0``/``nv``:
    int32 [B, 1] · ``steps``: f32 [n_pages, 2] per-page dequant steps.
    Returns f32 [B, C, K, G, hd].  Interpret mode runs the full-shape
    gather body (bit-identical to ``ref.paged_prefill_attention_ref``)
    unless ``force_split`` exercises the scalar-prefetch split path.
    """
    B, C, K, G, hd = q.shape
    P = k.shape[1]
    nblocks = bt.shape[1]
    out_shape = jax.ShapeDtypeStruct((B, C, K, G, hd), jnp.float32)

    if interpret and not force_split:
        return pl.pallas_call(
            functools.partial(_paged_batched_kernel, width=width, scale=scale,
                              window=window, causal=causal),
            out_shape=out_shape,
            interpret=True,
        )(bt, p0, nv, steps, q, k_new, v_new, k, v, pos)
    if _pltpu is None:  # pragma: no cover — compiled TPU implies pltpu
        raise RuntimeError(
            "paged flash-prefill needs jax.experimental.pallas.tpu for "
            "scalar-prefetch block-table index maps")

    last = nblocks - 1   # step nblocks re-reads a clamped page tile
    grid_spec = _pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, nblocks + 1),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, r, bt: (b, 0)),        # p0
            pl.BlockSpec((1, 1), lambda b, h, r, bt: (b, 0)),        # nv
            pl.BlockSpec((1, 2),
                         lambda b, h, r, bt: (bt[b, jnp.minimum(r, last)],
                                              0)),                   # steps
            pl.BlockSpec((1, C, 1, G, hd),
                         lambda b, h, r, bt: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, C, 1, hd),
                         lambda b, h, r, bt: (b, 0, h, 0)),          # kn
            pl.BlockSpec((1, C, 1, hd),
                         lambda b, h, r, bt: (b, 0, h, 0)),          # vn
            pl.BlockSpec((1, P, 1, hd),
                         lambda b, h, r, bt: (bt[b, jnp.minimum(r, last)],
                                              0, h, 0)),             # k page
            pl.BlockSpec((1, P, 1, hd),
                         lambda b, h, r, bt: (bt[b, jnp.minimum(r, last)],
                                              0, h, 0)),             # v page
            pl.BlockSpec((1, P),
                         lambda b, h, r, bt: (b, jnp.minimum(r, last))),
        ],
        out_specs=pl.BlockSpec((1, C, 1, G, hd),
                               lambda b, h, r, bt: (b, 0, h, 0, 0)),
        scratch_shapes=[_VMEM((C * G, 1), jnp.float32),    # running max
                        _VMEM((C * G, 1), jnp.float32),    # denominator
                        _VMEM((C * G, hd), jnp.float32)],  # numerator
    )
    return pl.pallas_call(
        functools.partial(_paged_split_kernel, width=width, scale=scale,
                          window=window, causal=causal, nblocks=nblocks,
                          C=C, G=G, hd=hd, P=P),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(bt, p0, nv, steps, q, k_new, v_new, k, v, pos)
