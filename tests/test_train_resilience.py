"""Fault-tolerant training: sentinels, rollback, bit-exact resume, chaos."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import PrecisionPolicy
from repro.data import SyntheticImages
from repro.models import maxout as MX
from repro.optim.opt import OptConfig, sgd_init
from repro.train import (FaultHarness, GradNaN, LossSpike, ParamBitFlip,
                         StepOutcome, TrainSupervisor, chaos_plan,
                         init_train_state)
from repro.train.faults import CkptTear

CFG = MX.MaxoutConfig(hidden=(48, 48), pieces=3)
GS = MX.group_shapes(CFG)
OPT = OptConfig(kind="sgd", lr=0.1, lr_decay_steps=2000, max_col_norm=1.9365)
DATA = SyntheticImages()

DFXP = PrecisionPolicy("dfxp", comp_width=10, update_width=12,
                       update_interval=4)


def _loss_fn(policy):
    def loss_fn(p, b, s, exps):
        return MX.loss_fn(CFG, policy, p, b, exps, s,
                          rng=jax.random.PRNGKey(1))
    return loss_fn


def _batch_fn(cursor):
    b = DATA.batch(cursor, 64)
    return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}


def _state(policy, seed=7):
    params = MX.init_params(CFG, jax.random.PRNGKey(seed))
    return init_train_state(params, sgd_init(params), GS, policy,
                            init_exp=-8.0)


def _sup(policy=DFXP, **kw):
    kw.setdefault("batch_fn", _batch_fn)
    kw.setdefault("rng", jax.random.PRNGKey(0))
    return TrainSupervisor(_loss_fn(policy), GS, policy, OPT,
                           _state(policy), **kw)


# ---------------------------------------------------------------- sentinels


def test_sentinel_skips_and_preserves_state():
    """A poisoned step is SKIPPED on device: TrainState does not advance,
    the data cursor does, and the next clean step proceeds."""
    h = FaultHarness([GradNaN(step=2), LossSpike(step=5)])
    sup = _sup(faults=h, skip_budget=10)
    summary = sup.run(8)
    outs = [r.outcome for r in sup.outcomes]
    assert outs[2] is StepOutcome.SKIPPED
    assert outs[5] is StepOutcome.SKIPPED
    assert summary["outcomes"]["ok"] == 6
    assert summary["steps_committed"] == 6      # skips never hit the state
    assert summary["cursor"] == 8               # but the cursor moved on
    assert all(np.isfinite(loss) for loss in sup.losses)
    kinds = {e["kind"] for e in h.log}
    assert "grad_nan" in kinds and "loss_spike" in kinds


def test_skipped_step_is_identical_to_never_poisoned():
    """The in-jit discard is total: a run with a skipped step ends bit-
    identical to a run where that batch's update simply never happened."""
    h = FaultHarness([GradNaN(step=3)])
    a = _sup(faults=h, skip_budget=10)
    a.run(6)
    b = _sup(skip_budget=10)
    b.run(6)
    # b consumed batch 3 productively, a skipped it: align by replaying
    # b without cursor 3's update — easiest exact check: state after a's
    # 6 attempts == training only on batches [0,1,2,4,5].
    c = _sup(skip_budget=10,
             batch_fn=lambda i: _batch_fn(i if i < 3 else i + 1))
    c.run(5)
    for x, y in zip(jax.tree.leaves(a.state.params),
                    jax.tree.leaves(c.state.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_runaway_overflow_sentinel_fires():
    """An absurdly low runaway threshold trips the §5 overflow sentinel
    (quantizing anything overflows at some rate > 0 with exps at -8)."""
    sup = _sup(runaway_ovf=1e-12, skip_budget=1000)
    sup.run(3)
    skipped = [r for r in sup.outcomes if r.outcome is StepOutcome.SKIPPED]
    assert skipped, [r.outcome for r in sup.outcomes]
    assert any("runaway_ovf" in r.info.get("sentinels", ())
               for r in skipped)


# ---------------------------------------------------------------- rollback


def test_skip_budget_exhaustion_rolls_back(tmp_path):
    """A poison burst longer than the skip budget triggers a rollback to
    the last committed checkpoint; training continues past the burst."""
    mgr = CheckpointManager(str(tmp_path))
    h = FaultHarness([GradNaN(step=4, count=4)])
    sup = _sup(manager=mgr, ckpt_every=2, skip_budget=2, faults=h)
    summary = sup.run(12)
    outs = [r.outcome for r in sup.outcomes]
    assert StepOutcome.ROLLED_BACK in outs
    rb = outs.index(StepOutcome.ROLLED_BACK)
    assert sup.outcomes[rb].info["restored"] == 4   # ckpt at cursor 4
    # after the burst window, training resumed cleanly
    assert outs[-1] is StepOutcome.OK
    assert not summary["halted"]
    assert summary["outcomes"]["rolled_back"] >= 1
    # cursor kept its advanced value: the poisoned window is not replayed
    assert summary["cursor"] == 12


def test_double_rollback_failure_halts_with_bundle(tmp_path):
    """No restorable checkpoint: two failed rollbacks escalate to HALTED
    and the diagnostic bundle is written; run() resolves, never raises."""
    from repro.obs import NumericsLog, Tracer
    bundle = str(tmp_path / "bundle")
    h = FaultHarness([GradNaN(step=0, count=100)])
    sup = _sup(manager=None, skip_budget=1, faults=h, tracer=Tracer(),
               numerics_log=NumericsLog(), bundle_dir=bundle)
    summary = sup.run(50)
    assert summary["halted"]
    outs = [r.outcome for r in sup.outcomes]
    assert outs[-1] is StepOutcome.HALTED
    assert outs.count(StepOutcome.ROLLED_BACK) == 1   # first failure
    assert summary["attempts"] < 50                   # stopped early
    for fname in ("outcomes.json", "summary.json", "faults.json",
                  "trace.json"):
        assert os.path.exists(os.path.join(bundle, fname)), fname
    with open(os.path.join(bundle, "outcomes.json")) as f:
        recs = json.load(f)
    assert recs[-1]["outcome"] == "halted"
    with pytest.raises(RuntimeError):
        sup.step_once()                               # halted stays halted


# ---------------------------------------------------------- bit-exact resume


def _resume_pair(policy, *, tmp_path, n=10, k=6, compress_bits=None,
                 seed=0):
    """Train ``n`` straight vs train ``k``, 'crash', restore, train n-k.

    Returns (solo_losses, resumed_losses, solo_state, resumed_state).
    """
    solo = _sup(policy, compress_bits=compress_bits,
                rng=jax.random.PRNGKey(seed))
    solo.run(n)

    d = str(tmp_path / "ck")
    first = _sup(policy, compress_bits=compress_bits,
                 rng=jax.random.PRNGKey(seed),
                 manager=CheckpointManager(d))
    first.run(k)                     # run() commits synchronously at end
    del first                        # the "crash"

    second = _sup(policy, compress_bits=compress_bits,
                  rng=jax.random.PRNGKey(4242),   # wrong seed on purpose:
                  manager=CheckpointManager(d))   # ckpt must carry the key
    assert second.resume() == k
    second.run(n - k)
    return solo, second


def _assert_bit_identical(solo, resumed, k):
    assert solo.losses[k:] == resumed.losses
    for a, b in zip(jax.tree.leaves(solo.ckpt_tree()),
                    jax.tree.leaves(resumed.ckpt_tree())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bit_exact_resume_deterministic(tmp_path):
    """K=6 lands mid-§5-window (interval 4): the pre-reset acc counters
    must be checkpointed for the cursor-8 controller move to agree."""
    solo, resumed = _resume_pair(DFXP, tmp_path=tmp_path, n=10, k=6)
    _assert_bit_identical(solo, resumed, 6)


def test_bit_exact_resume_stochastic_fused(tmp_path):
    """Stochastic rounding + fused matmul: the per-step key derives from
    the checkpointed base key and cursor, so the random stream continues
    exactly."""
    pol = dataclasses.replace(DFXP, stochastic_rounding=True,
                              fused_matmul=True)
    solo, resumed = _resume_pair(pol, tmp_path=tmp_path, n=9, k=5)
    _assert_bit_identical(solo, resumed, 5)


def test_bit_exact_resume_error_feedback_packed(tmp_path):
    """Error-feedback residuals + packed int16 storage survive the crash:
    forgetting either breaks bitwise equality immediately."""
    pol = dataclasses.replace(DFXP, storage="packed")
    solo, resumed = _resume_pair(pol, tmp_path=tmp_path, n=8, k=5,
                                 compress_bits=8)
    # the residuals themselves must be nonzero for this test to bite
    assert any(float(jnp.max(jnp.abs(leaf))) > 0
               for leaf in jax.tree.leaves(solo.ef))
    _assert_bit_identical(solo, resumed, 5)


# -------------------------------------------------------------- host faults


def test_param_bit_flip_packed_and_sim_skip(tmp_path):
    pol = dataclasses.replace(DFXP, storage="packed")
    h = FaultHarness([ParamBitFlip(step=2, bit=6)])
    sup = _sup(pol, faults=h, skip_budget=100)
    sup.run(5)
    assert any(e["kind"] == "bit_flip" for e in h.log)
    # sim storage has no mantissa: the injector skips with a reason
    h2 = FaultHarness([ParamBitFlip(step=2)])
    sup2 = _sup(DFXP, faults=h2, skip_budget=100)
    sup2.run(4)
    assert any(e["kind"] == "bit_flip_skipped" for e in h2.log)


@pytest.mark.parametrize("mode", ["strip", "corrupt"])
def test_ckpt_tear_falls_back_to_previous_commit(tmp_path, mode):
    """Tearing the newest checkpoint (strip _COMMITTED / corrupt a leaf
    against its CRC) makes restore fall back to the previous commit."""
    mgr = CheckpointManager(str(tmp_path))
    sup = _sup(manager=mgr, ckpt_every=2)
    sup.run(6)                       # commits at 2, 4, 6
    mgr.wait()
    h = FaultHarness([CkptTear(step=0, mode=mode)])
    h._tear(sup, h.faults[0], 0)
    assert any(e["kind"] == "ckpt_tear" for e in h.log)
    tree, step = mgr.restore_latest(sup.ckpt_template())
    assert step == 4                 # newest (6) torn -> previous commit
    assert int(np.asarray(tree["cursor"])) == 4


def test_ckpt_tear_writer_surfaces_on_wait(tmp_path):
    """Writer death mid-save: save_async captures the failure and the
    supervisor's next commit logs it instead of raising."""
    mgr = CheckpointManager(str(tmp_path), retries=0, backoff_s=0.0)
    h = FaultHarness([CkptTear(step=1, mode="writer")])
    sup = _sup(manager=mgr, ckpt_every=2, faults=h)
    summary = sup.run(6)
    assert not summary["halted"]
    assert summary["outcomes"]["ok"] == 6
    kinds = [e["kind"] for e in h.log]
    assert "ckpt_tear" in kinds
    assert any(k in ("sup:ckpt_async_error", "sup:ckpt_write_error")
               for k in kinds), kinds
    # the run still ended with a good committed checkpoint (final sync
    # save happens after the injected failure budget is exhausted)
    assert mgr.latest() is not None


# -------------------------------------------------------------------- chaos


@pytest.mark.parametrize("seed", [0, 1])
def test_chaos_sweep_every_step_resolves(tmp_path, seed):
    """A seeded fault mix (NaN bursts, spikes, tears, bit flips) always
    terminates with every attempt resolved to an outcome — no raw
    tracebacks, no unresolved steps."""
    from repro.obs import MetricsRegistry, Tracer
    pol = dataclasses.replace(DFXP, storage="packed")
    faults = chaos_plan(seed, n_steps=14, burst=4)
    assert faults                     # both seeds draw a non-empty plan
    mgr = CheckpointManager(str(tmp_path), retries=0, backoff_s=0.0)
    h = FaultHarness(faults, seed=seed, tracer=Tracer(),
                     metrics=MetricsRegistry())
    sup = _sup(pol, manager=mgr, ckpt_every=2, skip_budget=2, faults=h,
               bundle_dir=str(tmp_path / "bundle"))
    summary = sup.run(14)
    assert summary["attempts"] == len(sup.outcomes)
    assert all(isinstance(r.outcome, StepOutcome) for r in sup.outcomes)
    assert sum(summary["outcomes"].values()) == summary["attempts"]
    # same seed -> same plan (reproducibility of the sweep itself)
    again = chaos_plan(seed, n_steps=14, burst=4)
    assert [type(f).__name__ for f in again] == \
           [type(f).__name__ for f in faults]
    # fault log serializes (the CI artifact)
    json.dumps(h.summary())


def test_supervisor_outcome_counters_in_metrics():
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()
    h = FaultHarness([GradNaN(step=1)], metrics=reg)
    sup = _sup(faults=h, skip_budget=10, metrics=reg)
    sup.run(4)
    snap = reg.snapshot()
    assert snap["train_steps_ok"]["value"] == 3
    assert snap["train_steps_skipped"]["value"] == 1
    assert snap["train_faults_injected"]["value"] == 1
