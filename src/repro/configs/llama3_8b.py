"""llama3-8b [dense]: GQA, 128k vocab. [arXiv:2407.21783]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
    vocab_size=128256, rope_theta=5e5, tie_embeddings=False)

SMOKE = ModelConfig(
    name="llama3-smoke", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    tie_embeddings=False)

# pure full attention -> long_500k skipped (DESIGN.md §6)
CELLS = ("train_4k", "prefill_32k", "decode_32k")
