"""Kernel microbenchmarks: fused Pallas quantize / qmatmul vs jnp composite.

On this CPU container the Pallas kernels run in interpret mode, so absolute
times measure the *reference semantics*, not TPU perf; the jnp-composite
rows are the ones that time real XLA-compiled code. Roofline projections
for the TPU kernel live in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.quant import fixed_round
from repro.kernels.dfxp.ops import dfxp_quantize
from repro.kernels.qmatmul.ops import qmatmul
from repro.kernels.qmatmul.ref import qmatmul_ref


def _time(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    out = []
    x = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024))
    e = jnp.float32(-6)

    jnp_q = jax.jit(lambda x, e: fixed_round(x, 10, e))
    out.append(("kernels/quantize_jnp_1024x1024", _time(jnp_q, x, e), 1.0))
    out.append(("kernels/quantize_pallas_interp_1024x1024",
                _time(lambda x, e: dfxp_quantize(x, e, width=10,
                                                 interpret=True), x, e), 1.0))

    a = jax.random.normal(jax.random.PRNGKey(1), (256, 512))
    b = jax.random.normal(jax.random.PRNGKey(2), (512, 256))
    ref = jax.jit(lambda a, b: qmatmul_ref(a, b, e, e, width=10))
    out.append(("kernels/qmatmul_jnp_256x512x256", _time(ref, a, b),
                2 * 256 * 512 * 256 / 1e6))
    out.append(("kernels/qmatmul_pallas_interp_256x512x256",
                _time(lambda a, b: qmatmul(a, b, e, e, width=10,
                                           interpret=True), a, b),
                2 * 256 * 512 * 256 / 1e6))
    return out
