"""Token samplers with per-request PRNG streams.

Each request owns an independent key chain derived from ``(engine seed,
request uid)``; the key for a sampled token is ``fold_in(request_key,
absolute_position)``.  A request therefore draws the *same* random stream
whether it runs alone, lockstep-batched, or admitted mid-decode into a
freed slot — the property the continuous-batching equivalence tests pin.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

KINDS = ("greedy", "temperature", "top_k")


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    kind: str = "greedy"       # greedy | temperature | top_k
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown sampler {self.kind!r} (of {KINDS})")
        if self.kind == "top_k" and self.top_k <= 0:
            raise ValueError("top_k sampler needs top_k > 0")


def request_key(seed: int, uid: int) -> Array:
    """The root of one request's PRNG stream."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), uid)


def position_keys(req_keys: Array, pos: Array) -> Array:
    """Per-request keys for the token generated at ``pos`` [B]."""
    return jax.vmap(jax.random.fold_in)(req_keys, pos)


def guard_logits(logits: Array):
    """Device-side numeric sentinel: split non-finite rows out of a batch.

    Returns ``(safe_logits, bad)`` where ``bad`` is a bool [B] flag —
    True for any row containing a NaN/Inf — and ``safe_logits`` has
    those rows zeroed so :func:`sample` stays well-defined (``argmax``
    over NaN and ``categorical`` over NaN both produce garbage indices
    that would poison downstream host bookkeeping).  The engine harvests
    ``bad`` with the sampled tokens — one device sync, no extra
    round-trip — and quarantines flagged slots instead of crashing the
    batch.
    """
    bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
    safe = jnp.where(bad[..., None], 0.0, logits)
    return safe, bad


def sample(logits: Array, keys: Array, cfg: SamplerConfig) -> Array:
    """Draw one token per row. ``logits``: [B, V]; ``keys``: [B, 2]."""
    if cfg.kind == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / max(cfg.temperature, 1e-6)
    if cfg.kind == "top_k":
        kth = jax.lax.top_k(scaled, cfg.top_k)[0][:, -1:]
        scaled = jnp.where(scaled < kth, -1e30, scaled)
    toks = jax.vmap(jax.random.categorical)(keys, scaled)
    return toks.astype(jnp.int32)
