"""Fault-tolerant training: the supervised step loop.

The serve engine (PR 7) resolves every request to a terminal status and
never raises for load or faults; this module gives training the same
contract.  :class:`TrainSupervisor` wraps :func:`repro.train.step.
make_train_step` (``supervise=True``) and resolves every step attempt to
a :class:`StepOutcome`:

* ``OK`` — sentinels clean, update committed on device;
* ``SKIPPED`` — a device-side sentinel tripped (non-finite loss/grad, or
  a §5 runaway-overflow rate per tensor class): the update was discarded
  *inside the jit* (branch-free select — the step still costs one extra
  scalar fetch), the data cursor advances past the batch;
* ``ROLLED_BACK`` — ``skip_budget`` consecutive skips exhausted: restore
  the last committed checkpoint (walking past corrupt ones) and continue
  with the *advanced* data cursor, so the poisoned batch window is never
  replayed against the restored state;
* ``HALTED`` — rollback failed twice: a diagnostic bundle (obs trace,
  numerics JSONL tail, outcome log, fault log) is written and the run
  stops resolving instead of raising.

Bit-exact resume is the checkpoint contract: the saved tree covers the
:class:`~repro.train.state.TrainState` (params/opt/scale — DFXP
exponents AND the pre-reset §5 ``acc`` windows), the stochastic-rounding
base PRNG key, the dist error-feedback residual buffers, and the data
cursor.  ``train N steps solo == train K, crash, restore, train N-K``
holds bit-for-bit, for deterministic and stochastic rounding (the
per-step key derives from ``fold_in(base, cursor)``, both checkpointed).
"""
from __future__ import annotations

import dataclasses
import enum
import json
import os
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointError, CheckpointManager
from repro.core.policy import PrecisionPolicy
from repro.optim.opt import OptConfig

from .state import TrainState
from .step import (FLAG_GRAD_NONFINITE, FLAG_LOSS_NONFINITE,
                   FLAG_RUNAWAY_OVF, benign_injection, make_train_step)

Array = jax.Array


class StepOutcome(enum.Enum):
    OK = "ok"
    SKIPPED = "skipped"
    ROLLED_BACK = "rolled_back"
    HALTED = "halted"


@dataclasses.dataclass
class StepRecord:
    cursor: int                 # data cursor of the attempt
    outcome: StepOutcome
    flags: int                  # sentinel bitmask (step.FLAG_*)
    loss: float
    info: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {"cursor": self.cursor, "outcome": self.outcome.value,
                "flags": self.flags, "loss": self.loss, **self.info}


def flag_names(flags: int) -> List[str]:
    out = []
    if flags & FLAG_LOSS_NONFINITE:
        out.append("loss_nonfinite")
    if flags & FLAG_GRAD_NONFINITE:
        out.append("grad_nonfinite")
    if flags & FLAG_RUNAWAY_OVF:
        out.append("runaway_ovf")
    return out


class TrainSupervisor:
    """Supervised train loop: sentinels, skip budget, rollback, resume.

    Parameters mirror :func:`make_train_step` plus:

    * ``batch_fn(cursor) -> batch`` — the deterministic data pipeline
      (cursor is the checkpointed data position; batches must be a pure
      function of it, as :class:`repro.data.SyntheticLM` is of its step).
    * ``rng`` — base PRNG key; the per-step stochastic-rounding key is
      ``fold_in(rng, cursor)``.  Saved in the checkpoint, so resume does
      not even need the original seed.
    * ``manager``/``ckpt_every`` — checkpoint cadence (async writes; the
      final :meth:`commit` is synchronous).  Checkpoints are keyed by the
      data cursor, which is monotonic even across skips.
    * ``skip_budget`` — consecutive SKIPPED attempts tolerated before a
      rollback.
    * ``compress_bits`` — run gradients through
      :func:`repro.dist.compress.compress_tree` error feedback; the
      residual buffers become part of the checkpointed state.
    * ``faults`` — a :class:`repro.train.faults.FaultHarness`.
    * ``tracer``/``metrics``/``numerics_log`` — repro.obs hooks; all
      optional and zero-cost when absent.
    * ``bundle_dir`` — where the HALTED diagnostic bundle lands.
    """

    def __init__(self, loss_fn: Callable, group_shapes: Dict[str, tuple],
                 policy: PrecisionPolicy, opt_cfg: OptConfig,
                 state: TrainState, *,
                 batch_fn: Callable[[int], dict],
                 rng: Array,
                 manager: Optional[CheckpointManager] = None,
                 ckpt_every: int = 0,
                 skip_budget: int = 3,
                 runaway_ovf: Optional[float] = None,
                 compress_bits: Optional[int] = None,
                 microbatches: int = 1,
                 grad_transform: Optional[Callable] = None,
                 faults=None, tracer=None, metrics=None,
                 numerics_log=None, numerics_every: int = 0,
                 bundle_dir: Optional[str] = None):
        self.state = state
        self.batch_fn = batch_fn
        self.rng = jnp.asarray(rng)
        self.manager = manager
        self.ckpt_every = ckpt_every
        self.skip_budget = skip_budget
        self.policy = policy
        self.faults = faults
        self.tracer = tracer
        self.numerics_log = numerics_log
        self.numerics_every = numerics_every or policy.update_interval
        self.bundle_dir = bundle_dir

        ef_transform = None
        if compress_bits is not None:
            from repro.dist.compress import compress_tree, ef_init

            def ef_transform(grads, ef):
                return compress_tree(grads, ef, compress_bits)

            self.ef = ef_init(state.params)
        else:
            self.ef = {}
        self._step_fn = jax.jit(make_train_step(
            loss_fn, group_shapes, policy, opt_cfg,
            microbatches=microbatches, grad_transform=grad_transform,
            numerics_tap=numerics_log is not None,
            ef_transform=ef_transform, supervise=True,
            runaway_ovf=runaway_ovf))

        self.cursor = 0                     # next data position
        self.outcomes: List[StepRecord] = []
        self.losses: List[float] = []       # committed (OK) losses
        self.halted = False
        self._consec_skips = 0
        self._rollback_failures = 0
        self._last_commit: Optional[int] = None

        if metrics is None:
            from repro.obs import MetricsRegistry
            metrics = MetricsRegistry()
        self.metrics = metrics
        self._c = {o: metrics.counter(f"train_steps_{o.value}")
                   for o in StepOutcome}
        self._c_ckpt = metrics.counter("train_ckpt_commits")
        self._c_ckpt_err = metrics.counter("train_ckpt_errors")
        self._c_rollback_fail = metrics.counter("train_rollback_failures")

    # -- checkpoint tree ---------------------------------------------------
    def ckpt_tree(self) -> dict:
        """Everything bit-exact resume needs, as one pytree."""
        return {"train": self.state, "ef": self.ef, "rng": self.rng,
                "cursor": jnp.int32(self.cursor)}

    def _adopt(self, tree: dict) -> None:
        self.state = tree["train"]
        self.ef = tree["ef"]
        self.rng = tree["rng"]

    def resume(self) -> Optional[int]:
        """Restore the newest clean committed checkpoint, if any.

        Returns the restored cursor (None when starting fresh).  Raises
        :class:`CheckpointError` only when checkpoints exist but every
        one fails verification — starting silently from step 0 in that
        situation would *look* like a resume.
        """
        if self.manager is None:
            return None
        try:
            tree, step = self.manager.restore_latest(self.ckpt_template())
        except FileNotFoundError:
            return None
        self._adopt(tree)
        self.cursor = int(np.asarray(tree["cursor"]))
        self._last_commit = step
        self._event("resumed", step=step, cursor=self.cursor)
        return self.cursor

    def ckpt_template(self) -> dict:
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                           jnp.result_type(x)),
            self.ckpt_tree())

    def commit(self, *, sync: bool = True) -> bool:
        """Write a checkpoint now.  Never raises: a failed write logs an
        event, bumps ``train_ckpt_errors``, and returns False."""
        if self.manager is None:
            return False
        try:
            self.manager.wait()
        except Exception as e:               # surfaced background failure
            self._c_ckpt_err.inc()
            self._event("ckpt_async_error", error=str(e))
        try:
            if sync:
                self.manager.save(self.cursor, self.ckpt_tree())
            else:
                self.manager.save_async(self.cursor, self.ckpt_tree())
        except Exception as e:
            self._c_ckpt_err.inc()
            self._event("ckpt_write_error", cursor=self.cursor,
                        error=str(e))
            return False
        self._last_commit = self.cursor
        self._c_ckpt.inc()
        return True

    # -- the supervised step ----------------------------------------------
    def step_once(self) -> StepRecord:
        """One supervised step attempt; resolves to a StepRecord."""
        if self.halted:
            raise RuntimeError("supervisor is HALTED; inspect the bundle "
                               f"at {self.bundle_dir!r}")
        if self.faults is not None:
            self.faults.on_step(self)
        inj = (self.faults.injection(self) if self.faults is not None
               else benign_injection())
        batch = self.batch_fn(self.cursor)
        rng = jax.random.fold_in(self.rng, self.cursor)
        span = (self.tracer.span("train_step", tid="train")
                if self.tracer is not None else None)
        if span is not None:
            span.__enter__()
        new_state, metrics, new_ef = self._step_fn(
            self.state, batch, rng, self.ef, inj)
        flags = int(np.asarray(metrics["flags"]))   # the one extra fetch
        loss = float(np.asarray(metrics["loss"]))
        if span is not None:
            span.__exit__(None, None, None)

        cursor = self.cursor
        self.cursor += 1
        self.state, self.ef = new_state, new_ef     # select ran on device
        if flags == 0:
            self._consec_skips = 0
            self.losses.append(loss)
            rec = StepRecord(cursor, StepOutcome.OK, flags, loss)
            self._log_numerics(metrics)
            if (self.manager is not None and self.ckpt_every
                    and self.cursor % self.ckpt_every == 0):
                self.commit(sync=False)
        else:
            self._consec_skips += 1
            rec = StepRecord(cursor, StepOutcome.SKIPPED, flags, loss,
                             {"sentinels": flag_names(flags),
                              "consec": self._consec_skips})
            self._event("sentinel_skip", cursor=cursor, flags=flags,
                        sentinels=flag_names(flags))
            if self._consec_skips > self.skip_budget:
                rec = self._rollback(rec)
        self.outcomes.append(rec)
        self._c[rec.outcome].inc()
        if rec.outcome is StepOutcome.HALTED:
            bundle = self.write_bundle()
            self._event("halted", cursor=rec.cursor, bundle=bundle)
        if self.tracer is not None and rec.outcome is not StepOutcome.OK:
            self.tracer.instant(f"train:{rec.outcome.value}", tid="train",
                                cursor=cursor, flags=flags)
        return rec

    def _rollback(self, rec: StepRecord) -> StepRecord:
        """Skip budget exhausted: restore the last committed checkpoint.

        The data cursor keeps its *advanced* value — the restored state
        continues on fresh batches instead of replaying the window that
        tripped the sentinels (a deterministic poison would loop
        forever otherwise).  Two failed rollbacks escalate to HALTED +
        diagnostic bundle.
        """
        self._consec_skips = 0
        restored = None
        if self.manager is not None:
            try:
                self.manager.wait()
            except Exception as e:
                self._event("ckpt_async_error", error=str(e))
            try:
                restored = self.manager.restore_latest(self.ckpt_template())
            except (FileNotFoundError, CheckpointError) as e:
                self._event("rollback_restore_failed", error=str(e))
        if restored is None:
            self._rollback_failures += 1
            self._c_rollback_fail.inc()
            if self._rollback_failures >= 2:
                self.halted = True
                # bundle is written by step_once AFTER this record lands
                # in the outcome log, so the bundle includes it
                return StepRecord(rec.cursor, StepOutcome.HALTED, rec.flags,
                                  rec.loss,
                                  {**rec.info, "bundle": self.bundle_dir})
            self._event("rollback_failed", cursor=rec.cursor,
                        failures=self._rollback_failures)
            return StepRecord(rec.cursor, StepOutcome.ROLLED_BACK, rec.flags,
                              rec.loss, {**rec.info, "restored": None})
        tree, step = restored
        self._adopt(tree)
        # cursor stays advanced: do NOT replay the poisoned window
        self.cursor = max(self.cursor, int(np.asarray(tree["cursor"])))
        self._last_commit = step
        self._event("rolled_back", to_step=step, cursor=self.cursor)
        return StepRecord(rec.cursor, StepOutcome.ROLLED_BACK, rec.flags,
                          rec.loss, {**rec.info, "restored": step})

    def run(self, num_steps: int, *, stop: Optional[Callable[[], bool]] = None,
            log_every: int = 0) -> dict:
        """Drive ``num_steps`` attempts (or until HALTED / ``stop()``).

        Never raises for faults — every attempt lands in
        :attr:`outcomes`; returns :meth:`summary`.
        """
        for _ in range(num_steps):
            if self.halted or (stop is not None and stop()):
                break
            rec = self.step_once()
            if log_every and rec.outcome is StepOutcome.OK and \
                    len(self.losses) % log_every == 0:
                print(f"step {int(self.state.step)}: loss={rec.loss:.4f}",
                      flush=True)
        if not self.halted:
            self.commit(sync=True)
        return self.summary()

    # -- reporting ---------------------------------------------------------
    def outcome_counts(self) -> Dict[str, int]:
        counts = {o.value: 0 for o in StepOutcome}
        for r in self.outcomes:
            counts[r.outcome.value] += 1
        return counts

    def summary(self) -> dict:
        return {
            "attempts": len(self.outcomes),
            "outcomes": self.outcome_counts(),
            "steps_committed": int(self.state.step),
            "cursor": self.cursor,
            "final_loss": self.losses[-1] if self.losses else None,
            "halted": self.halted,
            "rollback_failures": self._rollback_failures,
            "last_checkpoint": self._last_commit,
            "faults": (self.faults.summary()["event_counts"]
                       if self.faults is not None else {}),
        }

    def write_bundle(self, path: Optional[str] = None,
                     numerics_tail: int = 50) -> Optional[str]:
        """Write the diagnostic bundle: outcome log, summary, fault log,
        obs trace, numerics JSONL tail."""
        path = path or self.bundle_dir
        if path is None:
            return None
        os.makedirs(path, exist_ok=True)
        with open(os.path.join(path, "outcomes.json"), "w") as f:
            json.dump([r.to_json() for r in self.outcomes], f, indent=2)
        with open(os.path.join(path, "summary.json"), "w") as f:
            json.dump(self.summary(), f, indent=2)
        if self.faults is not None:
            with open(os.path.join(path, "faults.json"), "w") as f:
                json.dump(self.faults.summary(), f, indent=2)
        if self.tracer is not None:
            self.tracer.export(os.path.join(path, "trace.json"))
        if self.numerics_log is not None:
            with open(os.path.join(path, "numerics_tail.jsonl"), "w") as f:
                for r in self.numerics_log.tail(numerics_tail):
                    f.write(json.dumps(r) + "\n")
        return path

    # -- internals ---------------------------------------------------------
    def _event(self, kind: str, **kw) -> None:
        if self.faults is not None:
            self.faults.log_supervisor_event(kind, **kw)
        elif self.tracer is not None:
            self.tracer.instant(f"train:{kind}", tid="train", **kw)

    def _log_numerics(self, metrics) -> None:
        if self.numerics_log is None:
            return
        if len(self.losses) % self.numerics_every:
            return
        import time

        from repro.obs import train_records
        tap = jax.device_get(metrics["numerics"])
        for rec in train_records(tap["prev_exps"], tap["exps"], tap["acc"],
                                 step=int(self.state.step),
                                 t=time.perf_counter()):
            self.numerics_log.record(rec)
