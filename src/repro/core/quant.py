"""Quantizers + autodiff plumbing for low-precision training (paper §4-§7).

Simulation contract (paper §7): values are held in wide float containers but
are *representable* in the target format every time they cross a group
boundary — activations/weights on the forward pass, cotangents on the
backward pass, parameters at update time. Accumulations stay wide (the
paper's accumulator hypothesis == the TPU MXU f32-accumulate contract).

Autodiff design:
  * :func:`qbound` quantizes the forward value with the *activation* format
    and the backward cotangent with the *gradient* format (custom_vjp).
  * Backward-pass overflow statistics cannot exit a custom_vjp as aux
    outputs, so they are routed as the **cotangent of a zero-valued sink
    input**: ``jax.grad(loss, argnums=sinks)`` then returns, for each
    quantization site, ``(n_overflow, n_overflow_at_half_scale, n_total)``
    as an ordinary gradient. ``lax.scan`` over layers stacks them per layer
    and SPMD sums them across data-parallel shards — exactly the global
    statistics the paper's scale controller consumes.
  * Forward-pass statistics are computed on ``stop_gradient``-ed values as
    plain outputs (XLA CSEs the shared division).

Scale exponents are float32 arrays holding integer values (so that zero
cotangents exist for them under custom_vjp); ``step = 2**e``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .formats import (
    DynamicFixedPoint,
    FixedPoint,
    FloatFormat,
    Format,
    Observe,
)

Array = jax.Array

_TINY = 1e-38


def exact_pow2(e: Array) -> Array:
    """Bit-exact ``2**e`` for integer-valued float ``e``.

    XLA's ``exp2`` goes through a polynomial libm path and is *not* exact for
    integer exponents on some backends (observed off-by-ULPs on CPU). The
    quantization grid must be an exact power of two or round/clip/overflow
    counting all drift, so we construct it with ``ldexp`` instead.
    """
    return jnp.ldexp(jnp.float32(1.0), jnp.asarray(e).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Fixed-point (static & dynamic share the same grid math)
# ---------------------------------------------------------------------------

# When enabled, large quantization sites route through the fused Pallas
# kernel (kernels/dfxp) instead of the jnp composite — identical numerics
# (kernel tests assert bit-equality), one HBM pass instead of several.
# ``interpret=None`` defers to the dispatch layer's backend detection
# (compiled on TPU, interpret elsewhere).
_PALLAS = {"enabled": False, "interpret": None, "min_size": 1 << 14}


def enable_pallas_quantize(enable: bool = True, *, interpret=None,
                           min_size: int = 1 << 14) -> None:
    _PALLAS.update(enabled=enable, interpret=interpret, min_size=min_size)


def fixed_round(
    x: Array,
    width: int,
    e: Array,
    *,
    stochastic: bool = False,
    key: Optional[Array] = None,
) -> Tuple[Array, Tuple[Array, Array]]:
    """Round ``x`` onto the grid ``k * 2**e``, ``k`` two's-complement ``width``-bit.

    Returns ``(y, (n_overflow, n_overflow_half))`` where ``n_overflow`` counts
    pre-clip values outside the representable range and ``n_overflow_half``
    counts values that would overflow if the scaling factor were halved
    (``e - 1``) — the two statistics the paper's controller monitors (§5).
    Counts are float32 scalars (exact for the magnitudes that matter).
    """
    if (_PALLAS["enabled"] and not stochastic and jnp.ndim(e) == 0
            and x.size >= _PALLAS["min_size"]):
        from repro.kernels.dfxp.ops import dfxp_quantize
        y, stats = dfxp_quantize(x, e, width=width,
                                 interpret=_PALLAS["interpret"])
        return y, (stats[0], stats[1])

    dtype = x.dtype
    xf = x.astype(jnp.float32)
    e = jnp.asarray(e, jnp.float32)
    step = exact_pow2(e)
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))

    m = xf / step
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        u = jax.random.uniform(key, m.shape, jnp.float32)
        m_rounded = jnp.floor(m + u)
    else:
        m_rounded = jnp.round(m)  # round-half-to-even

    ovf = jnp.sum((m_rounded > qmax) | (m_rounded < qmin), dtype=jnp.float32)
    # would-overflow at e-1 (step/2): |x / (step/2)| beyond the grid.
    ovf_half = jnp.sum((m_rounded > qmax / 2) | (m_rounded < qmin / 2),
                       dtype=jnp.float32)

    y = jnp.clip(m_rounded, qmin, qmax) * step
    return y.astype(dtype), (ovf, ovf_half)


# ---------------------------------------------------------------------------
# Float emulation
# ---------------------------------------------------------------------------

def float_round(x: Array, fmt: FloatFormat) -> Array:
    """Round ``x`` to an ``fmt``-representable value (round-to-nearest-even)."""
    if fmt.name == "float32":
        return x
    dtype = x.dtype
    if fmt.name == "float16":
        return x.astype(jnp.float16).astype(dtype)
    if fmt.name == "bfloat16":
        return x.astype(jnp.bfloat16).astype(dtype)
    # Generic (exp_bits, man_bits) emulation, with subnormals at emin.
    xf = x.astype(jnp.float32)
    ax = jnp.abs(xf)
    exp = jnp.floor(jnp.log2(jnp.maximum(ax, _TINY)))
    exp = jnp.clip(exp, fmt.emin, fmt.emax)
    step = exact_pow2(exp - fmt.man_bits)
    y = jnp.round(xf / step) * step
    y = jnp.clip(y, -fmt.maxval, fmt.maxval)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Unified dispatch
# ---------------------------------------------------------------------------

def q_value(x: Array, fmt: Format, e: Array) -> Array:
    """Quantize values only (no stats). ``e`` ignored for float formats."""
    if fmt is None or isinstance(fmt, Observe) or (
            isinstance(fmt, FloatFormat) and fmt.name == "float32"):
        return x
    if isinstance(fmt, FloatFormat):
        return float_round(x, fmt)
    if isinstance(fmt, FixedPoint):
        y, _ = fixed_round(x, fmt.width, jnp.float32(fmt.exp))
        return y
    if isinstance(fmt, DynamicFixedPoint):
        y, _ = fixed_round(x, fmt.width, e)
        return y
    raise TypeError(f"unknown format {fmt!r}")


def q_stats(x: Array, fmt: Format, e: Array) -> Array:
    """Overflow statistics ``(n_ovf, n_ovf_half, n_total)`` for ``x`` (no grad).

    For :class:`Observe` (calibration) the first slot carries ``max|x|``
    instead of an overflow count."""
    x = jax.lax.stop_gradient(x)
    n_total = jnp.float32(x.size)
    if isinstance(fmt, Observe):
        return jnp.stack([jnp.max(jnp.abs(x.astype(jnp.float32))),
                          jnp.float32(0), n_total])
    if isinstance(fmt, FixedPoint):
        _, (ovf, ovfh) = fixed_round(x, fmt.width, jnp.float32(fmt.exp))
        return jnp.stack([ovf, ovfh, n_total])
    if isinstance(fmt, DynamicFixedPoint):
        _, (ovf, ovfh) = fixed_round(x, fmt.width, jax.lax.stop_gradient(e))
        return jnp.stack([ovf, ovfh, n_total])
    return jnp.stack([jnp.float32(0), jnp.float32(0), n_total])


# ---------------------------------------------------------------------------
# Autodiff-aware quantization sites
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_qbound(act_fmt: Format, grad_fmt: Format):
    """Build the fwd-act / bwd-grad quantizer for a static format pair."""

    @jax.custom_vjp
    def qb(x, act_e, grad_e, sink):
        del grad_e, sink
        return q_value(x, act_fmt, act_e)

    def fwd(x, act_e, grad_e, sink):
        del sink
        return q_value(x, act_fmt, act_e), (grad_e,)

    def bwd(res, ct):
        (grad_e,) = res
        if isinstance(grad_fmt, Observe):
            stats = jnp.stack([jnp.max(jnp.abs(ct.astype(jnp.float32))),
                               jnp.float32(0), jnp.float32(ct.size)])
            return (ct, jnp.zeros_like(grad_e), jnp.zeros_like(grad_e),
                    stats)
        if isinstance(grad_fmt, (FixedPoint, DynamicFixedPoint)):
            e = (jnp.float32(grad_fmt.exp) if isinstance(grad_fmt, FixedPoint)
                 else grad_e)
            qct, (ovf, ovfh) = fixed_round(ct, grad_fmt.width, e)
            stats = jnp.stack([ovf, ovfh, jnp.float32(ct.size)])
        elif isinstance(grad_fmt, FloatFormat):
            qct = float_round(ct, grad_fmt)
            stats = jnp.stack([jnp.float32(0), jnp.float32(0),
                               jnp.float32(ct.size)])
        else:  # None → pass-through
            qct = ct
            stats = jnp.zeros((3,), jnp.float32)
        return qct, jnp.zeros_like(grad_e), jnp.zeros_like(grad_e), stats

    qb.defvjp(fwd, bwd)
    return qb


def qbound(
    x: Array,
    act_fmt: Format,
    grad_fmt: Format,
    act_e: Array,
    grad_e: Array,
    sink: Array,
) -> Array:
    """Quantize forward value with ``act_fmt`` and cotangent with ``grad_fmt``.

    ``sink`` must be a zero float32 array of shape ``(3,)``; its gradient
    receives the backward-pass overflow statistics for this site.
    """
    if act_fmt is None and grad_fmt is None:
        return x
    act_e = jnp.asarray(act_e, jnp.float32)
    grad_e = jnp.asarray(grad_e, jnp.float32)
    return _make_qbound(act_fmt, grad_fmt)(x, act_e, grad_e, sink)


@functools.lru_cache(maxsize=None)
def _make_ste(fmt: Format):
    @jax.custom_vjp
    def ste(x, e):
        return q_value(x, fmt, e)

    def fwd(x, e):
        return q_value(x, fmt, e), None

    def bwd(_, ct):
        return ct, jnp.float32(0)

    ste.defvjp(fwd, bwd)
    return ste


def ste_quant(x: Array, fmt: Format, e: Array) -> Array:
    """Forward quantization with straight-through (identity) backward.

    Used for *weight use-time* quantization: the stored (update-width)
    parameter is re-quantized to the computation width when it enters a
    multiplication; its gradient is quantized once, in the train step.
    """
    if fmt is None:
        return x
    return _make_ste(fmt)(x, jnp.asarray(e, jnp.float32))


def new_sink() -> Array:
    """A fresh stats sink for one quantization site."""
    return jnp.zeros((3,), jnp.float32)
