"""The DFXP train step (paper §5-§7, end to end).

Order of operations per step (all inside one jit program):
  1. microbatch ``lax.scan``: forward/backward with quantized activations &
     backprop signals (model-side qbound sites); accumulate mean grads,
     forward overflow stats, and sink cotangents (gradient overflow stats);
  2. optional global-norm clip;
  3. quantize accumulated weight gradients at the computation width
     (``pg:`` groups — these are the paper's "gradient" groups);
  4. optimizer math in f32 (wide accumulator hypothesis);
  5. quantize new parameters (and momentum) at the update width
     (``p:``/``pm:`` groups — the paper's 12-bit parameter updates),
     optionally with stochastic rounding (beyond-paper);
  6. max-norm constraint (paper's maxout recipe);
  7. feed every group's statistics to the overflow-rate controller; apply
     the scale-update rule every ``policy.update_interval`` steps.

In ``packed`` storage mode, parameters/momentum live as int-mantissa
``PackedArray``s; step 4 unpacks per-leaf (elementwise, fuses) and step 5
re-packs, so wide master copies never persist in HBM.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.packed import PackedArray, pack
from repro.core.policy import PrecisionPolicy
from repro.core.quant import exact_pow2
from repro.core.scale import accumulate, controller_step
from repro.optim.opt import (OptConfig, adamw_update, apply_max_norm,
                             clip_by_global_norm, global_norm, sgd_update)

from .state import TrainState, _bexp, _path_str, unpack_tree

Array = jax.Array


def quantize_param(x: Array, width: int, e: Array, *, stochastic_key=None):
    """Quantize a parameter/gradient leaf; per-layer stats if ``e`` is [L].

    Returns (y, stats) with stats shaped ``e.shape + (3,)``.
    """
    eb = _bexp(e, x)
    step = exact_pow2(eb)
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))
    m = x.astype(jnp.float32) / step
    if stochastic_key is not None:
        u = jax.random.uniform(stochastic_key, m.shape, jnp.float32)
        m_r = jnp.floor(m + u)
    else:
        m_r = jnp.round(m)
    over = (m_r > qmax) | (m_r < qmin)
    over_h = (m_r > qmax / 2) | (m_r < qmin / 2)
    axes = tuple(range(jnp.ndim(e), x.ndim))
    ovf = jnp.sum(over, axis=axes, dtype=jnp.float32)
    ovfh = jnp.sum(over_h, axis=axes, dtype=jnp.float32)
    total = jnp.broadcast_to(
        jnp.float32(x.size / max(1, int(jnp.size(e)))), ovf.shape)
    y = (jnp.clip(m_r, qmin, qmax) * step).astype(x.dtype)
    return y, jnp.stack([ovf, ovfh, total], axis=-1)


def _map_with_group(fn, tree, exps: Dict[str, Array], prefix: str,
                    is_packed=False):
    """tree_map with the leaf's scale group exponent. Returns (tree', stats)."""
    stats: Dict[str, Array] = {}

    def apply(path, leaf):
        name = _path_str(path)
        e = exps[f"{prefix}{name}"]
        out, st = fn(leaf, e, name)
        stats[f"{prefix}{name}"] = st
        return out

    leaf_fn = (lambda x: isinstance(x, PackedArray)) if is_packed else None
    out = jax.tree_util.tree_map_with_path(apply, tree, is_leaf=leaf_fn)
    return out, stats


def make_train_step(
    loss_fn: Callable,            # (params, batch, sinks, exps) -> (loss, stats)
    group_shapes: Dict[str, tuple],
    policy: PrecisionPolicy,
    opt_cfg: OptConfig,
    *,
    microbatches: int = 1,
    compute_dtype=jnp.float32,
    grad_transform: Optional[Callable] = None,   # e.g. DFXP compression
    numerics_tap: bool = False,
):
    """Build ``step(state, batch, rng) -> (state, metrics)``.

    ``numerics_tap=True`` adds a ``metrics["numerics"]`` sub-dict carrying
    the §5 controller's inputs and outputs out of the jit — per-group
    exponents before/after the controller and the window accumulators the
    decision was made from (captured BEFORE the post-apply reset).  The
    host feeds it to :func:`repro.obs.numerics.train_records` on the
    logging cadence; off (the default) the metrics pytree is unchanged.
    """
    dyn = policy.dynamic
    quant_params = policy.enabled and policy.arithmetic in ("fixed", "dfxp")

    def step(state: TrainState, batch, rng: Array):
        sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                 for n, s in group_shapes.items() if n.startswith("g:")}

        # ---- unpack storage (packed mode) --------------------------------
        if policy.storage == "packed":
            params_c = unpack_tree(state.params, compute_dtype)
            mom_c = unpack_tree(state.opt, jnp.float32)
        else:
            params_c = state.params
            mom_c = state.opt

        # ---- grads over microbatches --------------------------------------
        exps = state.scale.exps

        def loss_wrap(p, s, b):
            return loss_fn(p, b, s, exps)

        grad_fn = jax.value_and_grad(loss_wrap, argnums=(0, 1), has_aux=True)

        if microbatches > 1:
            for key in ("labels", "y", "tokens", "x"):
                if key in batch:
                    B = batch[key].shape[0]
                    break
            else:
                raise ValueError("cannot infer batch axis for microbatching")

            def to_micro(x):
                if x.shape[0] == B:
                    return x.reshape((microbatches, B // microbatches)
                                     + x.shape[1:])
                # leaves with batch on axis 1 (e.g. M-RoPE positions [3,B,S])
                assert x.ndim >= 2 and x.shape[1] == B, x.shape
                y = x.reshape((x.shape[0], microbatches, B // microbatches)
                              + x.shape[2:])
                return jnp.moveaxis(y, 1, 0)

            mb = jax.tree.map(to_micro, batch)

            def body(carry, b):
                (loss_a, g_a, s_a, st_a) = carry
                (loss, st), (g, gs) = grad_fn(params_c, sinks, b)
                st_new = {k: st_a[k] + st.get(k, 0.0) for k in st_a}
                return (loss_a + loss,
                        jax.tree.map(jnp.add, g_a, g),
                        jax.tree.map(jnp.add, s_a, gs),
                        st_new), None

            z_g = jax.tree.map(jnp.zeros_like, params_c)
            z_s = jax.tree.map(jnp.zeros_like, sinks)
            st0 = {n: jnp.zeros(s + (3,), jnp.float32)
                   for n, s in group_shapes.items()
                   if n.startswith(("a:", "w:"))}
            (loss, grads, sink_stats, fwd_stats), _ = jax.lax.scan(
                body, (jnp.float32(0), z_g, z_s, st0), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            (loss, fwd_stats), (grads, sink_stats) = grad_fn(params_c, sinks,
                                                             batch)

        if grad_transform is not None:
            grads = grad_transform(grads)

        # ---- gradient processing ------------------------------------------
        gnorm = global_norm(grads)
        if opt_cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, opt_cfg.grad_clip)

        all_stats: Dict[str, Array] = {}
        for d in (fwd_stats, sink_stats):
            for k, v in d.items():
                key = k if not k.startswith("g:") else k
                all_stats[key] = all_stats.get(key, 0) + v

        if quant_params:
            grads, gstats = _map_with_group(
                lambda g, e, n: quantize_param(g, policy.comp_width, e),
                grads, state.scale.exps, "pg:")
            all_stats.update(gstats)

        # ---- optimizer (wide math) ----------------------------------------
        if opt_cfg.kind == "sgd":
            updates, new_opt = sgd_update(opt_cfg, grads, mom_c, state.step)
        else:
            updates, new_opt = adamw_update(opt_cfg, grads, mom_c, state.step,
                                            params=params_c)

        new_params = jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                                + u).astype(jnp.float32),
                                  params_c, updates)
        if opt_cfg.max_col_norm:
            new_params = apply_max_norm(new_params, opt_cfg.max_col_norm)

        # ---- parameter/momentum storage quantization ----------------------
        def q_store(x, e, name, key=None):
            sk = None
            if policy.stochastic_rounding:
                sk = jax.random.fold_in(rng, hash(name) % (2 ** 31))
            return quantize_param(x, policy.update_width, e,
                                  stochastic_key=sk)

        if quant_params:
            if policy.storage == "packed":
                def pk(x, e, name):
                    y, st = q_store(x, e, name)
                    return pack(y, policy.update_width, _bexp(e, y)), st
                new_params, pstats = _map_with_group(
                    pk, new_params, state.scale.exps, "p:")
                all_stats.update(pstats)
                if policy.quantize_momentum and opt_cfg.kind == "sgd":
                    new_mom, mstats = _map_with_group(
                        pk, new_opt["momentum"], state.scale.exps, "pm:")
                    new_opt = {"momentum": new_mom}
                    all_stats.update(mstats)
            else:
                new_params, pstats = _map_with_group(
                    q_store, new_params, state.scale.exps, "p:")
                all_stats.update(pstats)
                if policy.quantize_momentum and opt_cfg.kind == "sgd":
                    new_mom, mstats = _map_with_group(
                        q_store, new_opt["momentum"], state.scale.exps, "pm:")
                    new_opt = {"momentum": new_mom}
                    all_stats.update(mstats)
        elif policy.enabled:
            # float emulation of the storage format (fp16/bf16/fp8 rows)
            from repro.core.quant import float_round
            fmt = policy.update_format()
            new_params = jax.tree.map(lambda x: float_round(x, fmt),
                                      new_params)

        # ---- scale controller ----------------------------------------------
        new_scale = state.scale
        acc_window = None
        if dyn:
            new_scale = accumulate(new_scale, all_stats)
            acc_window = new_scale.acc    # pre-reset §5 window accumulators
            apply = (state.step + 1) % policy.update_interval == 0
            new_scale = controller_step(
                new_scale, max_overflow_rate=policy.max_overflow_rate,
                apply=apply)

        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": state.step.astype(jnp.float32)}
        if numerics_tap:
            metrics["numerics"] = {
                "prev_exps": state.scale.exps,
                "exps": new_scale.exps,
                "acc": acc_window if acc_window is not None else {},
            }
        return TrainState(params=new_params, opt=new_opt, scale=new_scale,
                          step=state.step + 1), metrics

    return step
