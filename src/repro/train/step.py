"""The DFXP train step (paper §5-§7, end to end).

Order of operations per step (all inside one jit program):
  1. microbatch ``lax.scan``: forward/backward with quantized activations &
     backprop signals (model-side qbound sites); accumulate mean grads,
     forward overflow stats, and sink cotangents (gradient overflow stats);
  2. optional global-norm clip;
  3. quantize accumulated weight gradients at the computation width
     (``pg:`` groups — these are the paper's "gradient" groups);
  4. optimizer math in f32 (wide accumulator hypothesis);
  5. quantize new parameters (and momentum) at the update width
     (``p:``/``pm:`` groups — the paper's 12-bit parameter updates),
     optionally with stochastic rounding (beyond-paper);
  6. max-norm constraint (paper's maxout recipe);
  7. feed every group's statistics to the overflow-rate controller; apply
     the scale-update rule every ``policy.update_interval`` steps.

In ``packed`` storage mode, parameters/momentum live as int-mantissa
``PackedArray``s; step 4 unpacks per-leaf (elementwise, fuses) and step 5
re-packs, so wide master copies never persist in HBM.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.packed import PackedArray, pack
from repro.core.policy import PrecisionPolicy
from repro.core.quant import exact_pow2
from repro.core.scale import accumulate, controller_step
from repro.optim.opt import (OptConfig, adamw_update, apply_max_norm,
                             clip_by_global_norm, global_norm, sgd_update)

from .state import TrainState, _bexp, _path_str, unpack_tree

Array = jax.Array


def quantize_param(x: Array, width: int, e: Array, *, stochastic_key=None):
    """Quantize a parameter/gradient leaf; per-layer stats if ``e`` is [L].

    Returns (y, stats) with stats shaped ``e.shape + (3,)``.
    """
    eb = _bexp(e, x)
    step = exact_pow2(eb)
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))
    m = x.astype(jnp.float32) / step
    if stochastic_key is not None:
        u = jax.random.uniform(stochastic_key, m.shape, jnp.float32)
        m_r = jnp.floor(m + u)
    else:
        m_r = jnp.round(m)
    over = (m_r > qmax) | (m_r < qmin)
    over_h = (m_r > qmax / 2) | (m_r < qmin / 2)
    axes = tuple(range(jnp.ndim(e), x.ndim))
    ovf = jnp.sum(over, axis=axes, dtype=jnp.float32)
    ovfh = jnp.sum(over_h, axis=axes, dtype=jnp.float32)
    total = jnp.broadcast_to(
        jnp.float32(x.size / max(1, int(jnp.size(e)))), ovf.shape)
    y = (jnp.clip(m_r, qmin, qmax) * step).astype(x.dtype)
    return y, jnp.stack([ovf, ovfh, total], axis=-1)


def _map_with_group(fn, tree, exps: Dict[str, Array], prefix: str,
                    is_packed=False):
    """tree_map with the leaf's scale group exponent. Returns (tree', stats)."""
    stats: Dict[str, Array] = {}

    def apply(path, leaf):
        name = _path_str(path)
        e = exps[f"{prefix}{name}"]
        out, st = fn(leaf, e, name)
        stats[f"{prefix}{name}"] = st
        return out

    leaf_fn = (lambda x: isinstance(x, PackedArray)) if is_packed else None
    out = jax.tree_util.tree_map_with_path(apply, tree, is_leaf=leaf_fn)
    return out, stats


# Sentinel flag bits (metrics["flags"] in supervised mode).
FLAG_LOSS_NONFINITE = 1
FLAG_GRAD_NONFINITE = 2
FLAG_RUNAWAY_OVF = 4


def benign_injection() -> Dict[str, Array]:
    """The no-fault injection input for a supervised step."""
    return {"grad_nan": jnp.bool_(False), "loss_scale": jnp.float32(1.0)}


def make_train_step(
    loss_fn: Callable,            # (params, batch, sinks, exps) -> (loss, stats)
    group_shapes: Dict[str, tuple],
    policy: PrecisionPolicy,
    opt_cfg: OptConfig,
    *,
    microbatches: int = 1,
    compute_dtype=jnp.float32,
    grad_transform: Optional[Callable] = None,   # e.g. DFXP compression
    numerics_tap: bool = False,
    ef_transform: Optional[Callable] = None,     # (grads, ef) -> (grads, ef)
    supervise: bool = False,
    runaway_ovf: Optional[float] = None,
):
    """Build ``step(state, batch, rng) -> (state, metrics)``.

    ``numerics_tap=True`` adds a ``metrics["numerics"]`` sub-dict carrying
    the §5 controller's inputs and outputs out of the jit — per-group
    exponents before/after the controller and the window accumulators the
    decision was made from (captured BEFORE the post-apply reset).  The
    host feeds it to :func:`repro.obs.numerics.train_records` on the
    logging cadence; off (the default) the metrics pytree is unchanged.

    ``ef_transform`` threads an error-feedback state (e.g. the residual
    buffers of :func:`repro.dist.compress.compress_tree`) through the
    step: it is applied to the mean gradients and its state rides the
    signature — required so crash recovery can checkpoint the residuals
    and resume bit-exactly.

    ``supervise=True`` is the fault-tolerant variant used by
    :class:`repro.train.resilience.TrainSupervisor`.  The signature
    becomes ``step(state, batch, rng, ef, inj) -> (state, metrics, ef)``:

    * ``inj`` is a device-side fault-injection input (see
      :func:`benign_injection`): ``loss_scale`` multiplies the loss
      inside the differentiated function (a LossSpike travels through
      real gradients) and ``grad_nan`` poisons the mean gradients with
      NaN — both reach the sentinels by the same path a genuine blowup
      would, mirroring the serve engine's ``nan_mask``.
    * ``metrics["flags"]`` is an int32 sentinel bitmask computed inside
      the jit — :data:`FLAG_LOSS_NONFINITE` | :data:`FLAG_GRAD_NONFINITE`
      | :data:`FLAG_RUNAWAY_OVF` (any tensor class whose §5 overflow
      rate this step exceeds ``runaway_ovf``) — and
      ``metrics["cls_rates"]`` carries the per-tensor-class rates.  One
      extra scalar fetch per step, like serve's ``guard_logits``.
    * On a tripped sentinel the state update is discarded *on device*
      (branch-free select): params/opt/step/ef keep their old values.
      The scale state is still adopted when only the runaway flag is set
      — the §5 controller must see the overflow window to escape it —
      but never on a NaN flag.
    """
    dyn = policy.dynamic
    quant_params = policy.enabled and policy.arithmetic in ("fixed", "dfxp")

    def _impl(state: TrainState, batch, rng: Array, ef, inj):
        sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                 for n, s in group_shapes.items() if n.startswith("g:")}

        # ---- unpack storage (packed mode) --------------------------------
        if policy.storage == "packed":
            params_c = unpack_tree(state.params, compute_dtype)
            mom_c = unpack_tree(state.opt, jnp.float32)
        else:
            params_c = state.params
            mom_c = state.opt

        # ---- grads over microbatches --------------------------------------
        exps = state.scale.exps

        def loss_wrap(p, s, b):
            loss, st = loss_fn(p, b, s, exps)
            if inj is not None:
                # LossSpike rides through AD: scaled loss => scaled grads
                loss = loss * inj["loss_scale"]
            return loss, st

        grad_fn = jax.value_and_grad(loss_wrap, argnums=(0, 1), has_aux=True)

        if microbatches > 1:
            for key in ("labels", "y", "tokens", "x"):
                if key in batch:
                    B = batch[key].shape[0]
                    break
            else:
                raise ValueError("cannot infer batch axis for microbatching")

            def to_micro(x):
                if x.shape[0] == B:
                    return x.reshape((microbatches, B // microbatches)
                                     + x.shape[1:])
                # leaves with batch on axis 1 (e.g. M-RoPE positions [3,B,S])
                assert x.ndim >= 2 and x.shape[1] == B, x.shape
                y = x.reshape((x.shape[0], microbatches, B // microbatches)
                              + x.shape[2:])
                return jnp.moveaxis(y, 1, 0)

            mb = jax.tree.map(to_micro, batch)

            def body(carry, b):
                (loss_a, g_a, s_a, st_a) = carry
                (loss, st), (g, gs) = grad_fn(params_c, sinks, b)
                st_new = {k: st_a[k] + st.get(k, 0.0) for k in st_a}
                return (loss_a + loss,
                        jax.tree.map(jnp.add, g_a, g),
                        jax.tree.map(jnp.add, s_a, gs),
                        st_new), None

            z_g = jax.tree.map(jnp.zeros_like, params_c)
            z_s = jax.tree.map(jnp.zeros_like, sinks)
            st0 = {n: jnp.zeros(s + (3,), jnp.float32)
                   for n, s in group_shapes.items()
                   if n.startswith(("a:", "w:"))}
            (loss, grads, sink_stats, fwd_stats), _ = jax.lax.scan(
                body, (jnp.float32(0), z_g, z_s, st0), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            (loss, fwd_stats), (grads, sink_stats) = grad_fn(params_c, sinks,
                                                             batch)

        if inj is not None:
            poison = jnp.where(inj["grad_nan"], jnp.float32(jnp.nan),
                               jnp.float32(0.0))
            grads = jax.tree.map(lambda g: g + poison.astype(g.dtype), grads)

        if grad_transform is not None:
            grads = grad_transform(grads)

        new_ef = ef
        if ef_transform is not None:
            grads, new_ef = ef_transform(grads, ef)

        # ---- gradient processing ------------------------------------------
        gnorm = global_norm(grads)
        if opt_cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, opt_cfg.grad_clip)

        all_stats: Dict[str, Array] = {}
        for d in (fwd_stats, sink_stats):
            for k, v in d.items():
                key = k if not k.startswith("g:") else k
                all_stats[key] = all_stats.get(key, 0) + v

        if quant_params:
            grads, gstats = _map_with_group(
                lambda g, e, n: quantize_param(g, policy.comp_width, e),
                grads, state.scale.exps, "pg:")
            all_stats.update(gstats)

        # ---- optimizer (wide math) ----------------------------------------
        if opt_cfg.kind == "sgd":
            updates, new_opt = sgd_update(opt_cfg, grads, mom_c, state.step)
        else:
            updates, new_opt = adamw_update(opt_cfg, grads, mom_c, state.step,
                                            params=params_c)

        new_params = jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                                + u).astype(jnp.float32),
                                  params_c, updates)
        if opt_cfg.max_col_norm:
            new_params = apply_max_norm(new_params, opt_cfg.max_col_norm)

        # ---- parameter/momentum storage quantization ----------------------
        def q_store(x, e, name, key=None):
            sk = None
            if policy.stochastic_rounding:
                sk = jax.random.fold_in(rng, hash(name) % (2 ** 31))
            return quantize_param(x, policy.update_width, e,
                                  stochastic_key=sk)

        if quant_params:
            if policy.storage == "packed":
                def pk(x, e, name):
                    y, st = q_store(x, e, name)
                    return pack(y, policy.update_width, _bexp(e, y)), st
                new_params, pstats = _map_with_group(
                    pk, new_params, state.scale.exps, "p:")
                all_stats.update(pstats)
                if policy.quantize_momentum and opt_cfg.kind == "sgd":
                    new_mom, mstats = _map_with_group(
                        pk, new_opt["momentum"], state.scale.exps, "pm:")
                    new_opt = {"momentum": new_mom}
                    all_stats.update(mstats)
            else:
                new_params, pstats = _map_with_group(
                    q_store, new_params, state.scale.exps, "p:")
                all_stats.update(pstats)
                if policy.quantize_momentum and opt_cfg.kind == "sgd":
                    new_mom, mstats = _map_with_group(
                        q_store, new_opt["momentum"], state.scale.exps, "pm:")
                    new_opt = {"momentum": new_mom}
                    all_stats.update(mstats)
        elif policy.enabled:
            # float emulation of the storage format (fp16/bf16/fp8 rows)
            from repro.core.quant import float_round
            fmt = policy.update_format()
            new_params = jax.tree.map(lambda x: float_round(x, fmt),
                                      new_params)

        # ---- scale controller ----------------------------------------------
        new_scale = state.scale
        acc_window = None
        if dyn:
            new_scale = accumulate(new_scale, all_stats)
            acc_window = new_scale.acc    # pre-reset §5 window accumulators
            apply = (state.step + 1) % policy.update_interval == 0
            new_scale = controller_step(
                new_scale, max_overflow_rate=policy.max_overflow_rate,
                apply=apply)

        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": state.step.astype(jnp.float32)}
        if numerics_tap:
            metrics["numerics"] = {
                "prev_exps": state.scale.exps,
                "exps": new_scale.exps,
                "acc": acc_window if acc_window is not None else {},
            }

        new_state = TrainState(params=new_params, opt=new_opt,
                               scale=new_scale, step=state.step + 1)

        if supervise:
            from repro.core.tape import tensor_class
            bad_loss = ~jnp.isfinite(loss)
            bad_grad = ~jnp.isfinite(gnorm)
            cls_ovf: Dict[str, Array] = {}
            cls_tot: Dict[str, Array] = {}
            for gname, st in all_stats.items():
                c = tensor_class(gname)
                cls_ovf[c] = cls_ovf.get(c, 0.0) + jnp.sum(st[..., 0])
                cls_tot[c] = cls_tot.get(c, 0.0) + jnp.sum(st[..., 2])
            cls_rates = {c: cls_ovf[c] / jnp.maximum(cls_tot[c], 1.0)
                         for c in sorted(cls_ovf)}
            runaway = jnp.bool_(False)
            if runaway_ovf is not None and cls_rates:
                runaway = (jnp.stack(list(cls_rates.values())).max()
                           > runaway_ovf)
            flags = (bad_loss.astype(jnp.int32) * FLAG_LOSS_NONFINITE
                     + bad_grad.astype(jnp.int32) * FLAG_GRAD_NONFINITE
                     + runaway.astype(jnp.int32) * FLAG_RUNAWAY_OVF)
            metrics["flags"] = flags
            metrics["cls_rates"] = cls_rates

            # Discard a tripped step's update on device: SKIPPED costs no
            # extra host round-trip before the next step can launch.
            nan_bad = bad_loss | bad_grad
            any_bad = nan_bad | runaway

            def sel(pred, old, new):
                return jax.tree.map(lambda a, b: jnp.where(pred, a, b),
                                    old, new)

            new_state = TrainState(
                params=sel(any_bad, state.params, new_state.params),
                opt=sel(any_bad, state.opt, new_state.opt),
                # runaway-only: keep the new scale so the §5 controller
                # can move the exponent out of the overflow regime
                scale=sel(nan_bad, state.scale, new_state.scale),
                step=jnp.where(any_bad, state.step, new_state.step))
            new_ef = sel(any_bad, ef, new_ef)

        return new_state, metrics, new_ef

    if supervise:
        def step(state: TrainState, batch, rng: Array, ef, inj):
            return _impl(state, batch, rng, ef, inj)
    elif ef_transform is not None:
        def step(state: TrainState, batch, rng: Array, ef):
            return _impl(state, batch, rng, ef, None)
    else:
        def step(state: TrainState, batch, rng: Array):
            out_state, metrics, _ = _impl(state, batch, rng, {}, None)
            return out_state, metrics

    return step
