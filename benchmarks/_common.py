"""Shared harness for the paper-reproduction benchmarks.

All paper experiments train the same maxout network (paper §2) on the
synthetic PI-MNIST-like task (784-dim, 10 classes — real MNIST is not
available offline; see DESIGN.md §7.1) and report the *final loss
normalized by the float32 baseline*, mirroring the paper's normalized
final-test-error presentation.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy
from repro.data import SyntheticImages
from repro.models import maxout as MX
from repro.optim.opt import OptConfig, sgd_init
from repro.train import init_train_state, make_train_step
from repro.train.calibrate import calibrate

STEPS = 120
BATCH = 64

CFG = MX.MaxoutConfig(hidden=(48,), pieces=3)
OPT = OptConfig(kind="sgd", lr=0.1, lr_decay_steps=2000,
                max_col_norm=1.9365)
DATA = SyntheticImages.hard()
GS = MX.group_shapes(CFG)


def _batches(n):
    for i in range(n):
        b = DATA.batch(i, BATCH)
        yield {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}


@functools.lru_cache(maxsize=None)
def calibrated_exps_cached(policy: PrecisionPolicy):
    obs = dataclasses.replace(policy, arithmetic="observe", storage="sim")
    params0 = MX.init_params(CFG, jax.random.PRNGKey(7))

    def obs_loss(p, b, s, exps):
        return MX.loss_fn(CFG, obs, p, b, exps, s, rng=jax.random.PRNGKey(1))

    exps = calibrate(obs_loss, params0, GS, policy, OPT, _batches(10),
                     steps=6)
    return tuple(sorted((k, float(jnp.ravel(v)[0])) for k, v in exps.items()))


def train_once(policy: PrecisionPolicy, steps: int = STEPS):
    """Returns (final_loss, eval_accuracy, seconds_per_step).

    The benchmark metric is *final loss normalized by fp32* — on the
    synthetic task the error rate sits near the Bayes floor and compresses
    format differences, while the loss preserves the paper's ordering.
    """
    if policy.dynamic:
        init_exp = {k: v for k, v in calibrated_exps_cached(policy)}
    else:
        init_exp = -8.0
    params = MX.init_params(CFG, jax.random.PRNGKey(7))
    state = init_train_state(params, sgd_init(params), GS, policy,
                             init_exp=init_exp)

    def loss_fn(p, b, s, exps):
        return MX.loss_fn(CFG, policy, p, b, exps, s,
                          rng=jax.random.PRNGKey(1))

    step = jax.jit(make_train_step(loss_fn, GS, policy, OPT))
    t0 = None
    for i, b in enumerate(_batches(steps)):
        state, m = step(state, b, jax.random.PRNGKey(i))
        if i == 0:
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
    jax.block_until_ready(m["loss"])
    sps = (time.perf_counter() - t0) / max(steps - 1, 1)

    ev = DATA.eval_set(1024)
    sinks = {n: jnp.zeros(s + (3,), jnp.float32) for n, s in GS.items()
             if n.startswith("g:")}
    from repro.train.state import unpack_tree
    params_eval = (unpack_tree(state.params) if policy.storage == "packed"
                   else state.params)
    acc = MX.accuracy(CFG, policy, params_eval,
                      {"x": jnp.asarray(ev["x"]), "y": jnp.asarray(ev["y"])},
                      state.scale.exps, sinks)
    return float(m["loss"]), float(acc), sps


_BASELINE = {}


def fp32_baseline():
    if "v" not in _BASELINE:
        _BASELINE["v"] = train_once(PrecisionPolicy("float32"))
    return _BASELINE["v"]
