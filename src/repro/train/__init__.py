"""Training: DFXP train step, state, supervised loop, fault injection."""
from .faults import (CkptTear, FaultHarness, GradNaN, Kill,  # noqa: F401
                     LossSpike, ParamBitFlip, chaos_plan)
from .resilience import StepOutcome, TrainSupervisor  # noqa: F401
from .state import TrainState, init_train_state, param_group_shapes  # noqa: F401
from .step import benign_injection, make_train_step, quantize_param  # noqa: F401
