"""Low-precision-multiplication training reproduction (jax).

Deliberately import-light: ``repro.launch.dryrun`` must be able to set
``XLA_FLAGS`` before jax initializes a backend, so nothing here may import
jax (subpackages that need it import it themselves).
"""

__version__ = "0.1.0"
