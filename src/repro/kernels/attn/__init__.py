"""Fused Pallas attention over the packed KV pool: flash-decode (single
query) and flash-prefill (chunked prefill with quantize-on-write)."""
from .ops import flash_decode, flash_prefill  # noqa: F401
from .ref import decode_attention_ref, prefill_attention_ref  # noqa: F401
