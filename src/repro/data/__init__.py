"""Data pipelines: deterministic synthetic datasets + host-sharded loading."""
from .synthetic import (  # noqa: F401
    SyntheticImages,
    SyntheticLM,
    shard_batch,
)
