"""Fused flash-decode attention: kernel bit-equality, dispatch, serve path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.kernels import dispatch
from repro.kernels.attn import ref as R
from repro.kernels.attn.ops import flash_decode
from repro.models import transformer as T
from repro.serve import (CacheQuantConfig, EngineOptions, PackedKVCodec,
                         ServeEngine)


def _case(key, B, W, K, G, hd, width, n_valid=None, holes=False):
    """Random (q, k, v, pos, q_pos, k_exp, v_exp) in the codec layout."""
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, K, G, hd), jnp.float32)
    if width is None:
        k = jax.random.normal(ks[1], (B, W, K, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, W, K, hd), jnp.float32)
        ke = ve = None
    else:
        from repro.core.packed import container_dtype, qrange
        qmax, qmin = qrange(width)
        dt = container_dtype(width)
        k = jax.random.randint(ks[1], (B, W, K, hd), int(qmin),
                               int(qmax) + 1).astype(dt)
        v = jax.random.randint(ks[2], (B, W, K, hd), int(qmin),
                               int(qmax) + 1).astype(dt)
        ke = jax.random.randint(ks[3], (B,), -8, -2).astype(jnp.float32)
        ve = jax.random.randint(ks[4], (B,), -8, -2).astype(jnp.float32)
    n_valid = W if n_valid is None else n_valid
    pos = jnp.where(jnp.arange(W) < n_valid, jnp.arange(W), -1)
    pos = jnp.broadcast_to(pos, (B, W)).astype(jnp.int32)
    if holes:  # scattered empty slots, different per row
        gap = jax.random.bernoulli(ks[3] if width is None else ks[0],
                                   0.3, (B, W))
        pos = jnp.where(gap, -1, pos)
    # per-row query positions (unequal: continuous batching decodes each
    # slot at its own position)
    q_pos = jnp.maximum(jnp.max(pos, axis=1), 0).astype(jnp.int32)
    return q, k, v, pos, q_pos, ke, ve


def _both(case, width, scale=0.25, window=None, causal=True, block_w=None):
    q, k, v, pos, q_pos, ke, ve = case
    out = flash_decode(q, k, v, pos, q_pos, ke, ve, width=width, scale=scale,
                       window=window, causal=causal, block_w=block_w,
                       interpret=True)
    ref = R.decode_attention_ref(q, k, v, pos, q_pos, k_exp=ke, v_exp=ve,
                                 width=width, scale=scale, window=window,
                                 causal=causal)
    return np.asarray(out), np.asarray(ref)


# ---------------------------------------------------------------------------
# acceptance: interpret-mode bit-equality vs the ref composite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [8, 16, None], ids=["int8", "int16", "f32"])
def test_bit_equal_vs_ref(width):
    case = _case(jax.random.PRNGKey(0), B=2, W=12, K=2, G=2, hd=8, width=width)
    out, ref = _both(case, width)
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("K,G", [(1, 1), (1, 4), (2, 2), (4, 1)])
def test_gqa_groupings(K, G):
    """MHA (G=1), MQA (K=1) and grouped layouts all hit the same math."""
    case = _case(jax.random.PRNGKey(1), B=2, W=9, K=K, G=G, hd=4, width=8)
    out, ref = _both(case, 8)
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("W", [1, 5, 17, 33, 130])
def test_unaligned_window_lengths(W):
    case = _case(jax.random.PRNGKey(2), B=2, W=W, K=2, G=2, hd=4, width=16,
                 n_valid=max(1, W - 2))
    out, ref = _both(case, 16)
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("width", [8, None], ids=["int8", "f32"])
def test_per_slot_position_masks(width):
    """Scattered empty slots + per-row query positions mask exactly."""
    case = _case(jax.random.PRNGKey(3), B=3, W=15, K=2, G=2, hd=4,
                 width=width, holes=True)
    out, ref = _both(case, width)
    np.testing.assert_array_equal(out, ref)
    assert np.all(np.isfinite(out))


def test_sliding_window_mask():
    case = _case(jax.random.PRNGKey(4), B=2, W=16, K=2, G=2, hd=4, width=8)
    for window in (1, 4, 7):
        out, ref = _both(case, 8, window=window)
        np.testing.assert_array_equal(out, ref)


# ---------------------------------------------------------------------------
# split-K path (the compiled-TPU grid, run in interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [8, 16, None], ids=["int8", "int16", "f32"])
@pytest.mark.parametrize("block_w", [4, 5, 16])
def test_split_k_matches_ref(width, block_w):
    """Forced split sizes (aligned, unaligned, > valid range) reproduce the
    composite through the partial max/denominator/numerator combine."""
    case = _case(jax.random.PRNGKey(5), B=2, W=13, K=2, G=2, hd=8,
                 width=width, n_valid=11)
    out, ref = _both(case, width, block_w=block_w)
    np.testing.assert_allclose(out, ref, rtol=2e-6, atol=2e-6)


def test_split_k_fully_masked_block():
    """A split whose every slot is empty/future must contribute exactly 0
    (no NaN from the -inf running max, no probability leak)."""
    case = _case(jax.random.PRNGKey(6), B=2, W=12, K=1, G=2, hd=4, width=8,
                 n_valid=3)   # splits 2 and 3 all empty
    out, ref = _both(case, 8, block_w=3)
    np.testing.assert_allclose(out, ref, rtol=2e-6, atol=2e-6)
    assert np.all(np.isfinite(out))


# ---------------------------------------------------------------------------
# dispatch: split selection + persisted autotune table
# ---------------------------------------------------------------------------

def test_attn_blocks_interpret_is_whole_window():
    assert dispatch.attn_blocks_for(300, 4, 64, width=8, interpret=True) == 300


def test_autotune_persistence_roundtrip(tmp_path):
    """Measured entries survive save → reset → load; heuristics don't."""
    path = str(tmp_path / "autotune.json")
    saved_cache = dict(dispatch._BLOCK_CACHE)
    saved_meas = set(dispatch._MEASURED)
    try:
        dispatch.reset_autotune()
        dispatch._BLOCK_CACHE[("nn", 256, 256, 512)] = (128, 128, 256)
        dispatch._BLOCK_CACHE[("attn", 4096, 4, 64, 8)] = (512,)
        dispatch._MEASURED.update(dispatch._BLOCK_CACHE)
        dispatch._BLOCK_CACHE[("nt", 64, 64, 64)] = (64, 64, 64)  # heuristic
        assert dispatch.save_autotune(path) == path
        dispatch.reset_autotune()
        assert dispatch.load_autotune(path) == 2
        assert dispatch._BLOCK_CACHE[("nn", 256, 256, 512)] == (128, 128, 256)
        assert ("nt", 64, 64, 64) not in dispatch._BLOCK_CACHE
        # loaded measurement short-circuits blocks_for without re-measuring
        assert dispatch.blocks_for("nn", 200, 200, 500,
                                   interpret=False) == (128, 128, 256)
        # and the attn bucket resolves to the persisted split
        dispatch.set_autotune(measure=False)
        assert dispatch.attn_blocks_for(4000, 4, 64, width=8,
                                        interpret=False) == 512
    finally:
        dispatch.reset_autotune()
        dispatch.set_autotune(measure=True)
        dispatch._BLOCK_CACHE.update(saved_cache)
        dispatch._MEASURED.update(saved_meas)


def test_autotune_load_missing_or_corrupt(tmp_path):
    assert dispatch.load_autotune(str(tmp_path / "nope.json")) == 0
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert dispatch.load_autotune(str(bad)) == 0
    bad.write_text("[1, 2, 3]")            # valid JSON, wrong shape
    assert dispatch.load_autotune(str(bad)) == 0


def test_autotune_save_merges_and_load_validates(tmp_path):
    """Successive processes contribute different buckets without
    clobbering, and semantically-invalid persisted entries are skipped
    (a trusted-forever bad entry would break every call in its bucket)."""
    import json
    path = str(tmp_path / "autotune.json")
    saved_cache = dict(dispatch._BLOCK_CACHE)
    saved_meas = set(dispatch._MEASURED)
    try:
        dispatch.reset_autotune()          # "process A" measures one bucket
        dispatch._BLOCK_CACHE[("nn", 256, 256, 512)] = (128, 128, 256)
        dispatch._MEASURED.add(("nn", 256, 256, 512))
        dispatch.save_autotune(path)
        dispatch.reset_autotune()          # "process B" measures another
        dispatch._BLOCK_CACHE[("attn", 4096, 4, 64, 8)] = (512,)
        dispatch._MEASURED.add(("attn", 4096, 4, 64, 8))
        dispatch.save_autotune(path)
        dispatch.reset_autotune()
        assert dispatch.load_autotune(path) == 2   # both survived
        # zero blocks / over-budget split / wrong arity / unknown kind
        json.dump({"nn|256|256|512": [0, 0, 0],
                   "attn|4096|4|64|8": [1 << 20],
                   "nt|64|64": [64, 64, 64],
                   "bogus|1": [1]}, open(path, "w"))
        dispatch.reset_autotune()
        assert dispatch.load_autotune(path) == 0
    finally:
        dispatch.reset_autotune()
        dispatch._BLOCK_CACHE.update(saved_cache)
        dispatch._MEASURED.update(saved_meas)


# ---------------------------------------------------------------------------
# serve-level: --fused-decode is invisible in the token stream
# ---------------------------------------------------------------------------

POL = PrecisionPolicy("float32")
POL_FUSED = PrecisionPolicy("float32", fused_decode=True)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def prompts(model):
    cfg, _ = model
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(1), (n,), 0,
                                          cfg.vocab_size))
            for n in (8, 5)]


def _serve(cfg, params, prompts, policy, bits, max_new=6):
    eng = ServeEngine(cfg, policy, params, max_slots=2, max_len=24,
                      options=EngineOptions(cache_bits=bits))
    uids = [eng.submit(p, max_new=max_new) for p in prompts]
    out = eng.run()
    return [out[u] for u in uids], eng


@pytest.mark.parametrize("bits", [8, 16, 0], ids=["int8", "int16", "f32"])
def test_fused_decode_tokens_match_unfused(model, prompts, bits):
    """Mixed-length greedy decodes are token-for-token identical with
    --fused-decode on, for packed AND raw pools."""
    cfg, params = model
    ref, _ = _serve(cfg, params, prompts, POL, bits)
    got, eng = _serve(cfg, params, prompts, POL_FUSED, bits)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g, r)
    if bits:
        assert eng.codec.fused_decode
        assert eng.cache_stats()["cache_appends_quantized"] > 0


def test_fused_decode_never_calls_codec_load(model, prompts, monkeypatch):
    """Acceptance: no f32 K/V materialization on the fused hot path —
    decode must succeed with ``PackedKVCodec.load`` booby-trapped."""
    cfg, params = model

    def boom(self, entry):
        raise AssertionError("codec.load materialized f32 K/V on the "
                             "fused decode path")

    monkeypatch.setattr(PackedKVCodec, "load", boom)
    got, _ = _serve(cfg, params, prompts, POL_FUSED, 8, max_new=4)
    assert [len(g) for g in got] == [4, 4]
    with pytest.raises(Exception):   # and the trap itself is live
        _serve(cfg, params, prompts, POL, 8, max_new=2)


def test_fused_decode_windowed_arch():
    """Local (sliding-window) attention layers engage the kernel's window
    mask: gemma3-style 5:1 local:global smoke decodes identically."""
    cfg = configs.get_smoke("gemma3_27b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(2), (6,), 0,
                                             cfg.vocab_size))]
    ref, _ = _serve(cfg, params, prompts, POL, 8, max_new=5)
    got, _ = _serve(cfg, params, prompts, POL_FUSED, 8, max_new=5)
    np.testing.assert_array_equal(got[0], ref[0])


def test_fused_decode_stochastic_cache(model, prompts):
    """Gupta-2015 stochastic appends draw identical streams under the
    fused path (append is untouched; only the attend changed)."""
    cfg, params = model
    outs = []
    for pol in (POL, POL_FUSED):
        eng = ServeEngine(cfg, pol, params, max_slots=2, max_len=24,
                          options=EngineOptions(
                              cache_bits=8,
                              cache_cfg=CacheQuantConfig(width=8,
                                                         stochastic=True),
                              seed=7))
        uids = [eng.submit(p, max_new=5) for p in prompts]
        out = eng.run()
        outs.append([out[u] for u in uids])
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)
