from .ops import dfxp_quantize  # noqa: F401
