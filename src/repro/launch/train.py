"""Training driver: calibrate → DFXP train, with fault tolerance.

Fault-tolerance contract:
  * checkpoint every ``--ckpt-every`` steps (async, atomic, keeps 3);
  * SIGTERM/SIGINT (preemption) → synchronous final checkpoint → exit 143;
  * restart with the same ``--ckpt-dir`` resumes from the latest committed
    step; the data pipeline is deterministic in (seed, step), so the token
    stream continues exactly where it left off;
  * restore reshards onto whatever mesh the new job has (elastic).

CPU-runnable example (see examples/train_lm.py for the wrapped version):
  PYTHONPATH=src python -m repro.launch.train --arch granite_moe_1b \
      --smoke --steps 50 --global-batch 8 --seq-len 64 --arithmetic dfxp
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core.policy import PrecisionPolicy
from repro.data import SyntheticLM
from repro.models import transformer as T
from repro.optim.opt import OptConfig, sgd_init
from repro.train import init_train_state, make_train_step
from repro.train.calibrate import calibrate


def build_policy(args) -> PrecisionPolicy:
    return PrecisionPolicy(
        arithmetic=args.arithmetic, comp_width=args.comp_width,
        update_width=args.update_width, update_interval=args.update_interval,
        storage=args.storage,
        max_overflow_rate=args.max_overflow_rate,
        fused_matmul=getattr(args, "fused_matmul", False))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_moe_1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--arithmetic", default="dfxp",
                    choices=["float32", "float16", "bfloat16", "fixed",
                             "dfxp"])
    ap.add_argument("--comp-width", type=int, default=10)
    ap.add_argument("--update-width", type=int, default=12)
    ap.add_argument("--update-interval", type=int, default=20)
    ap.add_argument("--max-overflow-rate", type=float, default=1e-4)
    ap.add_argument("--storage", default="sim", choices=["sim", "packed"])
    ap.add_argument("--fused-matmul", action="store_true",
                    help="route QTape.dot through the fused Pallas qmatmul "
                         "(fwd+dgrad+wgrad custom-VJP kernels; bit-identical "
                         "to the composite, compiled on TPU)")
    ap.add_argument("--calibrate-steps", type=int, default=5)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--numerics-log", default="",
                    help="write the §5 numeric-health timeline (per-tensor-"
                         "class exponents, overflow rates, controller "
                         "up/down moves) as JSONL to this path")
    ap.add_argument("--numerics-every", type=int, default=0,
                    help="numerics sampling cadence in steps (default: the "
                         "controller's --update-interval)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    policy = build_policy(args)
    gs = T.group_shapes(cfg)
    opt_cfg = OptConfig(kind=args.optimizer, lr=args.lr,
                        lr_decay_steps=max(args.steps, 1000))
    key = jax.random.PRNGKey(args.seed)
    data = SyntheticLM(cfg.vocab_size, args.seq_len, args.global_batch,
                       seed=args.seed)

    def loss_fn(p, b, s, exps):
        return T.loss_fn(cfg, policy, p, b, exps, s)

    # --- calibration (paper §9.3), then reinitialize ------------------------
    init_exp = -8.0
    if policy.dynamic and args.calibrate_steps:
        obs_policy = dataclasses.replace(policy, arithmetic="observe",
                                         storage="sim")

        def obs_loss(p, b, s, exps):
            return T.loss_fn(cfg, obs_policy, p, b, exps, s)

        params0 = T.init_params(cfg, key)
        batches = ( {k: jnp.asarray(v) for k, v in data.batch(i).items()}
                    for i in range(args.calibrate_steps))
        init_exp = calibrate(obs_loss, params0, gs, policy, opt_cfg,
                             batches, steps=args.calibrate_steps)
        print(f"calibrated {len(init_exp)} scale groups")

    params = T.init_params(cfg, jax.random.fold_in(key, 1))
    state = init_train_state(params, sgd_init(params) if
                             args.optimizer == "sgd" else
                             __import__("repro.optim.opt",
                                        fromlist=["adamw_init"]).adamw_init(
                                            params),
                             gs, policy, init_exp=init_exp)

    num_log = None
    num_every = args.numerics_every or args.update_interval
    if args.numerics_log:
        from repro.obs import NumericsLog
        num_log = NumericsLog(args.numerics_log)

    step_fn = jax.jit(make_train_step(loss_fn, gs, policy, opt_cfg,
                                      microbatches=args.microbatches,
                                      numerics_tap=num_log is not None))

    # --- checkpoint / resume -------------------------------------------------
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr and mgr.latest() is not None:
        state = mgr.restore(state)
        start = int(state.step)
        print(f"resumed from step {start}")

    stop = {"now": False}

    def _preempt(signum, frame):
        stop["now"] = True

    signal.signal(signal.SIGTERM, _preempt)
    signal.signal(signal.SIGINT, _preempt)

    # --- loop -----------------------------------------------------------------
    # perf_counter: the step-rate readout is a delta, keep it monotonic
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = step_fn(state, batch, jax.random.fold_in(key, i))
        if num_log is not None and ((i + 1) % num_every == 0
                                    or i + 1 == args.steps):
            from repro.obs import train_records
            tap = jax.device_get(metrics["numerics"])
            for rec in train_records(tap["prev_exps"], tap["exps"],
                                     tap["acc"], step=i + 1,
                                     t=time.perf_counter() - t0):
                num_log.record(rec)
        if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
            print(f"step {i+1}: loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.perf_counter()-t0)/(i-start+1):.2f}s/step)",
                  flush=True)
        if mgr and ((i + 1) % args.ckpt_every == 0):
            mgr.save_async(i + 1, state)
        if stop["now"]:
            print(f"preempted at step {i+1}: writing final checkpoint")
            if mgr:
                mgr.wait()
                mgr.save(i + 1, state)
            sys.exit(143)
    if mgr:
        mgr.wait()
        mgr.save(args.steps, state)
    if num_log is not None:
        from repro.obs import count_moves
        print(f"numerics: {len(num_log.records)} records, "
              f"{count_moves(num_log.records)} controller moves -> "
              f"{args.numerics_log}")
        num_log.close()
    print("done")
    return state


if __name__ == "__main__":
    main()
