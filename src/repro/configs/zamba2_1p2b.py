"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention blocks.

38 mamba2 layers, d_model 2048, shared attn (32H, kv=32) + shared FFN every
6 mamba blocks (weights stored once — zamba's parameter-sharing trick),
ssm_state 64. [arXiv:2411.15242; hf]
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    hybrid_period=6, rope_theta=1e4, tie_embeddings=True)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", num_layers=8, d_model=128,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    ssm_state=16, ssm_headdim=32, ssm_chunk=16, hybrid_period=3,
    tie_embeddings=True)

# sub-quadratic (SSM + shared attn): long_500k runs
CELLS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
