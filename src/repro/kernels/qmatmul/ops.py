"""jit'd wrappers for the quantized matmul kernels: padding + scale packing.

Any-shape 2D operands are zero-padded up to block multiples (pads quantize
to 0 and contribute exactly 0.0 to the f32 accumulation) and the result is
sliced back.  Block sizes come from the caller — normally the autotuned
dispatch layer (:mod:`repro.kernels.dispatch`); ``None`` falls back to the
shared heuristic in :mod:`repro.kernels._tiling`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._tiling import (mm_blocks, pad2d, resolve_interpret,
                                   round_up)

from .qmatmul_kernel import qmm_2d


def _pack_scales(e_a, e_b, width_a, width_b):
    """(1, 4) [step_a, 1/step_a, step_b, 1/step_b]; 1.0 for raw operands."""
    from repro.core.quant import exact_pow2
    one = jnp.float32(1.0)

    def pair(e, width):
        if width is None:
            return one, one
        e = jnp.asarray(e, jnp.float32)
        return exact_pow2(e), exact_pow2(-e)

    sa, ia = pair(e_a, width_a)
    sb, ib = pair(e_b, width_b)
    return jnp.stack([sa, ia, sb, ib]).reshape(1, 4)


@functools.partial(jax.jit, static_argnames=(
    "kind", "width_a", "width_b", "blocks", "cast", "out_dtype",
    "interpret"))
def qmm(a, b, e_a, e_b, *, kind: str, width_a, width_b, blocks=None,
        cast=jnp.float32, out_dtype=None, interpret=None):
    """Quantized matmul on any-shape 2D operands; see ``qmm_2d`` layouts."""
    interpret = resolve_interpret(interpret)
    if kind == "nn":
        (R, D), (D2, C) = a.shape, b.shape
    elif kind == "nt":
        (R, D), (C, D2) = a.shape, b.shape
    else:  # tn
        (D, R), (D2, C) = a.shape, b.shape
    assert D == D2, f"contraction dims disagree: {a.shape} x {b.shape} ({kind})"
    if blocks is None:
        blocks = mm_blocks(kind, R, C, D)
    br, bc, bd = blocks
    Rp, Cp, Dp = round_up(R, br), round_up(C, bc), round_up(D, bd)
    if kind == "nn":
        ap, bp = pad2d(a, Rp, Dp), pad2d(b, Dp, Cp)
    elif kind == "nt":
        ap, bp = pad2d(a, Rp, Dp), pad2d(b, Cp, Dp)
    else:
        ap, bp = pad2d(a, Dp, Rp), pad2d(b, Dp, Cp)
    scales = _pack_scales(e_a, e_b, width_a, width_b)
    c = qmm_2d(ap, bp, scales, kind=kind, width_a=width_a, width_b=width_b,
               block_r=br, block_c=bc, block_d=bd, cast=cast,
               out_dtype=out_dtype, interpret=interpret)
    return c[:R, :C]


def qmatmul(a, b, e_a, e_b, *, width: int = 10, interpret=None):
    """DFXP matmul ``q(a) @ q(b)`` with f32 accumulation. Any [M,K]x[K,N].

    ``interpret=None`` auto-detects the backend (compiled on TPU,
    interpret elsewhere).
    """
    return qmm(a, b, e_a, e_b, kind="nn", width_a=width, width_b=width,
               interpret=interpret)
