"""Scale calibration (paper §9.3): "We find the initial scaling factors by
training with a higher precision format. Once those scaling factors are
found, we reinitialize the model parameters."

Runs K steps with the ``observe`` pseudo-arithmetic (fp32 math; every
quantization site records ``max|value|`` through the same tape/sink
machinery), takes the running max per group, and converts magnitudes to
initial log2-step exponents with one headroom bit. The online controller
then only has to track drift (gradients shrinking over training — paper
§10), not find 20 bits of scale from nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.core.scale import calibrate_exp
from repro.optim.opt import OptConfig, sgd_update

from .state import param_group_shapes
from .step import _map_with_group

Array = jax.Array


def observe_policy(policy: PrecisionPolicy) -> PrecisionPolicy:
    return dataclasses.replace(policy, arithmetic="observe", storage="sim")


def make_observe_step(loss_fn: Callable, group_shapes: Dict[str, tuple],
                      opt_cfg: OptConfig):
    """One fp32 SGD step that also returns per-group max-|value| stats."""

    def step(params, mom, opt_step, batch, exps):
        sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                 for n, s in group_shapes.items() if n.startswith("g:")}
        grad_fn = jax.value_and_grad(
            lambda p, s: loss_fn(p, batch, s, exps), argnums=(0, 1),
            has_aux=True)
        (loss, fwd_stats), (grads, sink_stats) = grad_fn(params, sinks)

        def obs(x, e, name):
            ax = jnp.abs(x.astype(jnp.float32))
            axes = tuple(range(jnp.ndim(e), x.ndim))
            mx = jnp.max(ax, axis=axes) if axes else ax
            z = jnp.zeros_like(mx)
            return x, jnp.stack([mx, z, z + 1.0], axis=-1)

        _, gstats = _map_with_group(obs, grads, {**{
            k: jnp.zeros(v) for k, v in group_shapes.items()
            if k.startswith("pg:")}}, "pg:")
        updates, new_momd = sgd_update(opt_cfg, grads, mom, opt_step)
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        _, pstats = _map_with_group(obs, new_params, {**{
            k: jnp.zeros(v) for k, v in group_shapes.items()
            if k.startswith("p:")}}, "p:")
        _, mstats = _map_with_group(obs, new_momd["momentum"], {**{
            k: jnp.zeros(v) for k, v in group_shapes.items()
            if k.startswith("pm:")}}, "pm:")

        stats = {}
        for d in (fwd_stats, sink_stats, gstats, pstats, mstats):
            for k, v in d.items():
                stats[k] = jnp.maximum(stats.get(k, 0.0), v[..., 0])
        return new_params, new_momd, loss, stats

    return step


def calibrate(loss_fn: Callable, params, group_shapes: Dict[str, tuple],
              policy: PrecisionPolicy, opt_cfg: OptConfig, batches,
              *, steps: int = 10) -> Dict[str, Array]:
    """Run K observe-steps over ``batches`` → per-group init exponents."""
    all_groups = dict(group_shapes)
    all_groups.update(param_group_shapes(params))
    obs_pol = observe_policy(policy)
    del obs_pol  # caller's loss_fn must already close over observe policy
    step = jax.jit(make_observe_step(loss_fn, all_groups, opt_cfg))
    mom = {"momentum": jax.tree.map(jnp.zeros_like, params)}
    exps0 = {n: jnp.zeros(s, jnp.float32) for n, s in all_groups.items()}

    maxes: Dict[str, Array] = {}
    it = iter(batches)
    for i in range(steps):
        batch = next(it)
        params, mom, loss, stats = step(params, mom, jnp.int32(i), batch,
                                        exps0)
        for k, v in stats.items():
            maxes[k] = jnp.maximum(maxes.get(k, 0.0), v)

    init_exp: Dict[str, Array] = {}
    for name, shape in all_groups.items():
        width = (policy.update_width if name.startswith(("p:", "pm:"))
                 else policy.comp_width)
        mx = maxes.get(name)
        if mx is None:
            init_exp[name] = jnp.zeros(shape, jnp.float32)
        else:
            init_exp[name] = jnp.broadcast_to(
                calibrate_exp(mx, width, margin_bits=1), shape)
    return init_exp
