"""Numeric-health timeline: §5 controller telemetry as JSONL records.

The paper's dynamic fixed-point scheme is a runtime feedback loop — per
group, the controller watches overflow rates and moves the shared
exponent ×2/÷2 every ``update_interval`` updates.  End-of-run totals
(``overflow_summary``) say whether it *converged*; this module records
the loop itself as a time series:

* **serve-side** — the engine samples a jit-safe batched snapshot of the
  packed KV pool (``kv_pool.numerics_snapshot``: per-layer/per-slot K and
  V exponents plus cumulative overflow counters, one ``device_get`` per
  sample on the controller cadence) and :func:`serve_records` diffs it
  against the previous sample into per-slot records carrying exponent
  values, overflow/underflow rates, and the controller's up/down moves.
* **train-side** — ``train/step.py`` exposes a ``numerics_tap`` that
  returns old/new exponents and the pre-reset §5 accumulators from the
  jit; :func:`train_records` aggregates them per tensor class
  (activation / gradient / weight / param...) via
  :func:`repro.core.tape.tensor_class`.

Both flow into a :class:`NumericsLog` — an append-only JSONL sink (one
JSON object per line) that is trivially greppable and loads into any
dataframe tool.  Everything here is stdlib-only and host-side; array
inputs are accepted via duck-typed ``.tolist()``.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional


def _tolist(x):
    return x.tolist() if hasattr(x, "tolist") else x


class NumericsLog:
    """Append-only JSONL sink for numeric-health records.

    With a ``path``, every :meth:`record` appends one line to the file;
    without one, records accumulate in :attr:`records` (tests, and the
    CLI's end-of-run summary read them back either way).
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.records: List[dict] = []
        self._f = open(path, "w") if path else None

    def record(self, rec: dict) -> None:
        self.records.append(rec)
        if self._f is not None:
            self._f.write(json.dumps(rec) + "\n")
            self._f.flush()

    def tail(self, n: int = 50) -> List[dict]:
        """Last ``n`` records (the diagnostic-bundle excerpt)."""
        return self.records[-n:] if n else []

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def serve_records(snapshot: dict, prev: Optional[dict], *, step: int,
                  t: float, slot_uids: Optional[Dict[int, int]] = None,
                  ) -> List[dict]:
    """Diff two KV-pool numerics snapshots into per-slot JSONL records.

    ``snapshot``/``prev`` are host-fetched ``kv_pool.numerics_snapshot``
    dicts: ``{entry_key: {"k_e"/"v_e"/"ovf"/"half"/"tot": [n_layers,
    n_slots]}}``.  One record per (entry, slot) carrying per-layer lists:

    * ``k_e``/``v_e`` — the current shared exponents (log2 steps);
    * ``ovf_rate``/``half_rate`` — cumulative §5 overflow / would-overflow-
      at-half-range rates of the slot's appends;
    * ``k_move``/``v_move`` — the controller's decision since the last
      sample per layer: +1 scale-up (exponent grew, range extended after
      overflows), −1 scale-down (precision reclaimed after a quiet
      window), 0 hold.  ``None`` on the first sample.

    Only slots present in ``slot_uids`` (occupied) are emitted when it is
    given; pass ``None`` to emit every slot.
    """
    out: List[dict] = []
    for ekey, cur in snapshot.items():
        k_e, v_e = _tolist(cur["k_e"]), _tolist(cur["v_e"])
        ovf, half, tot = (_tolist(cur["ovf"]), _tolist(cur["half"]),
                          _tolist(cur["tot"]))
        pk = pv = None
        if prev is not None and ekey in prev:
            pk, pv = _tolist(prev[ekey]["k_e"]), _tolist(prev[ekey]["v_e"])
        n_layers = len(k_e)
        n_slots = len(k_e[0]) if n_layers else 0
        slots = range(n_slots) if slot_uids is None else sorted(slot_uids)
        for b in slots:
            if b >= n_slots:
                continue
            rec = {
                "kind": "serve", "t": t, "step": step, "entry": ekey,
                "slot": b,
                "uid": slot_uids.get(b) if slot_uids is not None else None,
                "k_e": [k_e[L][b] for L in range(n_layers)],
                "v_e": [v_e[L][b] for L in range(n_layers)],
                "ovf_rate": [ovf[L][b] / max(tot[L][b], 1.0)
                             for L in range(n_layers)],
                "half_rate": [half[L][b] / max(tot[L][b], 1.0)
                              for L in range(n_layers)],
                "k_move": None if pk is None else
                [_sign(k_e[L][b] - pk[L][b]) for L in range(n_layers)],
                "v_move": None if pv is None else
                [_sign(v_e[L][b] - pv[L][b]) for L in range(n_layers)],
            }
            out.append(rec)
    return out


def train_records(prev_exps: dict, exps: dict, acc: dict, *, step: int,
                  t: float) -> List[dict]:
    """Aggregate one controller application into per-tensor-class records.

    ``prev_exps``/``exps``: group → exponent (scalar, host-fetched) before
    and after ``controller_step``; ``acc``: group → ``(ovf, ovf_half,
    total)`` — the §5 window accumulators the decision was made FROM
    (i.e. captured before the post-apply reset).  One record per tensor
    class (:func:`repro.core.tape.tensor_class` of the group name).
    """
    from repro.core.tape import tensor_class

    by_cls: Dict[str, dict] = {}
    for g, e_new in exps.items():
        cls = tensor_class(g)
        d = by_cls.setdefault(cls, {"exp": [], "up": 0, "down": 0,
                                    "ovf": 0.0, "half": 0.0, "tot": 0.0})
        new_vals = _flat(e_new)
        old_vals = _flat(prev_exps.get(g, e_new))
        for en, eo in zip(new_vals, old_vals):
            d["exp"].append(en)
            mv = _sign(en - eo)
            if mv > 0:
                d["up"] += 1
            elif mv < 0:
                d["down"] += 1
        a = acc.get(g) if acc else None
        if a is not None:
            # shape exps.shape + (3,): sum the (ovf, half, tot) triples
            flat = _flat(a)
            d["ovf"] += sum(flat[0::3])
            d["half"] += sum(flat[1::3])
            d["tot"] += sum(flat[2::3])
    out = []
    for cls in sorted(by_cls):
        d = by_cls[cls]
        tot = max(d["tot"], 1.0)
        out.append({
            "kind": "train", "t": t, "step": step, "class": cls,
            "n_groups": len(d["exp"]),
            "exp_mean": sum(d["exp"]) / len(d["exp"]),
            "exp_min": min(d["exp"]), "exp_max": max(d["exp"]),
            "ovf_rate": d["ovf"] / tot, "half_rate": d["half"] / tot,
            "moves_up": d["up"], "moves_down": d["down"],
        })
    return out


def count_moves(records: List[dict]) -> int:
    """Total §5 controller exponent moves across a record list (CI check)."""
    n = 0
    for r in records:
        if r.get("kind") == "train":
            n += int(r.get("moves_up", 0)) + int(r.get("moves_down", 0))
        else:
            for key in ("k_move", "v_move"):
                mv = r.get(key)
                if mv:
                    n += sum(1 for m in mv if m)
    return n


def read_jsonl(path: str) -> List[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _sign(d: float) -> int:
    return (d > 0) - (d < 0)


def _flat(x) -> List[float]:
    """Flatten a scalar / nested-list / array value to a float list."""
    x = _tolist(x)
    if not isinstance(x, list):
        return [float(x)]
    out: List[float] = []
    for v in x:
        out.extend(_flat(v))
    return out


__all__ = ["NumericsLog", "serve_records", "train_records", "count_moves",
           "read_jsonl"]
