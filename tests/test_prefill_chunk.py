"""Chunked prefill: kernel bit-equality, codec chunk-append, scheduler."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.packed import container_dtype, qrange
from repro.core.policy import PrecisionPolicy
from repro.kernels import dispatch
from repro.kernels.attn import ref as R
from repro.kernels.attn.ops import flash_prefill
from repro.launch.serve import Engine as LockstepEngine
from repro.models import transformer as T
from repro.serve import (
    CacheQuantConfig,
    EngineOptions,
    PackedKVCodec,
    SamplerConfig,
    ServeEngine,
)

POL = PrecisionPolicy("float32")


def _case(key, B, C, W, K, G, hd, width, n_valid=None, p0v=6, holes=False):
    """Random flash-prefill operands in the codec entry layout."""
    ks = jax.random.split(key, 8)
    q = jax.random.normal(ks[0], (B, C, K, G, hd), jnp.float32)
    kn = jax.random.normal(ks[5], (B, C, K, hd), jnp.float32)
    vn = jax.random.normal(ks[6], (B, C, K, hd), jnp.float32)
    if width is None:
        k = jax.random.normal(ks[1], (B, W, K, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, W, K, hd), jnp.float32)
        ke = ve = None
    else:
        qmax, qmin = qrange(width)
        dt = container_dtype(width)
        k = jax.random.randint(ks[1], (B, W, K, hd), int(qmin),
                               int(qmax) + 1).astype(dt)
        v = jax.random.randint(ks[2], (B, W, K, hd), int(qmin),
                               int(qmax) + 1).astype(dt)
        ke = jax.random.randint(ks[3], (B,), -8, -2).astype(jnp.float32)
        ve = jax.random.randint(ks[4], (B,), -8, -2).astype(jnp.float32)
    pos = jnp.where(jnp.arange(W) < p0v, jnp.arange(W), -1)
    pos = jnp.broadcast_to(pos, (B, W)).astype(jnp.int32)
    if holes:
        gap = jax.random.bernoulli(ks[7], 0.3, (B, W))
        pos = jnp.where(gap, -1, pos)
    p0 = jnp.full((B,), p0v, jnp.int32)
    nv = jnp.full((B,), n_valid if n_valid is not None else C, jnp.int32)
    return q, kn, vn, k, v, pos, p0, nv, ke, ve


def _both(case, width, scale=0.25, window=None, block_w=None):
    q, kn, vn, k, v, pos, p0, nv, ke, ve = case
    out = flash_prefill(q, kn, vn, k, v, pos, p0, nv, ke, ve, width=width,
                        scale=scale, window=window, block_w=block_w,
                        interpret=True)
    # the ref is jitted: the interpret kernel body and the model's inline
    # composite both run under jit, and unjitted XLA dispatch may pick a
    # different (1-ULP-off) contraction for degenerate chunk shapes
    reff = jax.jit(functools.partial(R.prefill_attention_ref, width=width,
                                     scale=scale, window=window))
    ref = reff(q, k, v, pos, kn, vn, p0, nv, k_exp=ke, v_exp=ve)
    return np.asarray(out), np.asarray(ref)


# ---------------------------------------------------------------------------
# acceptance: interpret-mode bit-equality vs the chunked ref composite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [8, 16, None], ids=["int8", "int16", "f32"])
def test_bit_equal_vs_chunk_ref(width):
    case = _case(jax.random.PRNGKey(0), B=2, C=4, W=12, K=2, G=2, hd=8,
                 width=width)
    out, ref = _both(case, width)
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("width", [8, None], ids=["int8", "f32"])
def test_ragged_tail_and_holes(width):
    """Ragged final chunks (n_valid < C) and scattered empty history slots
    mask exactly; garbage rows stay finite."""
    case = _case(jax.random.PRNGKey(1), B=3, C=5, W=15, K=2, G=2, hd=4,
                 width=width, n_valid=3, holes=True)
    out, ref = _both(case, width)
    np.testing.assert_array_equal(out, ref)
    assert np.all(np.isfinite(out))


def test_sliding_window_spans_history_and_chunk():
    case = _case(jax.random.PRNGKey(2), B=2, C=6, W=16, K=2, G=2, hd=4,
                 width=8)
    for window in (1, 3, 8):
        out, ref = _both(case, 8, window=window)
        np.testing.assert_array_equal(out, ref)


def test_admission_chunk_empty_history():
    """p0 == 0: every history lane is masked; only the self block scores."""
    case = _case(jax.random.PRNGKey(3), B=2, C=4, W=10, K=1, G=2, hd=4,
                 width=8, p0v=0)
    out, ref = _both(case, 8)
    np.testing.assert_array_equal(out, ref)
    assert np.all(np.isfinite(out))


@pytest.mark.parametrize("width", [8, 16, None], ids=["int8", "int16", "f32"])
@pytest.mark.parametrize("block_w", [3, 5, 16])
def test_split_k_matches_ref(width, block_w):
    """Forced history splits (aligned, unaligned, >W) reproduce the joint
    flash combine across history splits + the final self block."""
    case = _case(jax.random.PRNGKey(4), B=2, C=4, W=13, K=2, G=2, hd=8,
                 width=width, p0v=11)
    out, ref = _both(case, width, block_w=block_w)
    np.testing.assert_allclose(out, ref, rtol=2e-6, atol=2e-6)


def test_split_k_fully_masked_history():
    """All-empty history splits (p0 == 0) contribute exactly 0 through the
    running-max combine — no NaN, the self block alone decides."""
    case = _case(jax.random.PRNGKey(5), B=2, C=3, W=12, K=1, G=2, hd=4,
                 width=8, p0v=0)
    out, ref = _both(case, 8, block_w=4)
    np.testing.assert_allclose(out, ref, rtol=2e-6, atol=2e-6)
    assert np.all(np.isfinite(out))


# ---------------------------------------------------------------------------
# dispatch: prefill buckets share the persisted autotune table
# ---------------------------------------------------------------------------

def test_prefill_blocks_interpret_is_whole_window():
    assert dispatch.prefill_blocks_for(300, 8, 4, 64, width=8,
                                       interpret=True) == 300


def test_prefill_bucket_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "autotune.json")
    saved_cache = dict(dispatch._BLOCK_CACHE)
    saved_meas = set(dispatch._MEASURED)
    try:
        dispatch.reset_autotune()
        dispatch._BLOCK_CACHE[("prefill", 64, 4, 64, 8)] = (512,)
        dispatch._MEASURED.add(("prefill", 64, 4, 64, 8))
        assert dispatch.save_autotune(path) == path
        dispatch.reset_autotune()
        assert dispatch.load_autotune(path) == 1
        dispatch.set_autotune(measure=False)
        assert dispatch.prefill_blocks_for(4000, 64, 4, 64, width=8,
                                           interpret=False) == 512
        # semantic validation: an over-VMEM split is rejected on load
        import json
        json.dump({"prefill|64|4|64|8": [1 << 20]}, open(path, "w"))
        dispatch.reset_autotune()
        assert dispatch.load_autotune(path) == 0
    finally:
        dispatch.reset_autotune()
        dispatch.set_autotune(measure=True)
        dispatch._BLOCK_CACHE.update(saved_cache)
        dispatch._MEASURED.update(saved_meas)


# ---------------------------------------------------------------------------
# codec: chunk append == per-token appends; masking; admission reset
# ---------------------------------------------------------------------------

def _packed_entry(key, B=2, W=10, K=2, hd=4, width=8, n_valid=4):
    """A calibrated packed entry (layer dim stripped) with n_valid slots."""
    codec = PackedKVCodec(CacheQuantConfig(width=width))
    kk, kv = jax.random.split(key)
    pos = jnp.where(jnp.arange(W) < n_valid, jnp.arange(W), -1)
    raw = {"k": jax.random.normal(kk, (1, B, W, K, hd)),
           "v": jax.random.normal(kv, (1, B, W, K, hd)),
           "pos": jnp.broadcast_to(pos, (1, B, W)).astype(jnp.int32)}
    return codec, jax.tree_util.tree_map(lambda x: x[0],
                                         codec.pack_entry(raw))


def test_append_chunk_equals_token_appends():
    """A C-token chunk write lands the same mantissas/positions/stats as C
    sequential per-token appends (below the controller interval)."""
    codec, entry = _packed_entry(jax.random.PRNGKey(0))
    C = 3
    k_new = jax.random.normal(jax.random.PRNGKey(1), (2, C, 2, 4)) * 0.3
    v_new = jax.random.normal(jax.random.PRNGKey(2), (2, C, 2, 4)) * 0.3
    p0 = jnp.full((2,), 4, jnp.int32)
    chunked = codec.append_chunk(dict(entry), k_new, v_new, p0,
                                 jnp.full((2,), C, jnp.int32))
    stepped = dict(entry)
    for i in range(C):
        stepped = codec.append(stepped, k_new[:, i], v_new[:, i], p0 + i)
    for f in ("k_m", "v_m", "pos", "k_e", "v_e", "n_app", "acc_k", "acc_v",
              "tot_k", "tot_v"):
        np.testing.assert_array_equal(np.asarray(chunked[f]),
                                      np.asarray(stepped[f]), err_msg=f)


def test_append_chunk_ragged_rows_dropped():
    codec, entry = _packed_entry(jax.random.PRNGKey(3))
    C, nv = 4, 2
    k_new = jax.random.normal(jax.random.PRNGKey(4), (2, C, 2, 4)) * 0.3
    p0 = jnp.full((2,), 4, jnp.int32)
    out = codec.append_chunk(dict(entry), k_new, k_new, p0,
                             jnp.full((2,), nv, jnp.int32))
    pos = np.asarray(out["pos"])
    assert np.all(pos[:, 4:6] == [4, 5])       # valid rows written
    assert np.all(pos[:, 6:] == -1)            # ragged tail dropped
    assert np.all(np.asarray(out["n_app"]) == nv)


def test_admission_chunk_resets_recycled_slot():
    """p0 == 0 behaves like pack_entry: stale ring positions vanish,
    exponents recalibrate from the chunk, counters restart."""
    codec, entry = _packed_entry(jax.random.PRNGKey(5), n_valid=9)
    entry = dict(entry)
    entry["n_app"] = entry["n_app"] + 7.0          # stale occupant state
    big = jax.random.normal(jax.random.PRNGKey(6), (2, 3, 2, 4)) * 40.0
    out = codec.append_chunk(entry, big, big, jnp.zeros((2,), jnp.int32),
                             jnp.full((2,), 3, jnp.int32))
    pos = np.asarray(out["pos"])
    assert np.all(pos[:, :3] == [0, 1, 2])
    assert np.all(pos[:, 3:] == -1)                # previous occupant gone
    assert np.all(np.asarray(out["n_app"]) == 0.0)
    assert np.all(np.asarray(out["tot_k"]) == 0.0)
    # exponents refit the chunk's magnitude (40 >> the stale calibration)
    step = 2.0 ** np.asarray(out["k_e"])
    assert np.all(step * 127 >= 40.0)
    km = np.asarray(out["k_m"][:, :3], np.float32)
    err = np.abs(km * step[:, None, None, None] - np.asarray(big))
    assert np.all(err <= step[:, None, None, None] / 2 + 1e-6)


def test_masked_append_leaves_rows_untouched():
    """mask=False rows keep every field bit-identical (no write, no stats,
    no counter, no controller) while mask=True rows match the unmasked
    append — the invariant that keeps mid-prefill slots solo-exact."""
    codec, entry = _packed_entry(jax.random.PRNGKey(7))
    k_new = jax.random.normal(jax.random.PRNGKey(8), (2, 2, 4)) * 0.3
    pos = jnp.full((2,), 4, jnp.int32)
    mask = jnp.asarray([True, False])
    out = codec.append(dict(entry), k_new, k_new, pos, mask=mask)
    ref = codec.append(dict(entry), k_new, k_new, pos)
    for f in ("k_m", "v_m", "pos", "k_e", "v_e", "n_app", "acc_k", "tot_k"):
        np.testing.assert_array_equal(np.asarray(out[f])[0],
                                      np.asarray(ref[f])[0], err_msg=f)
        np.testing.assert_array_equal(np.asarray(out[f])[1],
                                      np.asarray(entry[f])[1], err_msg=f)


# ---------------------------------------------------------------------------
# scheduler: chunked == whole-prompt, one jit, immediate admission
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def prompts(model):
    cfg, _ = model
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i),
                                          (n,), 0, cfg.vocab_size))
            for i, n in enumerate((5, 9, 13))]


def _drive(cfg, params, prompts, *, bits, chunk, fused=False, max_new=6,
           slots=2, max_len=32):
    pol = PrecisionPolicy("float32", fused_decode=fused,
                          prefill_chunk=chunk)
    eng = ServeEngine(cfg, pol, params, max_slots=slots, max_len=max_len,
                      options=EngineOptions(cache_bits=bits))
    uids = [eng.submit(p, max_new=max_new) for p in prompts]
    out = eng.run()
    return [out[u] for u in uids], eng


@pytest.mark.parametrize("bits", [0, 8, 16], ids=["f32", "int8", "int16"])
def test_chunked_tokens_match_whole_prompt(model, prompts, bits):
    """Acceptance: greedy streams are identical chunked vs whole-prompt on
    f32/int8/int16 pools — no equal-length partner anywhere."""
    cfg, params = model
    ref, _ = _drive(cfg, params, prompts, bits=bits, chunk=0)
    got, eng = _drive(cfg, params, prompts, bits=bits, chunk=4)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g, r)
    assert eng.prefill_chunk == 4


@pytest.mark.parametrize("bits", [0, 8], ids=["f32", "int8"])
def test_chunked_fused_tokens_match_whole_prompt(model, prompts, bits):
    """The flash-prefill kernel path (fused_decode) is invisible in the
    token stream too."""
    cfg, params = model
    ref, _ = _drive(cfg, params, prompts, bits=bits, chunk=0)
    got, _ = _drive(cfg, params, prompts, bits=bits, chunk=4, fused=True)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g, r)


def test_one_prefill_jit_across_mixed_lengths(model, prompts):
    """Acceptance: exactly one prefill compilation for a mixed-length
    stream (whole-prompt mode compiles one per (g, L) pair)."""
    cfg, params = model
    _, eng = _drive(cfg, params, prompts, bits=8, chunk=4)
    assert eng._chunk._cache_size() == 1
    assert eng._prefill._cache_size() == 0      # grouped path never ran
    _, eng0 = _drive(cfg, params, prompts, bits=8, chunk=0)
    assert eng0._prefill._cache_size() == len({len(p) for p in prompts})


def test_immediate_admission_without_length_partner(model, prompts):
    """Mixed lengths admit into free slots on the first step — nobody
    waits for an equal-length partner, and TTFT ordering shows the long
    prompt's chunks interleaving with the short request's decode."""
    cfg, params = model
    pol = PrecisionPolicy("float32", prefill_chunk=4)
    eng = ServeEngine(cfg, pol, params, max_slots=2, max_len=32)
    u_short = eng.submit(prompts[0], max_new=2)          # 5 tokens
    u_long = eng.submit(prompts[2], max_new=2)           # 13 tokens
    eng.step()
    tr = eng.metrics.traces
    assert tr[u_short].t_admit is not None
    assert tr[u_long].t_admit is not None                # no partner wait
    eng.run()
    # FIFO chunking: the short prompt (2 chunks) finished prefill and
    # decoded while the long prompt (4 chunks) was still prefilling
    assert tr[u_short].t_first < tr[u_long].t_first
    assert tr[u_long].prefill_chunks == 4
    assert tr[u_short].prefill_chunks == 2
    # and each stream equals its solo run
    solo, _ = _drive(cfg, params, [prompts[2]], bits=0, chunk=4, max_new=2)
    np.testing.assert_array_equal(eng._results[u_long], solo[0])


def test_chunked_admission_into_freed_slot_matches_solo(model, prompts):
    """3 requests, 2 slots: the queued request chunk-prefills into a slot
    freed mid-decode and reproduces its run-alone tokens exactly."""
    cfg, params = model
    reqs = [(prompts[0], 3), (prompts[1], 8), (prompts[0][:5], 5)]
    pol = PrecisionPolicy("float32", prefill_chunk=4)
    eng = ServeEngine(cfg, pol, params, max_slots=2, max_len=24,
                      options=EngineOptions(cache_bits=8))
    uids = [eng.submit(p, max_new=m) for p, m in reqs]
    out = eng.run()
    solo, _ = _drive(cfg, params, [prompts[0][:5]], bits=8, chunk=4,
                     max_new=5, max_len=24)
    np.testing.assert_array_equal(out[uids[2]], solo[0])


def test_chunked_windowed_arch_chunk_larger_than_window():
    """gemma3-style local layers: a chunk larger than the window cap
    (in-chunk ring eviction) still matches whole-prompt exactly."""
    cfg = configs.get_smoke("gemma3_27b")     # window 16
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(7 + i),
                                             (n,), 0, cfg.vocab_size))
               for i, n in enumerate((6, 21))]
    for fused in (False, True):
        ref, _ = _drive(cfg, params, prompts, bits=8, chunk=0, max_new=5)
        got, _ = _drive(cfg, params, prompts, bits=8, chunk=24,
                        fused=fused, max_new=5)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(g, r)


def test_chunked_stochastic_topk_solo_equals_batched(model, prompts):
    """Per-request PRNG streams survive chunked admission: stochastic
    cache + top-k sampling draw identical tokens solo vs batched."""
    cfg, params = model
    kw = dict(max_slots=2, max_len=24, options=EngineOptions(
        cache_bits=8,
        cache_cfg=CacheQuantConfig(width=8, stochastic=True),
        sampler_cfg=SamplerConfig("top_k", temperature=0.9, top_k=8),
        seed=7))
    pol = PrecisionPolicy("float32", prefill_chunk=3)
    a = ServeEngine(cfg, pol, params, **kw)
    uids = [a.submit(p, max_new=4) for p in prompts[:2]]
    out = a.run()
    b = ServeEngine(cfg, pol, params, **kw)
    u = b.submit(prompts[0], max_new=4)
    np.testing.assert_array_equal(out[uids[0]], b.run()[u])


def test_chunked_fused_never_calls_codec_load(model, prompts, monkeypatch):
    """Acceptance: no f32 K/V materialization in either direction — a
    chunked + fused engine must survive a booby-trapped codec.load."""
    cfg, params = model

    def boom(self, entry):
        raise AssertionError("codec.load materialized f32 K/V on the "
                             "fused chunked-prefill path")

    monkeypatch.setattr(PackedKVCodec, "load", boom)
    got, _ = _drive(cfg, params, prompts[:2], bits=8, chunk=4, fused=True,
                    max_new=4)
    assert [len(g) for g in got] == [4, 4]
    with pytest.raises(Exception):      # and the trap itself is live
        _drive(cfg, params, prompts[:2], bits=8, chunk=4, max_new=2)


def test_moe_keeps_whole_prompt_carveout():
    """MoE expert capacity couples a prompt's tokens: prefill_chunk is
    ignored and the solo whole-prompt admission path stays in force."""
    cfg = configs.get_smoke("granite_moe_1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    pol = PrecisionPolicy("float32", prefill_chunk=4)
    eng = ServeEngine(cfg, pol, params, max_slots=2, max_len=16)
    assert eng.prefill_chunk == 0
    assert eng._admit_group_cap == 1


# ---------------------------------------------------------------------------
# ssm ragged-tail fix (submit no longer demands ssm_chunk alignment)
# ---------------------------------------------------------------------------

def test_ssm_ragged_prompt_serves_and_matches_lockstep():
    cfg = configs.get_smoke("mamba2_370m")    # ssm_chunk 16
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (19,), 0,
                                           cfg.vocab_size))
    ref = np.asarray(LockstepEngine(cfg, POL, params, max_len=32)
                     .generate(jnp.asarray(prompt[None]), max_new=5))
    eng = ServeEngine(cfg, POL, params, max_slots=1, max_len=32)
    uid = eng.submit(prompt, max_new=5)       # 19 % 16 != 0: now accepted
    np.testing.assert_array_equal(eng.run()[uid], ref[0])


def test_ssm_ragged_prefill_state_matches_decode_steps():
    """The masked final chunk's cache equals aligned prefill + per-token
    decode over the ragged tail (the state after exactly L real tokens)."""
    from repro.core import ScaleState
    cfg = configs.get_smoke("mamba2_370m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    gs = T.group_shapes(cfg)
    exps = ScaleState.create(gs, -6.0).exps
    sinks = {n: jnp.zeros(s + (3,), jnp.float32)
             for n, s in gs.items() if n.startswith("g:")}
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 19), 0,
                              cfg.vocab_size)
    logits_r, _, cache_r = T.prefill(cfg, POL, params, {"tokens": toks},
                                     exps, sinks, max_cache_len=32)
    logits_a, _, cache_a = T.prefill(cfg, POL, params,
                                     {"tokens": toks[:, :16]}, exps, sinks,
                                     max_cache_len=32)
    for i in range(16, 19):
        logits_a, _, cache_a = T.decode_step(cfg, POL, params, cache_a,
                                             toks[:, i], jnp.int32(i),
                                             exps, sinks)
    np.testing.assert_allclose(np.asarray(logits_r), np.asarray(logits_a),
                               rtol=2e-4, atol=2e-5)
    for bkey, e in cache_r["dec"].items():
        for f in e:
            np.testing.assert_allclose(
                np.asarray(e[f]), np.asarray(cache_a["dec"][bkey][f]),
                rtol=2e-4, atol=1e-5, err_msg=f"{bkey}/{f}")
