"""qwen3-14b [dense]: qk_norm, GQA. [hf:Qwen/Qwen3-14B]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=17408,
    vocab_size=151936, qk_norm=True, rope_theta=1e6, tie_embeddings=False)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    qk_norm=True, tie_embeddings=False)

CELLS = ("train_4k", "prefill_32k", "decode_32k")
