"""Chaos suite: fault injection against the serve engine's robustness layer.

Every test drives real faults through the real boundaries (device-side
NaN masks, allocator page grabs, mantissa bit flips, admission gates)
and asserts the two invariants the robustness layer promises:

1. the engine always drains — ``run()`` never raises for load, faults,
   or exhaustion, and every submitted uid ends in a terminal
   ``RequestStatus``;
2. fault blast radius is one request — sibling streams are byte-for-byte
   identical to a fault-free run.
"""
import json

import numpy as np
import pytest
import jax

from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.models import transformer as T
from repro.serve import (AdmitDelay, EngineOptions, FaultHarness, KVBitFlip,
                         LogitNaN, PageSqueeze, RequestStatus, SamplerConfig,
                         ServeEngine, chaos_plan)
from repro.serve import metrics as M

P, MAXLEN = 8, 32


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("llama3_8b")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prompts(model):
    cfg, _ = model
    shared = (np.arange(1, 17) % cfg.vocab_size).astype(np.int32)
    pa = np.concatenate([shared, [17, 18, 19, 20]]).astype(np.int32)
    pb = np.concatenate([shared, [31, 32, 33, 34]]).astype(np.int32)
    pc = (np.arange(5, 15) % cfg.vocab_size).astype(np.int32)
    return pa, pb, pc


def _mk(model, *, bits=0, slots=2, n_pages=None, faults=None,
        sampler=None, **kw):
    cfg, params = model
    pol = PrecisionPolicy("dfxp", fused_decode=bool(bits), prefill_chunk=P,
                          page_size=P)
    return ServeEngine(cfg, pol, params, max_slots=slots, max_len=MAXLEN,
                       options=EngineOptions(
                           cache_bits=bits, n_pages=n_pages, faults=faults,
                           sampler_cfg=sampler or SamplerConfig(), **kw))


def _submit_all(eng, ps, max_new=6):
    return [eng.submit(p, max_new=max_new) for p in ps]


# ---------------------------------------------------------------------------
# forced exhaustion → preemption → bit-identical resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampler", [SamplerConfig(),
                                     SamplerConfig(kind="top_k", top_k=5,
                                                   temperature=0.8)])
def test_page_squeeze_preempts_and_resumes_bit_identical(model, prompts,
                                                         sampler):
    """Grabbing the free pages mid-decode forces genuine exhaustion: the
    engine preempts the youngest request, recycles its pages, and after
    the squeeze releases, the victim resumes and finishes — with both
    the greedy and the stochastic stream bit-identical to uninterrupted
    solo runs (the sampler keys on absolute position, not on step)."""
    pa, pb, _ = prompts
    fh = FaultHarness([PageSqueeze(step=6, n_pages=16, release_step=14)])
    eng = _mk(model, faults=fh, sampler=sampler)
    ua, ub = _submit_all(eng, [pa, pb])
    out = eng.run()
    assert eng.status(ua) is RequestStatus.OK
    assert eng.status(ub) is RequestStatus.OK
    assert eng.stats()["preemptions"] >= 1
    assert any(ev["kind"] == "page_squeeze" for ev in fh.log)
    np.testing.assert_array_equal(out[ua], _solo(model, pa, sampler, uid=ua))
    np.testing.assert_array_equal(out[ub], _solo(model, pb, sampler, uid=ub))


def _solo(model, prompt, sampler=None, bits=0, max_new=6, uid=0):
    """Uninterrupted solo run of ``prompt`` under request id ``uid``.

    The sampler stream keys on ``(seed, uid, position)``, so matching a
    multi-request engine's stream requires the same uid — earlier ids
    are burned on throwaway one-token requests."""
    eng = _mk(model, bits=bits, slots=1, sampler=sampler)
    for _ in range(uid):
        eng.submit(np.array([1], np.int32), max_new=1)
    u = eng.submit(prompt, max_new=max_new)
    assert u == uid
    return eng.run()[u]


# ---------------------------------------------------------------------------
# numeric sentinels: NaN quarantine + overflow runaway
# ---------------------------------------------------------------------------

def test_logit_nan_quarantines_victim_only(model, prompts):
    """A NaN injected into one slot's decode logits (device-side, through
    the real sentinel) quarantines that request FAILED with exactly the
    clean tokens it streamed before the fault; sibling streams are
    byte-identical to a fault-free run."""
    pa, pb, pc = prompts
    clean = _mk(model)
    cu = _submit_all(clean, [pa, pb])
    cout = clean.run()

    fh = FaultHarness([LogitNaN(uid=1, token_idx=2)])
    eng = _mk(model, faults=fh)
    ua, ub = _submit_all(eng, [pa, pb])
    out = eng.run()
    assert ub == 1
    assert eng.status(ub) is RequestStatus.FAILED
    assert out[ub].size == 2                   # tokens 0,1 clean, 2 dropped
    np.testing.assert_array_equal(out[ub], cout[cu[1]][:2])
    assert eng.status(ua) is RequestStatus.OK
    np.testing.assert_array_equal(out[ua], cout[cu[0]])  # sibling untouched
    st = eng.stats()
    assert st["requests_failed"] == 1
    assert any(ev["kind"] == "logit_nan" for ev in fh.log)
    assert eng.metrics.traces[ub].status == "failed"


def test_overflow_runaway_quarantines(model, prompts):
    """The §5 runaway sentinel wires through: with an impossible
    threshold every packed-pool request trips it on its first decode
    step and quarantines FAILED (one clean prefill token harvested)."""
    _, _, pc = prompts
    eng = _mk(model, bits=8, slots=1, runaway_ovf=-1.0)
    uid = eng.submit(pc, max_new=6)
    out = eng.run()
    assert eng.status(uid) is RequestStatus.FAILED
    assert out[uid].size == 1
    assert eng.stats()["requests_failed"] == 1


# ---------------------------------------------------------------------------
# KV storage corruption: engine must drain, siblings must be untouched
# ---------------------------------------------------------------------------

def test_kv_bitflip_drains_and_spares_siblings(model, prompts):
    """Flipping a mantissa bit in one request's PRIVATE page corrupts at
    most that request's own stream: the engine still drains with
    terminal statuses, and the sibling's tokens are byte-identical to a
    fault-free run (refcounted pages isolate the blast radius)."""
    pa, pb, _ = prompts
    clean = _mk(model, bits=8)
    cu = _submit_all(clean, [pa, pb])
    cout = clean.run()

    fh = FaultHarness([KVBitFlip(step=6, uid=1, bit=6)])
    eng = _mk(model, bits=8, faults=fh)
    ua, ub = _submit_all(eng, [pa, pb])
    out = eng.run()
    # the corrupted request may still decode to completion (just with a
    # perturbed stream) or trip a sentinel — either way it's terminal
    assert eng.status(ub) in (RequestStatus.OK, RequestStatus.FAILED)
    assert eng.status(ua) is RequestStatus.OK
    np.testing.assert_array_equal(out[ua], cout[cu[0]])  # sibling exact
    kinds = {ev["kind"] for ev in fh.log}
    assert "bit_flip" in kinds or "bit_flip_skipped" in kinds


# ---------------------------------------------------------------------------
# admission control: queue cap, deadlines, delayed admission
# ---------------------------------------------------------------------------

def test_queue_cap_rejects_overflow_submit(model, prompts):
    pa, pb, pc = prompts
    eng = _mk(model, slots=1, queue_cap=2)
    ua = eng.submit(pa, max_new=4)
    ub = eng.submit(pb, max_new=4)
    uc = eng.submit(pc, max_new=4)            # queue full → rejected
    assert eng.status(uc) is RequestStatus.REJECTED
    out = eng.run()
    assert out[uc].size == 0
    assert eng.status(ua) is RequestStatus.OK
    assert eng.status(ub) is RequestStatus.OK
    st = eng.stats()
    assert st["requests_rejected"] == 1
    assert st["queue_depth_peak"] == 2
    assert eng.metrics.traces[uc].status == "rejected"


def test_queued_deadline_times_out(model, prompts):
    _, _, pc = prompts
    eng = _mk(model, slots=1)
    uid = eng.submit(pc, max_new=4, deadline_ms=0.0)   # expires instantly
    out = eng.run()
    assert eng.status(uid) is RequestStatus.TIMED_OUT
    assert out[uid].size == 0
    assert eng.stats()["requests_timed_out"] == 1


def test_inflight_deadline_returns_partial(model, prompts):
    """A deadline that expires mid-decode resolves TIMED_OUT with the
    tokens already generated (not an exception, not an empty result)."""
    _, _, pc = prompts
    eng = _mk(model, slots=1)
    uid = eng.submit(pc, max_new=8)
    # admit + stream a couple of tokens, then force the deadline into
    # the past — deterministic, no wall-clock race
    for _ in range(4):
        eng.step()
    eng._reqs[0].deadline = M._now() - 1.0
    out = eng.run()
    assert eng.status(uid) is RequestStatus.TIMED_OUT
    assert out[uid].size >= 1
    assert out[uid].size < 8


def test_admit_delay_streams_identical(model, prompts):
    """Holding a request in the queue changes scheduling, never tokens."""
    pa, _, pc = prompts
    clean = _mk(model)
    cu = _submit_all(clean, [pa, pc], max_new=4)
    cout = clean.run()
    fh = FaultHarness([AdmitDelay(uid=1, until_step=6)])
    eng = _mk(model, faults=fh)
    ua, uc = _submit_all(eng, [pa, pc], max_new=4)
    out = eng.run()
    assert eng.status(ua) is RequestStatus.OK
    assert eng.status(uc) is RequestStatus.OK
    np.testing.assert_array_equal(out[ua], cout[cu[0]])
    np.testing.assert_array_equal(out[uc], cout[cu[1]])
    assert any(ev["kind"] == "admit_released" for ev in fh.log)


# ---------------------------------------------------------------------------
# drain timeout: partial results, never an exception
# ---------------------------------------------------------------------------

def test_drain_timeout_returns_partial_results(model, prompts):
    pa, _, pc = prompts
    eng = _mk(model, slots=1)
    ua = eng.submit(pa, max_new=8)
    uc = eng.submit(pc, max_new=8)
    out = eng.run(max_steps=6)                 # not enough to finish both
    assert set(out) == {ua, uc}
    assert eng.status(ua) is not None and eng.status(uc) is not None
    assert RequestStatus.TIMED_OUT in (eng.status(ua), eng.status(uc))
    # the engine is clean afterwards: a new wave runs to completion
    ud = eng.submit(pc, max_new=4)
    out2 = eng.run()
    assert eng.status(ud) is RequestStatus.OK
    assert out2[ud].size == 4


# ---------------------------------------------------------------------------
# seeded chaos sweep: everything terminal, log serializable
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7])
def test_chaos_sweep_always_drains(model, prompts, seed):
    """A randomized (but seeded) fault mix — NaNs, bit flips, admission
    delays, a page squeeze — over an int8 paged engine with a tight
    arena: run() drains, every request ends terminal, and the fault log
    round-trips through JSON (the CI artifact contract)."""
    pa, pb, pc = prompts
    faults = chaos_plan(seed, [0, 1, 2], n_steps=24, squeeze_pages=4)
    fh = FaultHarness(faults, seed=seed)
    eng = _mk(model, bits=8, slots=2, n_pages=9, faults=fh)
    uids = _submit_all(eng, [pa, pb, pc], max_new=5)
    out = eng.run()
    for u in uids:
        assert eng.status(u) is not None, f"uid {u} has no terminal status"
        assert u in out
    assert not eng._queue and not eng._active.any()
    assert all(r is None for r in eng._reqs)
    blob = json.dumps(fh.summary())            # must be JSON-serializable
    assert json.loads(blob)["seed"] == seed
    st = eng.stats()
    assert st["requests_submitted"] == 3
