"""Dynamic fixed point scale state + the paper's overflow-rate controller (§5).

Each tensor *group* (per layer: weights, weighted sums, outputs, and their
gradients; plus embeddings/head/params) owns one power-of-two scaling factor,
stored as a float32 log2-step ``e`` (integer-valued). Groups belonging to a
scanned layer stack are stored as ``[L]`` vectors so a single ``lax.scan``
threads them.

Controller rule (paper §5, verbatim semantics):
  * accumulate ``(n_overflow, n_overflow_half, n_total)`` per group;
  * every ``update_interval`` steps (the paper used every 10k examples):
      - if ``overflow_rate > max_overflow_rate``        → scale ×2 (``e+1``)
      - elif ``overflow_rate_at_half <= max_overflow``  → scale ÷2 (``e-1``)
  * reset accumulators.

The two branches are mutually exclusive by construction (rate_half ≥ rate),
so the update is a single branch-free ``jnp.where`` — SPMD-safe and
identical on every replica because stats are global sums.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

Array = jax.Array

E_MIN, E_MAX = -40.0, 40.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScaleState:
    """Per-group log2 steps + accumulated overflow statistics."""

    exps: Dict[str, Array]   # group -> f32 (integer-valued), shape [] or [L]
    acc: Dict[str, Array]    # group -> f32 stats, shape exps.shape + (3,)

    @staticmethod
    def create(group_shapes: Dict[str, tuple], init_exp=-8.0) -> "ScaleState":
        """``group_shapes``: group -> () or (L,). ``init_exp``: scalar, or a
        per-group dict of scalars/arrays (e.g. from calibration)."""
        exps, acc = {}, {}
        for name, shape in group_shapes.items():
            e0 = init_exp[name] if isinstance(init_exp, dict) else init_exp
            e0 = jnp.asarray(e0, jnp.float32)
            exps[name] = jnp.broadcast_to(e0, shape).astype(jnp.float32)
            acc[name] = jnp.zeros(shape + (3,), jnp.float32)
        return ScaleState(exps=exps, acc=acc)


def accumulate(state: ScaleState, stats: Dict[str, Array]) -> ScaleState:
    """Add this step's statistics. Missing groups are left untouched."""
    acc = dict(state.acc)
    for name, s in stats.items():
        if name in acc:
            acc[name] = acc[name] + s.astype(jnp.float32)
    return ScaleState(exps=state.exps, acc=acc)


def controller_step(
    state: ScaleState,
    *,
    max_overflow_rate: float,
    apply: Array,
) -> ScaleState:
    """Apply the paper's rule where ``apply`` is true; reset acc.

    ``apply`` is a bool scalar (the training cadence) or an array
    broadcastable to each group's exponent shape (e.g. per-slot ``[B]``
    for the serve-time KV-cache groups, where every slot runs its own
    append counter).
    """
    apply = jnp.asarray(apply)
    # acc carries a trailing stats axis the exponents don't have
    apply_acc = apply if apply.ndim == 0 else apply[..., None]
    new_exps, new_acc = {}, {}
    for name, e in state.exps.items():
        a = state.acc[name]
        total = jnp.maximum(a[..., 2], 1.0)
        rate = a[..., 0] / total
        rate_half = a[..., 1] / total
        up = rate > max_overflow_rate
        down = jnp.logical_and(jnp.logical_not(up),
                               rate_half <= max_overflow_rate)
        delta = up.astype(jnp.float32) - down.astype(jnp.float32)
        # Groups that saw no data keep their scale.
        delta = jnp.where(a[..., 2] > 0, delta, 0.0)
        e_new = jnp.clip(e + delta, E_MIN, E_MAX)
        new_exps[name] = jnp.where(apply, e_new, e)
        new_acc[name] = jnp.where(apply_acc, jnp.zeros_like(a), a)
    return ScaleState(exps=new_exps, acc=new_acc)


def calibrate_exp(maxabs: Array, width: int, margin_bits: int = 1) -> Array:
    """log2-step so that ``maxabs`` fits with ``margin_bits`` of headroom.

    The paper finds initial scales "by training with a higher precision
    format"; this helper converts observed group max-magnitudes into initial
    exponents (``calibrate`` mode).
    """
    qmax = float(2 ** (width - 1) - 1)
    need = jnp.ceil(jnp.log2(jnp.maximum(maxabs, 1e-20) / qmax))
    return jnp.clip(need + margin_bits, E_MIN, E_MAX).astype(jnp.float32)
