"""jit'd wrapper around the DFXP quantize kernel: any-shape in, padded tiles.

``dfxp_quantize(x, e, width)`` accepts any shape/f32-f16-bf16 dtype; it
reshapes to 2D, pads to tile multiples (pad values quantize to 0 and are
excluded from overflow counts by construction — 0 never overflows), runs
the Pallas kernel, and unpads.

``interpret=None`` auto-detects the backend (compiled on TPU, interpret
elsewhere — numerically identical, used by tests/benchmarks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import exact_pow2
from repro.kernels._tiling import quantize_blocks, resolve_interpret, round_up

from .dfxp_kernel import dfxp_quantize_2d


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def dfxp_quantize(x, e, *, width: int, interpret=None):
    """Fused quantize+stats. Returns (y, stats[2])."""
    interpret = resolve_interpret(interpret)
    orig_shape = x.shape
    n = x.size
    if x.ndim >= 2 and orig_shape[-1] % 128 == 0:
        # keep the natural lane dim when it's already aligned
        N = orig_shape[-1]
        M = n // N
        x2 = x.reshape(M, N)
        bm, bn = quantize_blocks(M, N)
        x2 = jnp.pad(x2, ((0, round_up(M, bm) - M), (0, round_up(N, bn) - N)))
    else:
        # flatten + pad (pads quantize to 0 and never overflow)
        N = 128 if n < 512 * 8 else 512
        M = -(-n // N)
        bm, bn = quantize_blocks(M, N)
        M = round_up(M, bm)
        x2 = jnp.pad(x.reshape(-1), (0, M * N - n)).reshape(M, N)

    step = exact_pow2(e)
    inv_step = exact_pow2(-jnp.asarray(e, jnp.float32))
    y, stats = dfxp_quantize_2d(x2, step, inv_step, width=width,
                                block_m=bm, block_n=bn, interpret=interpret)
    if x.ndim >= 2 and orig_shape[-1] % 128 == 0:
        return y[: n // N, :N].reshape(orig_shape), stats
    return y.reshape(-1)[:n].reshape(orig_shape), stats
