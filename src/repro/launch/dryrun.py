import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the real jit program (train_step for train shapes,
prefill/serve_step for inference shapes) with production in/out shardings,
``.lower().compile()``s it for the 16×16 single-pod (256 chips) and 2×16×16
two-pod (512 chips) meshes, and records:
  * per-device memory (argument/temp/output bytes — proves it fits),
  * per-device HLO FLOPs + bytes accessed (cost_analysis),
  * per-collective bytes parsed from the partitioned HLO,
into a JSON-lines results file that §Roofline reads.

Usage:
  python -m repro.launch.dryrun --arch llama3_8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--out results.jsonl]   # subprocess/cell
"""
import argparse
import dataclasses
import gzip
import json
import os as _os
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.shapes import SHAPES, input_specs
from repro.core.policy import PrecisionPolicy
from repro.dist.context import multi_pod_ctx, single_pod_ctx
from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.optim.opt import OptConfig, sgd_init
from repro.train import init_train_state, make_train_step

# Per-arch dry-run settings: paper-faithful DFXP (10/12) everywhere;
# float16 containers hold the DFXP grid exactly (≤12 bits) at half the HBM
# of f32 — used where f32 activations/storage cannot fit; llama4's 400B
# params additionally need packed int16 storage (DESIGN.md §2).
ARCH_SETTINGS = {
    "zamba2_1p2b": dict(compute="float32", storage="sim", microbatches=8),
    "llama3_8b": dict(compute="float32", storage="sim", microbatches=8),
    "qwen3_14b": dict(compute="float32", storage="sim", microbatches=8),
    "phi3_medium_14b": dict(compute="float32", storage="sim", microbatches=8),
    "gemma3_27b": dict(compute="float16", storage="sim", microbatches=16),
    "seamless_m4t_medium": dict(compute="float32", storage="sim",
                                microbatches=8),
    "llama4_maverick_400b": dict(compute="float16", storage="packed",
                                 microbatches=16),
    "granite_moe_1b": dict(compute="float32", storage="sim", microbatches=8),
    "mamba2_370m": dict(compute="float32", storage="sim", microbatches=8),
    "qwen2_vl_72b": dict(compute="float16", storage="sim", microbatches=16),
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16,
                "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3fnuz": 1,
                "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "f8e3m4": 1}


OVERRIDES: dict = {}


def policy_for(arch: str) -> PrecisionPolicy:
    s = ARCH_SETTINGS[arch]
    return PrecisionPolicy("dfxp", comp_width=10, update_width=12,
                           update_interval=100, storage=s["storage"],
                           compute_dtype=OVERRIDES.get("compute",
                                                       s["compute"]),
                           a2a_compress_bits=OVERRIDES.get("a2a_bits", 0))


_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes per collective kind from partitioned HLO.

    Handles both forms:
      %all-gather.3 = bf16[8,5120,8192]{1,0} all-gather(%p) ...
      %all-to-all.12 = (s8[2,8,1024]{2,1,0}, s8[...], ...) all-to-all(...)
    (multi-operand collectives — e.g. the int8 lanes of
    ``compressed_all_to_all`` — lower to the tuple form; every element
    counts toward the wire bytes).
    """
    out = {k: 0.0 for k in COLLECTIVES}
    count = {k: 0 for k in COLLECTIVES}
    pat = re.compile(r"= (\([^)]*\)|\S+) ("
                     + "|".join(COLLECTIVES) + r")\(")
    for m in pat.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        size = 0.0
        for dt, dims in _SHAPE.findall(shapes):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            size += n * _DTYPE_BYTES.get(dt, 4)
        out[kind] += size
        count[kind] += 1
    return {"bytes": out, "count": count,
            "total_bytes": sum(out.values())}


def _loss_builder(cfg, policy, dist, remat, ce_chunk=512):  # noqa: D103
    def loss_fn(p, b, s, exps):
        return T.loss_fn(cfg, policy, p, b, exps, s, dist=dist, remat=remat,
                         ce_chunk=ce_chunk)
    return loss_fn


def build_cell(arch: str, shape_name: str, multi_pod: bool):
    """Returns (jitted, example_args) ready to .lower(*args)."""
    cfg = configs.get(arch)
    if OVERRIDES.get("ssm_chunk"):
        cfg = dataclasses.replace(cfg, ssm_chunk=OVERRIDES["ssm_chunk"])
    shape = SHAPES[shape_name]
    policy = policy_for(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = multi_pod_ctx() if multi_pod else single_pod_ctx()
    if OVERRIDES.get("attn_seq_shard"):
        dist = dataclasses.replace(dist, attn_seq_shard=True)
    if OVERRIDES.get("moe_stationary"):
        dist = dataclasses.replace(dist, moe_stationary=True)
    gs = T.group_shapes(cfg)
    cdtype = jnp.dtype(policy.compute_dtype)
    specs = input_specs(cfg, shape)

    long_ctx = shape_name == "long_500k"
    if long_ctx:
        # KV window is sharded (seq_shard_cache below): decode attention
        # must run the context-parallel exact-merge path over it.
        dist = dataclasses.replace(dist, cp_decode=True)
    rules = ShardingRules(mesh, multi_pod=multi_pod,
                          shard_batch=not long_ctx,
                          seq_shard_cache=long_ctx)

    if shape.kind == "train":
        mb = OVERRIDES.get("microbatches", ARCH_SETTINGS[arch]["microbatches"])
        if multi_pod:
            mb = min(mb, shape.global_batch // (2 * 16))
        opt_cfg = OptConfig(kind="sgd", lr=0.01, lr_decay_steps=100_000)
        loss_fn = _loss_builder(cfg, policy, dist,
                                remat=OVERRIDES.get("remat", "full"),
                                ce_chunk=OVERRIDES.get("ce_chunk", 512))
        step = make_train_step(loss_fn, gs, policy, opt_cfg,
                               microbatches=mb, compute_dtype=cdtype)

        def make_state():
            params = T.init_params(cfg, jax.random.PRNGKey(0))
            return init_train_state(params, sgd_init(params), gs, policy,
                                    init_exp=-8.0)

        state_shape = jax.eval_shape(make_state)
        state_sh = rules.state_shardings(state_shape)
        batch_sh = rules.batch_shardings(specs["batch"])
        rng_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
        jitted = jax.jit(step,
                         in_shardings=(state_sh, batch_sh, None),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        return jitted, (state_shape, specs["batch"], rng_s)

    # inference cells: params + scales only (no optimizer state)
    params_shape = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    params_sh = rules.params_shardings(params_shape)
    exps_shape = jax.eval_shape(
        lambda: {n: jnp.zeros(s, jnp.float32) for n, s in gs.items()})

    if shape.kind == "prefill":
        def prefill_step(params, batch, exps):
            sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                     for n, s in gs.items() if n.startswith("g:")}
            logits, _, cache = T.forward(
                cfg, policy, params, batch, exps, sinks, dist,
                mode="prefill", max_cache_len=shape.seq_len)
            return logits[:, -1, :], cache

        batch_sh = rules.batch_shardings(specs["batch"])
        cache_shape = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len,
                                 src_len=(shape.seq_len if cfg.encoder_layers
                                          else 0), dtype=cdtype))
        cache_sh = rules.cache_shardings(cache_shape)
        logits_sh = jax.NamedSharding(
            mesh, jax.sharding.PartitionSpec(rules.dp, "model"))
        jitted = jax.jit(prefill_step,
                         in_shardings=(params_sh, batch_sh, None),
                         out_shardings=(logits_sh, cache_sh))
        return jitted, (params_shape, specs["batch"], exps_shape)

    # decode
    def serve_step(params, cache, tok, pos, exps):
        sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                 for n, s in gs.items() if n.startswith("g:")}
        logits, _, cache2 = T.decode_step(cfg, policy, params, cache, tok,
                                          pos, exps, sinks, dist)
        return logits, cache2

    src_len = shape.seq_len if cfg.encoder_layers else 0
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len,
                             src_len=src_len, dtype=cdtype))
    cache_sh = rules.cache_shardings(cache_shape)
    tok_spec = specs["tokens"]
    tok_sh = (jax.NamedSharding(mesh, jax.sharding.PartitionSpec(rules.dp))
              if rules.shard_batch else None)
    if cfg.input_mode == "embeds" and rules.shard_batch:
        tok_sh = jax.NamedSharding(
            mesh, jax.sharding.PartitionSpec(rules.dp, None, None))
    logits_sh = jax.NamedSharding(
        mesh, jax.sharding.PartitionSpec(
            rules.dp if rules.shard_batch else None, "model"))
    jitted = jax.jit(serve_step,
                     in_shardings=(params_sh, cache_sh, tok_sh, None, None),
                     out_shardings=(logits_sh, cache_sh),
                     donate_argnums=(1,))
    return jitted, (params_shape, cache_shape, tok_spec, specs["pos"],
                    exps_shape)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hlo_dir: str = "hlo") -> dict:
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with jax.set_mesh(mesh):
        jitted, args = build_cell(arch, shape_name, multi_pod)
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax 0.4.x returns [dict], newer: dict
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    if hlo_dir:
        _os.makedirs(hlo_dir, exist_ok=True)
        fname = f"{hlo_dir}/{arch}_{shape_name}_{rec['mesh']}.hlo.gz"
        with gzip.open(fname, "wt") as f:
            f.write(txt)
        rec["hlo"] = fname
    # loop-aware cost model (cost_analysis counts while bodies once;
    # benchmarks/hlo_cost multiplies by known_trip_count)
    try:
        from benchmarks.hlo_cost import analyze_text
        rec["loop_aware"] = analyze_text(txt)
    except Exception as e:  # keep the record even if the parser trips
        rec["loop_aware_error"] = str(e)[:200]
    rec.update({
        "ok": True,
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
        "per_device": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "flops": ca.get("flops", 0.0),
        "bytes_accessed": ca.get("bytes accessed", 0.0),
        "transcendentals": ca.get("transcendentals", 0.0),
        "collectives": collective_bytes(txt),
    })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    # perf-iteration overrides (recorded via --tag)
    ap.add_argument("--tag", default="")
    ap.add_argument("--compute", default="")
    ap.add_argument("--remat", default="")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--a2a-bits", type=int, default=0)
    ap.add_argument("--ce-chunk", type=int, default=0)
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--attn-seq-shard", action="store_true")
    ap.add_argument("--moe-stationary", action="store_true")
    args = ap.parse_args()
    if args.ssm_chunk:
        OVERRIDES["ssm_chunk"] = args.ssm_chunk
    if args.attn_seq_shard:
        OVERRIDES["attn_seq_shard"] = True
    if args.moe_stationary:
        OVERRIDES["moe_stationary"] = True
    if args.compute:
        OVERRIDES["compute"] = args.compute
    if args.remat:
        OVERRIDES["remat"] = args.remat
    if args.microbatches:
        OVERRIDES["microbatches"] = args.microbatches
    if args.a2a_bits:
        OVERRIDES["a2a_bits"] = args.a2a_bits
    if args.ce_chunk:
        OVERRIDES["ce_chunk"] = args.ce_chunk

    if args.all:
        cells = [(a, s, mp) for a in configs.ARCHS
                 for s in configs.cells(a) for mp in (False, True)]
        done = set()
        try:
            with open(args.out) as f:
                for line in f:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
        except FileNotFoundError:
            pass
        for a, s, mp in cells:
            mesh_name = "2x16x16" if mp else "16x16"
            if (a, s, mesh_name) in done:
                print(f"skip (done): {a} {s} {mesh_name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--out", args.out]
            if mp:
                cmd.append("--multi-pod")
            print(f"=== {a} {s} {mesh_name}", flush=True)
            r = subprocess.run(cmd)
            if r.returncode != 0:
                with open(args.out, "a") as f:
                    f.write(json.dumps({"arch": a, "shape": s,
                                        "mesh": mesh_name, "ok": False}) + "\n")
        return

    rec = run_cell(args.arch, args.shape, args.multi_pod)
    if args.tag:
        rec["tag"] = args.tag
        rec["overrides"] = dict(OVERRIDES)
    line = json.dumps(rec)
    print(line)
    with open(args.out, "a") as f:
        f.write(line + "\n")


if __name__ == "__main__":
    main()
