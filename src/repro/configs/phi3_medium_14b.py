"""phi3-medium-14b [dense]: RoPE SwiGLU GQA kv=10. [arXiv:2404.14219]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=10, head_dim=128, d_ff=17920,
    vocab_size=100352, rope_theta=1e4, tie_embeddings=False)

SMOKE = ModelConfig(
    name="phi3-smoke", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    tie_embeddings=False)

CELLS = ("train_4k", "prefill_32k", "decode_32k")
