"""Distributed execution: mesh context, sharding rules, low-bit collectives.

The subsystem has four pieces, mirroring the distributed hot paths the
paper's low-precision formats must flow through:

  * :mod:`repro.dist.context`   — ``DistCtx``, the mesh-axis contract every
    model/launch function threads (which axes hold tokens, experts, FSDP
    shards, the context-parallel KV window);
  * :mod:`repro.dist.sharding`  — ``ShardingRules``, logical-name →
    ``PartitionSpec`` resolution for params, optimizer state, batches and
    decode caches;
  * :mod:`repro.dist.compress`  — DFXP gradient/activation compression with
    error feedback for the all-reduce and MoE all-to-all wires;
  * :mod:`repro.dist.cp_attention` — context-parallel GQA decode attention
    (KV window sharded, softmax statistics combined exactly).
"""
from repro import _jax_compat

_jax_compat.install()

from .context import (  # noqa: E402,F401
    DistCtx,
    MeshConfigError,
    multi_pod_ctx,
    serve_pod_ctx,
    single_pod_ctx,
)
from .sharding import ShardingRules  # noqa: E402,F401
