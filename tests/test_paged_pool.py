"""Paged KV pool: codec round-trip, copy-on-write isolation, allocator
refcounts, block-table kernels vs jitted refs, paged-vs-slot engine parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.kernels.attn import ref as AR
from repro.kernels.attn.ops import flash_decode_paged, flash_prefill_paged
from repro.models import transformer as T
from repro.serve import (CacheQuantConfig, EngineOptions, RequestStatus,
                         ServeEngine, kv_pool, paged)

SCALE = 0.3


# ---------------------------------------------------------------------------
# codec-level helpers (one layer, no model)
# ---------------------------------------------------------------------------

def _entry(width, key, *, P=4, nblocks=3, B=2, K=2, hd=4, n_pages=None,
           fill_blocks=None, n_valid_last=None):
    """Paged entry filled through ``append_chunk`` (chunk size == P).

    Slot ``b`` maps pages ``1 + b*nblocks ..`` for its first
    ``fill_blocks`` blocks; returns ``(entry, k_vals, v_vals, codec)``
    with the f32 values that were quantized in.
    """
    qcfg = None if width is None else CacheQuantConfig(width=width)
    codec = paged.PagedKVCodec(P, qcfg)
    W = nblocks * P
    n_pages = n_pages or 1 + B * nblocks
    fill_blocks = nblocks if fill_blocks is None else fill_blocks
    raw = {"k": jnp.zeros((1, B, W, K, hd), jnp.float32),
           "v": jnp.zeros((1, B, W, K, hd), jnp.float32),
           "pos": jnp.full((1, B, W), -1, jnp.int32)}
    e = jax.tree_util.tree_map(lambda a: a[0], codec.init_like(raw, n_pages))
    bt = np.zeros((B, nblocks), np.int32)
    for b in range(B):
        bt[b, :fill_blocks] = 1 + b * nblocks + np.arange(fill_blocks)
    e["bt"] = jnp.asarray(bt)
    kk, kv = jax.random.split(key)
    k_vals = jax.random.normal(kk, (B, W, K, hd), jnp.float32) * 0.5
    v_vals = jax.random.normal(kv, (B, W, K, hd), jnp.float32) * 0.5
    for c in range(fill_blocks):
        nv = P if (n_valid_last is None or c < fill_blocks - 1) \
            else n_valid_last
        e = codec.append_chunk(e, k_vals[:, c * P:(c + 1) * P],
                               v_vals[:, c * P:(c + 1) * P],
                               jnp.full((B,), c * P, jnp.int32),
                               jnp.full((B,), nv, jnp.int32))
    return e, k_vals, v_vals, codec


def _wrap(e):
    """Entry → single-layer pool (the layer dim the pool ops expect)."""
    return {"blk": {"attn": jax.tree_util.tree_map(lambda a: a[None], e)}}


def _unwrap(pool):
    return jax.tree_util.tree_map(lambda a: a[0], pool["blk"]["attn"])


# ---------------------------------------------------------------------------
# page-granular pack/append round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [8, 16])
def test_paged_append_roundtrip(width):
    """Chunk appends quantize against per-PAGE exponents; dequantized
    values come back within half a step, ragged tail rows stay empty."""
    P, nblocks = 4, 3
    e, k_vals, _, codec = _entry(width, jax.random.PRNGKey(0), P=P,
                                 nblocks=nblocks, n_valid_last=2)
    k, v, pos = codec.load(e)
    pos = np.asarray(pos)
    valid = pos >= 0
    assert valid.sum(axis=1).tolist() == [(nblocks - 1) * P + 2] * 2
    # logical row r lives on page bt[b, r//P]: its step is that page's
    ke = np.asarray(jnp.take(e["k_e"], e["bt"], axis=0))   # [B, nblocks]
    step = np.repeat(2.0 ** ke, P, axis=1)[..., None, None]
    err = np.abs(np.asarray(k) - np.asarray(k_vals)) * valid[..., None, None]
    assert np.all(err <= step / 2 + 1e-7)
    # every kept row's K and V landed in the per-page §5 counters
    tot = float(jnp.sum(e["tot_k"][..., 2]) + jnp.sum(e["tot_v"][..., 2]))
    assert tot > 0
    assert float(jnp.sum(e["tot_k"][..., 0])) <= float(
        jnp.sum(e["tot_k"][..., 2]))
    # the null page is never written
    assert not np.any(np.asarray(e["k_m"][0]))


def test_paged_f32_roundtrip_exact():
    e, k_vals, v_vals, codec = _entry(None, jax.random.PRNGKey(1))
    k, v, _ = codec.load(e)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(k_vals))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_vals))


# ---------------------------------------------------------------------------
# copy-on-write isolation
# ---------------------------------------------------------------------------

def test_cow_fork_leaves_sharer_bytes_untouched():
    """Slot 1 shares slot 0's pages mid-page, forks the tail page, and
    writes on: the shared page's mantissas/exponent/stats stay
    bit-identical and slot 0 reads exactly what it read before."""
    P, nblocks = 4, 3
    e, _, _, codec = _entry(8, jax.random.PRNGKey(2), P=P, nblocks=nblocks,
                            n_pages=12, fill_blocks=2)
    k0_before = np.asarray(codec.load(e)[0][0])      # slot 0's view
    page2 = {f: np.asarray(e[f][2]) for f in
             ("k_m", "v_m", "acc_k", "acc_v", "tot_k", "tot_v")}
    e2_before = (float(e["k_e"][2]), float(e["v_e"][2]))

    pool = _wrap(e)
    # slot 1 shares rows 0..5: page 1 whole, page 2 rows 4,5 (mid-page)
    pool = paged.reset_slot(pool, 1, 6, jnp.asarray([1, 2, 0], jnp.int32),
                            6.0)
    # fork the shared tail page before writing row 6, map a fresh block 2
    pool = paged.cow_page(pool, 2, 8)
    pool = paged.set_block(pool, 1, 1, 8)
    pool = paged.set_block(pool, 1, 2, 9)
    e = _unwrap(pool)
    kn = jax.random.normal(jax.random.PRNGKey(3), (2, P, 2, 4)) * 0.5
    e = codec.append_chunk(e, kn, kn, jnp.asarray([0, 6], jnp.int32),
                           jnp.asarray([0, P], jnp.int32))

    for f, before in page2.items():                  # sharer's bytes
        np.testing.assert_array_equal(np.asarray(e[f][2]), before)
    assert (float(e["k_e"][2]), float(e["v_e"][2])) == e2_before
    np.testing.assert_array_equal(np.asarray(codec.load(e)[0][0]), k0_before)
    # the fork carried the shared rows and took the new ones
    np.testing.assert_array_equal(np.asarray(e["k_m"][8][:2]),
                                  page2["k_m"][:2])
    assert not np.array_equal(np.asarray(e["k_m"][8][2:]), page2["k_m"][2:])
    # continuation rule: the forked mid-page kept the donor's exponent
    assert float(e["k_e"][8]) == e2_before[0]


# ---------------------------------------------------------------------------
# metrics walk the block table (shared page counts ONCE)
# ---------------------------------------------------------------------------

def test_overflow_summary_counts_shared_page_once():
    e, _, _, _ = _entry(8, jax.random.PRNGKey(4), fill_blocks=2)
    # slot 1 drops its own pages and maps slot 0's two written pages
    e["bt"] = jnp.asarray([[1, 2, 0], [1, 2, 0]], jnp.int32)
    pool = _wrap(e)
    per_page = np.asarray(e["tot_k"][..., 2]) + np.asarray(e["tot_v"][..., 2])
    expect = float(per_page[1] + per_page[2])        # pages 1,2 once each
    got = kv_pool.overflow_summary(pool, np.array([True, True]))
    assert got["cache_appends_quantized"] == pytest.approx(expect)
    # per-REQUEST totals still see the shared pages for each mapper
    t0 = np.asarray(kv_pool.slot_totals(pool, 0))
    t1 = np.asarray(kv_pool.slot_totals(pool, 1))
    np.testing.assert_allclose(t0, t1)
    assert t0[2] == pytest.approx(expect)
    # inactive slots drop out of the summary
    got0 = kv_pool.overflow_summary(pool, np.array([True, False]))
    assert got0["cache_appends_quantized"] == pytest.approx(expect)
    gotn = kv_pool.overflow_summary(pool, np.array([False, False]))
    assert gotn["cache_appends_quantized"] == 0.0


def test_overflow_summary_paged_f32_is_zero():
    e, _, _, _ = _entry(None, jax.random.PRNGKey(5))
    got = kv_pool.overflow_summary(_wrap(e), np.array([True, True]))
    assert got["cache_appends_quantized"] == 0.0


# ---------------------------------------------------------------------------
# allocator: refcounts, prefix index, eviction, churn
# ---------------------------------------------------------------------------

def test_allocator_refcount_free_reuse_churn():
    P, nblocks = 4, 4
    al = paged.PageAllocator(n_pages=10, page_size=P, nblocks=nblocks)
    toks = np.arange(8, dtype=np.int32)

    al.new_slot(0, [])
    first = []
    for b in range(2):
        kind, _, pg = al.ensure_block(0, b)
        assert kind == "alloc"
        first.append(pg)
    al.register_prefix(0, toks)                      # pins both pages
    al.free_slot(0)
    assert al.stats()["pages_registered"] == 2
    assert al.stats()["pages_in_use"] == 2           # pinned, not leaked

    # identical prompt: both pages hit; the L-1 cap forces a tail COW
    pages, shared = al.match_prefix(toks)
    assert pages == first and shared == 7
    al.new_slot(1, pages)
    act = al.ensure_block(1, 1)                      # writes row 7
    assert act is not None and act[0] == "cow" and act[1] == first[1]
    fork = act[2]
    assert fork not in first
    assert al.ensure_block(1, 1) is None             # now privately owned
    al.free_slot(1)
    assert al.stats()["page_cache_hits"] == 2
    assert al.stats()["page_cow_forks"] == 1

    # churn distinct prompts through one slot until eviction recycles the
    # registered pages; the arena never exceeds its budget
    seen = set(first)
    for i in range(12):
        t = (100 * (i + 1) + np.arange(8)).astype(np.int32)
        pages, shared = al.match_prefix(t)
        assert pages == [] and shared == 0
        al.new_slot(0, pages)
        for b in range(2):
            _, _, pg = al.ensure_block(0, b)
            seen.add(pg)
        al.register_prefix(0, t)
        al.free_slot(0)
        st = al.stats()
        assert st["pages_in_use"] <= 9               # null page excluded
        assert st["pages_in_use_peak"] <= 9
    assert al.stats()["page_evictions"] > 0
    assert len(seen) <= 9                            # freed ids were reused


def test_allocator_exhaustion_raises():
    al = paged.PageAllocator(n_pages=3, page_size=4, nblocks=4)
    al.new_slot(0, [])
    al.ensure_block(0, 0)
    al.ensure_block(0, 1)
    with pytest.raises(RuntimeError, match="exhausted"):
        al.ensure_block(0, 2)


# ---------------------------------------------------------------------------
# fused kernels: bit-equality vs the jitted refs through the gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [None, 8, 16])
def test_flash_decode_paged_bitwise(width):
    B, K, G, hd, P, nblocks = 2, 2, 2, 4, 4, 3
    e, _, _, _ = _entry(width, jax.random.PRNGKey(6), P=P, nblocks=nblocks,
                        K=K, hd=hd, n_valid_last=3)
    q = jax.random.normal(jax.random.PRNGKey(7), (B, K, G, hd), jnp.float32)
    qpos = jnp.full((B,), (nblocks - 1) * P + 3, jnp.int32)
    ref = jax.jit(lambda *a: AR.paged_decode_attention_ref(
        *a, k_exp=e.get("k_e"), v_exp=e.get("v_e"), width=width,
        scale=SCALE, window=None, causal=True))(
            q, e["k_m"], e["v_m"], e["bt"], e["pos"], qpos)
    out = flash_decode_paged(q, e["k_m"], e["v_m"], e["bt"], e["pos"], qpos,
                             e.get("k_e"), e.get("v_e"), width=width,
                             scale=SCALE)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # the per-page split path (one grid step per block) stays close
    split = flash_decode_paged(q, e["k_m"], e["v_m"], e["bt"], e["pos"],
                               qpos, e.get("k_e"), e.get("v_e"), width=width,
                               scale=SCALE, force_split=True)
    np.testing.assert_allclose(np.asarray(split), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("width", [None, 8])
def test_flash_prefill_paged_bitwise(width):
    B, K, G, hd, P, nblocks, C = 2, 2, 2, 4, 4, 3, 4
    e, _, _, _ = _entry(width, jax.random.PRNGKey(8), P=P, nblocks=nblocks,
                        K=K, hd=hd, fill_blocks=1)
    kq, kn, vn = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(kq, (B, C, K, G, hd), jnp.float32)
    k_new = jax.random.normal(kn, (B, C, K, hd), jnp.float32) * 0.5
    v_new = jax.random.normal(vn, (B, C, K, hd), jnp.float32) * 0.5
    p0 = jnp.full((B,), P, jnp.int32)
    nv = jnp.asarray([C, 3], jnp.int32)              # ragged final chunk
    ref = jax.jit(lambda *a: AR.paged_prefill_attention_ref(
        *a, k_exp=e.get("k_e"), v_exp=e.get("v_e"), width=width,
        scale=SCALE, window=None, causal=True))(
            q, e["k_m"], e["v_m"], e["bt"], e["pos"], k_new, v_new, p0, nv)
    out = flash_prefill_paged(q, k_new, v_new, e["k_m"], e["v_m"], e["bt"],
                              e["pos"], p0, nv, e.get("k_e"), e.get("v_e"),
                              width=width, scale=SCALE)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    split = flash_prefill_paged(q, k_new, v_new, e["k_m"], e["v_m"],
                                e["bt"], e["pos"], p0, nv, e.get("k_e"),
                                e.get("v_e"), width=width, scale=SCALE,
                                force_split=True)
    np.testing.assert_allclose(np.asarray(split), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# engine parity + sharing (smoke model)
# ---------------------------------------------------------------------------

P_ENG = 8          # page size == prefill chunk: matched quantize-on-write
MAXLEN = 32        # multiple of P_ENG so paged Wp == slot-major W


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("llama3_8b")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prompts(model):
    cfg, _ = model
    shared = (np.arange(1, 17) % cfg.vocab_size).astype(np.int32)  # 2 pages
    pa = np.concatenate([shared, [17, 18, 19, 20]]).astype(np.int32)
    pb = np.concatenate([shared, [31, 32, 33, 34]]).astype(np.int32)
    return pa, pb


def _mk(model, *, bits=0, fused=False, page=True, slots=2, n_pages=None,
        cache_cfg=None):
    cfg, params = model
    pol = PrecisionPolicy("dfxp", fused_decode=fused, prefill_chunk=P_ENG,
                          page_size=P_ENG if page else 0)
    return ServeEngine(cfg, pol, params, max_slots=slots, max_len=MAXLEN,
                       options=EngineOptions(cache_bits=bits,
                                             cache_cfg=cache_cfg,
                                             n_pages=n_pages))


def _run(eng, prompts, max_new=6):
    uids = [eng.submit(p, max_new=max_new) for p in prompts]
    out = eng.run()
    return [out[u] for u in uids]


@pytest.mark.parametrize("bits,fused", [(0, False), (0, True), (8, False),
                                        (8, True), (16, False), (16, True)])
def test_paged_matches_slot_major_greedy(model, prompts, bits, fused):
    """Greedy token streams are identical paged-vs-slot-major for
    f32/int8/int16 pools, fused and unfused."""
    ref = _run(_mk(model, bits=bits, fused=fused, page=False), prompts)
    out = _run(_mk(model, bits=bits, fused=fused, page=True), prompts)
    for o, r in zip(out, ref):
        np.testing.assert_array_equal(o, r)


def test_prefix_sharing_saves_pages_and_matches_solo(model, prompts):
    pa, pb = prompts
    eng = _mk(model, bits=8, fused=True)
    out = _run(eng, [pa, pb])
    st = eng.stats()
    ea = _mk(model, bits=8, fused=True, slots=1)
    sa = _run(ea, [pa])
    eb = _mk(model, bits=8, fused=True, slots=1)
    sb = _run(eb, [pb])
    np.testing.assert_array_equal(out[0], sa[0])     # shared == solo tokens
    np.testing.assert_array_equal(out[1], sb[0])
    assert st["page_cache_hits"] >= 1
    solo_alloc = ea.stats()["pages_allocated"] + eb.stats()["pages_allocated"]
    n_shared = 16 // P_ENG                           # the 2 prefix pages
    assert solo_alloc - st["pages_allocated"] == n_shared
    solo_chunks = ea.stats()["prefill_chunks"] + eb.stats()["prefill_chunks"]
    assert st["prefill_chunks"] < solo_chunks
    assert st["cache_appends_quantized"] > 0         # §5 stats still flow


def test_identical_prompts_fork_on_write(model, prompts):
    """Two identical page-aligned prompts: the L-1 cap leaves one row
    inside the shared tail page, so the second request's first chunk
    forks it (copy-on-write); tokens still match exactly."""
    pa, _ = prompts
    pa = pa[:16]                  # exactly 2 pages → the cap lands mid-page
    eng = _mk(model, bits=8, fused=True)
    out = _run(eng, [pa, pa])
    np.testing.assert_array_equal(out[0], out[1])
    st = eng.stats()
    assert st["page_cache_hits"] >= 1
    assert st["page_cow_forks"] >= 1


def test_paged_stochastic_disables_sharing(model, prompts):
    """A shared page cannot replay two requests' PRNG chains: sharing is
    off under stochastic rounding, but paging itself still serves and a
    request still reproduces its solo tokens."""
    pa, pb = prompts
    ccfg = CacheQuantConfig(width=8, stochastic=True)
    eng = _mk(model, bits=8, fused=True, cache_cfg=ccfg)
    out = _run(eng, [pa, pb])
    st = eng.stats()
    assert st["page_cache_hits"] == 0
    assert st["pages_registered"] == 0
    solo = _mk(model, bits=8, fused=True, slots=1, cache_cfg=ccfg)
    np.testing.assert_array_equal(out[0], _run(solo, [pa])[0])


def test_engine_page_budget_exhaustion_fails_request(model, prompts):
    """A lone request that cannot fit in the arena resolves FAILED —
    there is no sibling to preempt — and ``run()`` never raises."""
    pa, _ = prompts
    eng = _mk(model, slots=1, n_pages=3)             # null + 2 usable pages
    uid = eng.submit(pa, max_new=6)                  # needs 4 blocks
    out = eng.run()
    assert eng.status(uid) is RequestStatus.FAILED
    assert out[uid].size == 0                        # died mid-prefill
    assert eng.stats()["requests_failed"] == 1


def test_engine_exhaustion_preempts_f32_bit_identical(model, prompts):
    """With a sibling present, exhaustion preempts the youngest request
    instead of failing anyone — and at f32 pool precision BOTH streams,
    the survivor's and the preempted-and-resumed one's, are bit-identical
    to their uninterrupted solo runs (the sampler keys on absolute
    position, so the resumed stream continues exactly where it left)."""
    pa, pb = prompts
    # two 20-token prompts + 6 new tokens each need 4 blocks apiece; the
    # 2 shared prefix pages bring peak demand to 6 usable pages, and the
    # stagger lets the finisher hand pages to the other — a 4-page arena
    # guarantees a mid-decode collision and at least one preemption
    eng = _mk(model, n_pages=5)
    ua = eng.submit(pa, max_new=6)
    ub = eng.submit(pb, max_new=6)
    out = eng.run()
    assert eng.status(ua) is RequestStatus.OK
    assert eng.status(ub) is RequestStatus.OK
    assert eng.stats()["preemptions"] >= 1
    sa = _run(_mk(model, slots=1), [pa])[0]
    sb = _run(_mk(model, slots=1), [pb])[0]
    np.testing.assert_array_equal(out[ua], sa)
    np.testing.assert_array_equal(out[ub], sb)


def test_engine_exhaustion_preempts_int8_accounting(model, prompts):
    """Same collision on the int8 packed pool: statuses stay OK, the
    never-preempted sibling is bit-identical to its solo run (its pages
    were never touched), and the overflow accounting survives the
    preempted request's release-and-reacquire of pages — the cumulative
    rate stays a valid average with no double count.  (The preempted
    stream itself may differ post-resume at int8: carry rows re-quantize
    through the chunk path, whose page exponents calibrate from chunk
    maxima rather than per-token maxima — the documented carve-out.)"""
    pa, pb = prompts
    eng = _mk(model, bits=8, fused=True, n_pages=5)
    ua = eng.submit(pa, max_new=6)
    ub = eng.submit(pb, max_new=6)
    out = eng.run()
    assert eng.status(ua) is RequestStatus.OK
    assert eng.status(ub) is RequestStatus.OK
    assert eng.stats()["preemptions"] >= 1
    # the requester (older, ua) is never the victim: its stream is solo
    sa = _run(_mk(model, bits=8, fused=True, slots=1), [pa])[0]
    np.testing.assert_array_equal(out[ua], sa)
    assert out[ub].size == 6                  # full budget, carry included
    # per-request totals harvested at finish stay consistent
    cs = eng.cache_stats()
    assert cs["cache_appends_quantized"] > 0
    assert 0.0 <= cs["cache_overflow_rate"] <= 1.0
    # live-pool summary over the drained engine counts shared pages once
    live = kv_pool.overflow_summary(eng._pool, np.zeros(2, bool))
    assert live["cache_appends_quantized"] == 0.0


def test_paged_rejects_non_dense(prompts):
    cfg = configs.get_smoke("granite_moe_1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    pol = PrecisionPolicy("dfxp", page_size=8)
    with pytest.raises(ValueError, match="dense"):
        ServeEngine(cfg, pol, params, max_slots=1, max_len=16)
