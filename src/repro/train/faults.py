"""Deterministic fault injection for the training loop (chaos testing).

The training-side twin of :mod:`repro.serve.faults`: the resilience
layer in :mod:`repro.train.resilience` — device-side sentinels, the skip
budget, rollback to the last committed checkpoint — is only worth
trusting if its failure paths actually run.  Each injector fires at the
real boundary the matching production fault would cross:

* :class:`GradNaN` poisons the gradients **inside the train jit** (the
  step's ``inj`` input adds ``where(flag, nan, 0)`` to every leaf after
  the microbatch scan), so the non-finite-gradient sentinel genuinely
  detects it on device.
* :class:`LossSpike` scales the loss *before* autodiff, so the spike
  propagates through the backward pass like a real blowup (a large
  enough factor overflows grads to inf; a NaN-producing 0*inf is the
  loss sentinel's job).
* :class:`CkptTear` attacks the checkpoint pipeline in one of three
  modes — ``writer`` kills the background save mid-write (via
  :meth:`CheckpointManager.inject_failure`, surfacing on the next
  ``wait()``), ``strip`` deletes the newest ``_COMMITTED`` marker
  (power-cut-shaped tear), ``corrupt`` flips a byte in a committed leaf
  file against its manifest CRC32.  Restore must fall back to the
  previous committed step in all three.
* :class:`ParamBitFlip` XORs a mantissa bit of one packed param leaf on
  the host between steps, modeling a storage upset in DFXP weight
  memory.  Skips (with a logged reason) when params are in f32 compute
  storage — there is no mantissa to flip.
* :class:`Kill` SIGKILLs the process at a step — the CI ``train-resume``
  smoke's crash; nothing in-process can observe it, which is the point.

:class:`FaultHarness` fires each fault exactly once (or for its
``count`` window), keeps a JSON-able event log, and mirrors every event
into the PR 8 tracer/metrics registry when attached.  Injectors no-op
with a logged reason when their precondition fails, so a chaos sweep
never crashes the harness itself.  :func:`chaos_plan` draws a
reproducible fault mix from a seed.
"""
from __future__ import annotations

import dataclasses
import os
import random
import signal
from typing import List

import jax.numpy as jnp
import numpy as np

from .step import benign_injection

__all__ = ["GradNaN", "LossSpike", "CkptTear", "ParamBitFlip", "Kill",
           "FaultHarness", "chaos_plan"]


@dataclasses.dataclass
class GradNaN:
    """Poison the gradient tree at data cursor ``step`` (device-side),
    for ``count`` consecutive attempts — ``count > skip_budget`` forces
    a rollback instead of a lone skip."""

    step: int
    count: int = 1
    fired: bool = False


@dataclasses.dataclass
class LossSpike:
    """Multiply the loss by ``factor`` at cursor ``step`` for ``count``
    attempts.  ``factor=float('inf')`` (or ~1e30) trips the loss/grad
    sentinels; a merely-large factor tests that finite-but-ugly steps
    are NOT skipped (sentinels are for non-finites, §5 handles scale)."""

    step: int
    factor: float = float("inf")
    count: int = 1
    fired: bool = False


@dataclasses.dataclass
class CkptTear:
    """Tear the checkpoint pipeline at cursor ``step``.

    ``mode``: ``writer`` — the next ``retries+1`` save attempts die
    mid-leaf-write (async error surfaces at ``wait()``); ``strip`` —
    delete the newest checkpoint's ``_COMMITTED`` marker; ``corrupt`` —
    XOR one byte of a leaf file in the newest committed checkpoint, so
    its manifest CRC32 no longer matches.
    """

    step: int
    mode: str = "corrupt"
    fired: bool = False

    def __post_init__(self):
        if self.mode not in ("writer", "strip", "corrupt"):
            raise ValueError(f"unknown CkptTear mode {self.mode!r}")


@dataclasses.dataclass
class ParamBitFlip:
    """XOR bit ``bit`` of one packed-param mantissa at cursor ``step``."""

    step: int
    bit: int = 5
    fired: bool = False


@dataclasses.dataclass
class Kill:
    """SIGKILL the process at cursor ``step`` (the CI crash smoke)."""

    step: int
    fired: bool = False


class FaultHarness:
    """Drives a fault list against a :class:`TrainSupervisor`.

    The supervisor calls two hooks per step attempt: :meth:`on_step`
    (host-side surgery — checkpoint tears, param bit flips, kills)
    before building the batch, and :meth:`injection` for the device-side
    ``inj`` dict fed to the train jit.  Both are cheap no-ops with no
    pending faults.  ``log`` accumulates one JSON-able dict per event.
    """

    def __init__(self, faults, seed: int = 0, tracer=None, metrics=None):
        self.faults = list(faults)
        self.seed = seed
        self.log: List[dict] = []
        self.tracer = tracer
        self._c_injected = (metrics.counter("train_faults_injected")
                            if metrics is not None else None)

    def _event(self, kind: str, **kw) -> None:
        self.log.append({"kind": kind, **kw})
        if self.tracer is not None:
            self.tracer.instant(f"fault:{kind}", tid="faults", **kw)
        if self._c_injected is not None and not kind.endswith("_skipped"):
            self._c_injected.inc()

    def log_supervisor_event(self, kind: str, **kw) -> None:
        """Supervisor outcomes land in the same log (rollbacks, halts),
        tagged so ``summary()`` separates them from injections."""
        self.log.append({"kind": f"sup:{kind}", **kw})
        if self.tracer is not None:
            self.tracer.instant(f"train:{kind}", tid="train", **kw)

    # -- supervisor hooks --------------------------------------------------
    def on_step(self, sup) -> None:
        cursor = sup.cursor
        for f in self.faults:
            if isinstance(f, CkptTear) and not f.fired and cursor >= f.step:
                f.fired = True
                self._tear(sup, f, cursor)
            elif isinstance(f, ParamBitFlip) and not f.fired and \
                    cursor >= f.step:
                f.fired = True
                self._flip(sup, f, cursor)
            elif isinstance(f, Kill) and not f.fired and cursor >= f.step:
                f.fired = True
                self._event("kill", cursor=cursor, pid=os.getpid())
                os.kill(os.getpid(), signal.SIGKILL)

    def injection(self, sup) -> dict:
        inj = benign_injection()
        cursor = sup.cursor
        for f in self.faults:
            if isinstance(f, GradNaN) and \
                    f.step <= cursor < f.step + f.count:
                inj["grad_nan"] = jnp.bool_(True)
                if not f.fired:
                    f.fired = True
                self._event("grad_nan", cursor=cursor,
                            window=[f.step, f.step + f.count])
            elif isinstance(f, LossSpike) and \
                    f.step <= cursor < f.step + f.count:
                inj["loss_scale"] = jnp.float32(f.factor)
                if not f.fired:
                    f.fired = True
                self._event("loss_spike", cursor=cursor, factor=f.factor)
        return inj

    # -- host-side surgery -------------------------------------------------
    def _tear(self, sup, f: CkptTear, cursor: int) -> None:
        mgr = sup.manager
        if mgr is None:
            self._event("ckpt_tear_skipped", cursor=cursor,
                        reason="no checkpoint manager attached")
            return
        if f.mode == "writer":
            mgr.inject_failure()
            self._event("ckpt_tear", mode="writer", cursor=cursor)
            return
        try:
            mgr.wait()
        except Exception:
            pass                            # surfaced later by supervisor
        steps = mgr.all_steps()
        committed = [s for s in steps if os.path.exists(
            os.path.join(mgr.dir, f"step_{s:08d}", "_COMMITTED"))]
        if not committed:
            self._event("ckpt_tear_skipped", cursor=cursor, mode=f.mode,
                        reason="no committed checkpoint to tear")
            return
        path = os.path.join(mgr.dir, f"step_{max(committed):08d}")
        if f.mode == "strip":
            os.remove(os.path.join(path, "_COMMITTED"))
            self._event("ckpt_tear", mode="strip", cursor=cursor,
                        victim=os.path.basename(path))
            return
        leaves = sorted(n for n in os.listdir(path) if n.endswith(".npy"))
        if not leaves:
            self._event("ckpt_tear_skipped", cursor=cursor, mode=f.mode,
                        reason="committed dir has no leaf files")
            return
        victim = os.path.join(path, leaves[len(leaves) // 2])
        with open(victim, "r+b") as fh:
            fh.seek(-1, os.SEEK_END)
            b = fh.read(1)
            fh.seek(-1, os.SEEK_END)
            fh.write(bytes([b[0] ^ 0xFF]))
        self._event("ckpt_tear", mode="corrupt", cursor=cursor,
                    victim=os.path.relpath(victim, mgr.dir))

    def _flip(self, sup, f: ParamBitFlip, cursor: int) -> None:
        from repro.core.packed import PackedArray

        import jax

        leaves = [x for x in jax.tree.leaves(
            sup.state.params,
            is_leaf=lambda x: isinstance(x, PackedArray))
            if isinstance(x, PackedArray)]
        if not leaves:
            self._event("bit_flip_skipped", cursor=cursor,
                        reason="params are not in packed storage")
            return
        target = leaves[len(leaves) // 2]
        m = np.asarray(target.mantissa)
        idx = tuple(d // 2 for d in m.shape)
        width = 8 * m.dtype.itemsize
        bit = min(f.bit, width - 2)         # keep off the sign bit
        old = int(m[idx])
        new_m = target.mantissa.at[idx].set(
            jnp.bitwise_xor(target.mantissa[idx],
                            jnp.asarray(1 << bit, target.mantissa.dtype)))
        sup.state = _replace_leaf(sup.state, target, new_m)
        self._event("bit_flip", cursor=cursor, bit=bit,
                    index=[int(i) for i in idx], old=old,
                    new=int(np.asarray(new_m[idx])))

    # -- reporting ---------------------------------------------------------
    def summary(self) -> dict:
        counts: dict = {}
        for ev in self.log:
            counts[ev["kind"]] = counts.get(ev["kind"], 0) + 1
        return {"seed": self.seed, "n_faults": len(self.faults),
                "events": list(self.log), "event_counts": counts}


def _replace_leaf(state, victim, new_mantissa):
    """Rebuild ``state`` with ``victim``'s mantissa swapped (PackedArray
    leaves are frozen dataclasses; the tree is host-side plumbing)."""
    import dataclasses as dc

    import jax

    from repro.core.packed import PackedArray

    def sub(x):
        if x is victim:
            return dc.replace(x, mantissa=new_mantissa)
        return x

    new_params = jax.tree.map(
        sub, state.params, is_leaf=lambda x: isinstance(x, PackedArray))
    return dc.replace(state, params=new_params)


def chaos_plan(seed: int, *, n_steps: int = 24, p_nan: float = 0.5,
               p_spike: float = 0.5, p_tear: float = 0.5,
               p_flip: float = 0.5, burst: int = 0) -> list:
    """Reproducible random fault mix for a train chaos sweep.

    Same seed → same plan (``random.Random(seed)``, no global state).
    Each class draws independently; ``burst > 0`` adds one GradNaN run
    of that length (longer than the default skip budget → exercises the
    rollback path, not just lone skips).
    """
    rng = random.Random(seed)
    faults: list = []
    hi = max(3, n_steps - 2)
    if rng.random() < p_nan:
        faults.append(GradNaN(step=rng.randint(2, hi)))
    if rng.random() < p_spike:
        faults.append(LossSpike(step=rng.randint(2, hi),
                                factor=float("inf")))
    if rng.random() < p_tear:
        faults.append(CkptTear(step=rng.randint(3, hi),
                               mode=rng.choice(["writer", "strip",
                                                "corrupt"])))
    if rng.random() < p_flip:
        faults.append(ParamBitFlip(step=rng.randint(2, hi),
                                   bit=rng.randint(0, 6)))
    if burst > 0:
        faults.append(GradNaN(step=rng.randint(2, hi), count=burst))
    return faults
