"""Model zoo: unified transformer (dense/MoE/SSM/hybrid/enc-dec) + maxout."""
from . import layers, maxout, moe, ssm, transformer  # noqa: F401
from .transformer import ModelConfig, build_stages, group_shapes  # noqa: F401
