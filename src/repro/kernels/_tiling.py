"""Shared tiling policy for the Pallas kernels: padding + block choice.

Both kernel families (dfxp quantize, qmatmul fwd/dgrad/wgrad) pad their
operands up to block multiples before the ``pallas_call`` and slice the
result back.  Zero padding is semantically free for every kernel here:
pads quantize to 0 (0 never overflows, so the statistics are exact) and
contribute exactly 0.0 to f32 dot-product accumulations.

Block heuristics live here so the two ``ops.py`` wrappers and the
dispatch layer agree on one notion of "tile-friendly"; the measured
autotune cache in :mod:`repro.kernels.dispatch` overrides these numbers
per shape bucket on compiled backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def default_interpret() -> bool:
    """Backend detection, resolved once per process.

    Compiled Pallas on TPU; everywhere else (CPU/GPU containers) the
    kernels run in interpret mode — numerically identical, used by tests
    and benchmarks.
    """
    if _BACKEND["interpret"] is None:
        _BACKEND["interpret"] = jax.default_backend() != "tpu"
    return _BACKEND["interpret"]


def resolve_interpret(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


_BACKEND = {"interpret": None}


# ---------------------------------------------------------------------------
# block heuristics
# ---------------------------------------------------------------------------

def mm_blocks(kind: str, R: int, C: int, D: int) -> tuple:
    """Heuristic (block_r, block_c, block_d) for an (R, C) output with
    reduction length D, per contraction layout (see qmatmul.qmm_2d).

    Lane and contraction tiles are 128-aligned to feed the MXU directly;
    dims that only ever sit on the sublane axis shrink in multiples of 8
    for skinny operands.  In ``tn`` the output-row dim R is a *lane* dim
    of the left operand tile (and D a sublane dim), so the alignment
    roles swap.
    """
    if kind == "tn":
        br = min(128, round_up(R, 128))
        bd = min(128, round_up(D, 8))
    else:
        br = min(128, round_up(R, 8))
        bd = min(128, round_up(D, 128))
    bc = min(128, round_up(C, 128))
    return br, bc, bd


def quantize_blocks(M: int, N: int) -> tuple:
    """Heuristic (block_m, block_n) for the elementwise quantize kernel."""
    bn = 128
    while bn * 2 <= min(N, 512):
        bn *= 2
    bm = 8
    while bm * 2 <= min(M, 256):
        bm *= 2
    return bm, bn


# ---------------------------------------------------------------------------
# padding
# ---------------------------------------------------------------------------

def pad2d(x, rows: int, cols: int):
    """Zero-pad a 2D array up to (rows, cols); no-op when already there."""
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr or pc:
        return jnp.pad(x, ((0, pr), (0, pc)))
    return x
