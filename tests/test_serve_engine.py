"""repro.serve: equivalence, continuous batching, sampler, packed pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.launch.serve import Engine as LockstepEngine
from repro.models import transformer as T
from repro.serve import (
    CacheQuantConfig,
    EngineOptions,
    PackedKVCodec,
    SamplerConfig,
    ServeEngine,
    sample,
)

POL = PrecisionPolicy("float32")


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def prompts(model):
    cfg, _ = model
    return np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                         cfg.vocab_size))


@pytest.fixture(scope="module")
def f32_eng(model):
    """One greedy f32 engine reused across waves (jits compile once)."""
    cfg, params = model
    return ServeEngine(cfg, POL, params, max_slots=2, max_len=24)


def _wave(eng, reqs):
    """``reqs``: [(prompt, max_new)]. Returns outputs in submit order."""
    uids = [eng.submit(p, max_new=m) for p, m in reqs]
    out = eng.run()
    return [out[u] for u in uids], uids


# ---------------------------------------------------------------------------
# acceptance: equivalences
# ---------------------------------------------------------------------------

def test_f32_engine_matches_lockstep_bitwise(model, prompts, f32_eng):
    """Equal-length prompts: serve engine == lockstep greedy, bit-for-bit."""
    cfg, params = model
    ref = np.asarray(LockstepEngine(cfg, POL, params, max_len=24)
                     .generate(jnp.asarray(prompts), max_new=6))
    out, _ = _wave(f32_eng, [(p, 6) for p in prompts])
    np.testing.assert_array_equal(np.stack(out), ref)


def test_packed_cache_matches_f32_greedy(model, prompts, f32_eng):
    """int8/int16 packed-pool greedy == f32-pool greedy for >= 8 steps."""
    cfg, params = model
    ref, _ = _wave(f32_eng, [(p, 8) for p in prompts])
    for bits in (8, 16):
        eng = ServeEngine(cfg, POL, params, max_slots=2, max_len=24,
                          options=EngineOptions(cache_bits=bits))
        out, _ = _wave(eng, [(p, 8) for p in prompts])
        for o, r in zip(out, ref):
            np.testing.assert_array_equal(o, r)
        # every decode append on both slots was quantized and accounted
        assert eng.cache_stats()["cache_appends_quantized"] > 0


def test_queued_request_admitted_into_freed_slot(prompts, f32_eng):
    """2 slots, 3 requests: the queued one decodes mid-stream in a freed
    slot and reproduces its run-alone tokens exactly."""
    short = prompts[0][:5]
    out, (u0, u1, u2) = _wave(f32_eng, [(prompts[0], 3), (prompts[1], 8),
                                        (short, 5)])
    assert [len(o) for o in out] == [3, 8, 5]
    # the queued request was admitted mid-decode: after the first slot
    # freed, before the long request finished
    tr = f32_eng.metrics.traces
    assert tr[u2].t_admit > tr[u0].t_finish
    assert tr[u2].t_first < tr[u1].t_finish

    solo, _ = _wave(f32_eng, [(short, 5)])
    np.testing.assert_array_equal(out[2], solo[0])


def test_slot_reuse_many_waves(prompts, f32_eng):
    """More requests than slots, differing budgets: all finish and match
    their solo decodes (slot state fully recycled between occupants)."""
    reqs = [(prompts[0], 4), (prompts[1], 6), (prompts[0][:5], 3),
            (prompts[1][:5], 5), (prompts[0], 2)]
    out, _ = _wave(f32_eng, reqs)
    assert [len(o) for o in out] == [m for _, m in reqs]
    for got, req in zip(out, reqs):
        solo, _ = _wave(f32_eng, [req])
        np.testing.assert_array_equal(got, solo[0])


def test_submit_validation(model):
    cfg, params = model
    eng = ServeEngine(cfg, POL, params, max_slots=1, max_len=8)
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(np.zeros(5, np.int32), max_new=4)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.zeros(0, np.int32), max_new=1)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(np.zeros(2, np.int32), max_new=0)


def test_moe_request_independent_of_batchmates():
    """MoE prefill capacity couples a batch's routing: the engine must
    admit MoE requests one per prefill so solo == shared exactly."""
    cfg = configs.get_smoke("granite_moe_1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 6),
                                            0, cfg.vocab_size))
    eng = ServeEngine(cfg, POL, params, max_slots=2, max_len=16)
    assert eng._admit_group_cap == 1
    shared, _ = _wave(eng, [(p, 4) for p in prompts])
    solo, _ = _wave(eng, [(prompts[0], 4)])
    np.testing.assert_array_equal(shared[0], solo[0])


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

def _keys(n, base=0):
    return jnp.stack([jax.random.PRNGKey(base + i) for i in range(n)])


def test_sampler_greedy_is_argmax():
    logits = jnp.asarray(np.random.RandomState(0).randn(3, 17), jnp.float32)
    toks = sample(logits, _keys(3), SamplerConfig("greedy"))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.argmax(np.asarray(logits), -1))


def test_sampler_top_k_stays_in_top_k():
    logits = jnp.asarray(np.random.RandomState(1).randn(4, 50), jnp.float32)
    cfg = SamplerConfig("top_k", temperature=1.5, top_k=5)
    top5 = np.argsort(-np.asarray(logits), -1)[:, :5]
    for s in range(20):
        toks = np.asarray(sample(logits, _keys(4, base=4 * s), cfg))
        for b in range(4):
            assert toks[b] in top5[b]


def test_stochastic_sampling_solo_equals_batched(model, prompts):
    """Per-request PRNG streams: a top-k request draws the same tokens
    alone as when batched with another request (stochastic cache too)."""
    cfg, params = model
    kw = dict(max_slots=2, max_len=24, options=EngineOptions(
        cache_bits=8,
        cache_cfg=CacheQuantConfig(width=8, stochastic=True),
        sampler_cfg=SamplerConfig("top_k", temperature=0.9, top_k=8),
        seed=7))
    a = ServeEngine(cfg, POL, params, **kw)
    batched, _ = _wave(a, [(p, 4) for p in prompts])
    b = ServeEngine(cfg, POL, params, **kw)
    solo, _ = _wave(b, [(prompts[0], 4)])
    np.testing.assert_array_equal(batched[0], solo[0])


# ---------------------------------------------------------------------------
# packed pool mechanics (no model)
# ---------------------------------------------------------------------------

def _raw_entry(key, n=2, g=1, w=6, k=2, hd=4, n_valid=4, scale=1.0):
    kk, kv = jax.random.split(key)
    pos = jnp.where(jnp.arange(w) < n_valid, jnp.arange(w), -1)
    return {"k": jax.random.normal(kk, (n, g, w, k, hd)) * scale,
            "v": jax.random.normal(kv, (n, g, w, k, hd)) * scale,
            "pos": jnp.broadcast_to(pos, (n, g, w)).astype(jnp.int32)}


def test_pack_entry_roundtrip_accuracy():
    codec = PackedKVCodec(CacheQuantConfig(width=8))
    raw = _raw_entry(jax.random.PRNGKey(2))
    entry = codec.pack_entry(raw)
    k, v, pos = codec.load(entry)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(raw["pos"]))
    step = 2.0 ** np.asarray(entry["k_e"])[..., None, None, None]
    valid = (np.asarray(raw["pos"]) >= 0)[..., None, None]
    err = np.abs(np.asarray(k) - np.asarray(raw["k"])) * valid
    assert np.all(err <= step / 2 + 1e-7)


def test_controller_adapts_slot_exponent_on_append_overflow():
    """Appends far beyond the calibrated range overflow until the per-slot
    controller raises the exponent; stored mantissas rescale in place."""
    qcfg = CacheQuantConfig(width=8, update_interval=3)
    codec = PackedKVCodec(qcfg)
    raw = _raw_entry(jax.random.PRNGKey(3), w=8, n_valid=2, scale=0.1)
    # strip the layer dim as the layer scan does
    entry = jax.tree_util.tree_map(lambda x: x[0], codec.pack_entry(raw))
    e0 = float(entry["k_e"][0])
    pre = np.asarray(codec.load(entry)[0])[0, 0]    # slot 0, before
    k_big = jnp.full((1, 2, 4), 30.0)               # >> qmax * 2**e0
    v_new = jnp.zeros((1, 2, 4))
    for i in range(2 * qcfg.update_interval):       # slots 2..7: 0 untouched
        entry = codec.append(entry, k_big, v_new,
                             jnp.asarray([2 + i], jnp.int32))
    e1 = float(entry["k_e"][0])
    assert e1 > e0                                  # paper rule: scale x2
    assert float(entry["tot_k"][0, 0]) > 0          # overflows were counted
    # the untouched slot's values survived the rescale within the new step
    now = np.asarray(codec.load(entry)[0])[0, 0]
    assert np.all(np.abs(now - pre) <= 2.0 ** e1 + 1e-7)


def test_stochastic_append_diverges_then_reproduces():
    """Stochastic appends draw from the entry's own key chain: two equal
    entries produce identical appends, a reseeded one differs."""
    qcfg = CacheQuantConfig(width=8, stochastic=True)
    codec = PackedKVCodec(qcfg)
    raw = _raw_entry(jax.random.PRNGKey(4))
    keys = jnp.stack([jax.random.PRNGKey(11)])
    e1 = jax.tree_util.tree_map(lambda x: x[0],
                                codec.pack_entry(raw, slot_keys=keys))
    e2 = jax.tree_util.tree_map(lambda x: x[0],
                                codec.pack_entry(raw, slot_keys=keys))
    k_new = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 4)) * 0.3
    a = codec.append(dict(e1), k_new, k_new, jnp.asarray([4], jnp.int32))
    b = codec.append(dict(e2), k_new, k_new, jnp.asarray([4], jnp.int32))
    np.testing.assert_array_equal(np.asarray(a["k_m"]), np.asarray(b["k_m"]))
    keys3 = jnp.stack([jax.random.PRNGKey(12)])
    e3 = jax.tree_util.tree_map(lambda x: x[0],
                                codec.pack_entry(raw, slot_keys=keys3))
    c = codec.append(dict(e3), k_new, k_new, jnp.asarray([4], jnp.int32))
    assert not np.array_equal(np.asarray(a["k_m"]), np.asarray(c["k_m"]))


def test_f32_pool_is_init_cache(model):
    from repro.serve import make_pool
    cfg, _ = model
    a = make_pool(cfg, 2, 16, None)
    b = T.init_cache(cfg, 2, 16)
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)
