"""Optimizers + schedules (paper: SGD, linear-decay LR, saturating momentum)."""
from .opt import (  # noqa: F401
    AdamWState,
    OptConfig,
    SGDState,
    adamw_init,
    adamw_update,
    apply_max_norm,
    global_norm,
    lr_at,
    momentum_at,
    sgd_init,
    sgd_update,
)
