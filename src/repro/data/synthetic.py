"""Deterministic synthetic datasets (offline container: no MNIST/CIFAR/SVHN).

Two generators:
  * :class:`SyntheticLM` — a *learnable* token stream: tokens follow a
    random-projection bigram/trigram chart with Zipf-ish marginals, so a
    language model's loss decreases well below the unigram entropy.
  * :class:`SyntheticImages` — class-conditional Gaussian clusters pushed
    through a fixed random deep projection (matched to MNIST/CIFAR input
    dims), hard enough that a linear model underperforms the maxout nets.

Both are deterministic in (seed, step) — a restart resumes bit-identically
from the step counter (fault-tolerance contract), and each host generates
only its own shard (``host_id``/``num_hosts``).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        v = self.vocab_size
        # sparse-ish bigram chart: each token has ~8 likely successors
        self.n_next = min(8, v)
        self.nexts = rng.randint(0, v, size=(v, self.n_next)).astype(np.int32)
        zipf = 1.0 / np.arange(1, v + 1)
        self.marginal = (zipf / zipf.sum()).astype(np.float64)

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    def batch(self, step: int) -> dict:
        """Host-local shard of the global batch for ``step`` (numpy)."""
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 613 + self.host_id) % 2 ** 31)
        B, S = self.host_batch, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(self.vocab_size, size=B, p=self.marginal)
        # 85% bigram-following, 15% resample → learnable but not trivial
        follows = rng.random((B, S)) < 0.85
        pick = rng.randint(0, self.n_next, size=(B, S))
        resample = rng.randint(0, self.vocab_size, size=(B, S))
        for t in range(S):
            nxt = self.nexts[toks[:, t], pick[:, t]]
            toks[:, t + 1] = np.where(follows[:, t], nxt, resample[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class SyntheticImages:
    input_dim: int = 784
    num_classes: int = 10
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    image_shape: tuple = ()      # e.g. (1, 28, 28) → conv layout
    # difficulty knobs (hard() raises the Bayes error so format differences
    # show up in both loss and error rate)
    center_scale: float = 1.0
    latent_noise: float = 1.0
    out_noise: float = 0.3

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        d_latent = 32
        self.centers = rng.randn(self.num_classes, d_latent).astype(np.float32) * 2.0
        self.proj1 = rng.randn(d_latent, 128).astype(np.float32) / np.sqrt(d_latent)
        self.proj2 = rng.randn(128, self.input_dim).astype(np.float32) / np.sqrt(128)

    @classmethod
    def hard(cls, **kw):
        return cls(center_scale=0.5, latent_noise=1.6, out_noise=1.0, **kw)

    def batch(self, step: int, batch_size: int) -> dict:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 613 + self.host_id + 7) % 2 ** 31)
        per_host = batch_size // self.num_hosts
        y = rng.randint(0, self.num_classes, per_host)
        z = (self.centers[y] * self.center_scale
             + rng.randn(per_host, self.centers.shape[1]) * self.latent_noise)
        h = np.tanh(z @ self.proj1)
        x = (h @ self.proj2 + rng.randn(per_host, self.input_dim)
             * self.out_noise)
        x = x.astype(np.float32)
        if self.image_shape:
            x = x.reshape((per_host,) + tuple(self.image_shape))
        return {"x": x, "y": y.astype(np.int32)}

    def eval_set(self, n: int = 2048) -> dict:
        return self.batch(step=10 ** 6, batch_size=n)


def shard_batch(batch: dict, sharding) -> dict:
    """Device-put a host-local numpy batch with the given NamedSharding."""
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)
