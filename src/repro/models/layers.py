"""Shared neural-net layers, quantization-aware (tape-threaded).

Every weighted sum goes through ``tape.dot`` (weight re-quantized to the
computation width at use time, wide f32 accumulation — the paper's §7
accumulator hypothesis == the TPU MXU contract) and every group boundary
through ``tape.act`` (forward value + backward cotangent quantized, overflow
stats recorded). With a float32 policy all of it is the identity.

Attention comes in three shapes:
  * ``attention_train``  — naive masked scores (seq ≤ ~8k; remat-friendly).
  * ``attention_prefill`` — online-softmax scan over KV chunks (no-grad
    inference path; peak memory ∝ chunk, required for 32k prefill).
  * ``attention_decode`` — single-query against a cache (O(S) memory).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.tape import QTape

Array = jax.Array


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, scale: Optional[float] = None) -> Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return jnp.exp(
        -jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
        * jnp.log(jnp.float32(theta))
    )  # [hd/2]


def apply_rope(x: Array, positions: Array, theta: float,
               mrope_sections: Tuple[int, ...] = ()) -> Array:
    """``x``: [B, S, H, hd]. ``positions``: [B, S] or [3, B, S] for M-RoPE.

    M-RoPE (qwen2-vl): frequency dims are partitioned into (temporal, height,
    width) sections, each rotated by its own position stream.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    if positions.ndim == 3:  # M-RoPE
        if not mrope_sections:
            mrope_sections = (hd // 2,)
        sec_ids = jnp.repeat(
            jnp.arange(len(mrope_sections)),
            jnp.asarray(mrope_sections),
            total_repeat_length=hd // 2,
        )  # [hd/2] -> which position stream each freq dim uses
        pos = positions[sec_ids]                       # [hd/2, B, S]
        angle = jnp.einsum("fbs,f->bsf", pos.astype(jnp.float32), freqs)
    else:
        angle = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, hd/2]
    cos = jnp.cos(angle)[:, :, None, :]
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, ...] = ()
    causal: bool = True
    use_rope: bool = True

    @property
    def q_dim(self):
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self):
        return self.num_kv_heads * self.head_dim


def init_attn(key, spec: AttnSpec) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], spec.d_model, spec.q_dim),
        "wk": init_dense(ks[1], spec.d_model, spec.kv_dim),
        "wv": init_dense(ks[2], spec.d_model, spec.kv_dim),
        "wo": init_dense(ks[3], spec.q_dim, spec.d_model),
    }
    if spec.qk_norm:
        p["q_norm"] = jnp.ones((spec.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((spec.head_dim,), jnp.float32)
    return p


def _qkv(params, spec: AttnSpec, x: Array, positions, tape: QTape, prefix: str):
    B, S, _ = x.shape
    q = tape.dot(f"{prefix}/wq", x, params["wq"]).reshape(
        B, S, spec.num_heads, spec.head_dim)
    k = tape.dot(f"{prefix}/wk", x, params["wk"]).reshape(
        B, S, spec.num_kv_heads, spec.head_dim)
    v = tape.dot(f"{prefix}/wv", x, params["wv"]).reshape(
        B, S, spec.num_kv_heads, spec.head_dim)
    if spec.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    if spec.use_rope:
        q = apply_rope(q, positions, spec.rope_theta, spec.mrope_sections)
        k = apply_rope(k, positions, spec.rope_theta, spec.mrope_sections)
    q = tape.act(f"{prefix}/qkv", q)
    k = tape.act(f"{prefix}/k", k)
    v = tape.act(f"{prefix}/v", v)
    return q, k, v


def _mask(q_pos: Array, k_pos: Array, window, causal: bool) -> Array:
    """[.., Sq, Sk] boolean validity mask. window==0 means global."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    m = (d >= 0) if causal else jnp.ones(d.shape, bool)
    if window is not None:
        w = jnp.asarray(window)
        m = m & ((w == 0) | (d < w))
    return m


def _sdpa(q, k, v, mask, scale) -> Array:
    """Naive scores; f32 softmax; GQA via head-group reshape."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_train(params, spec: AttnSpec, x: Array, positions: Array,
                    tape: QTape, prefix: str, window=None,
                    kv_source: Optional[Array] = None,
                    kv_positions: Optional[Array] = None) -> Array:
    """Training-path attention (naive masked). ``kv_source`` → cross-attn."""
    B, S, _ = x.shape
    if kv_source is None:
        q, k, v = _qkv(params, spec, x, positions, tape, prefix)
        k_pos = positions
        causal = spec.causal
    else:
        q = tape.dot(f"{prefix}/wq", x, params["wq"]).reshape(
            B, S, spec.num_heads, spec.head_dim)
        Sk = kv_source.shape[1]
        k = tape.dot(f"{prefix}/wk", kv_source, params["wk"]).reshape(
            B, Sk, spec.num_kv_heads, spec.head_dim)
        v = tape.dot(f"{prefix}/wv", kv_source, params["wv"]).reshape(
            B, Sk, spec.num_kv_heads, spec.head_dim)
        q = tape.act(f"{prefix}/qkv", q)
        k = tape.act(f"{prefix}/k", k)
        v = tape.act(f"{prefix}/v", v)
        k_pos = (kv_positions if kv_positions is not None
                 else jnp.broadcast_to(jnp.arange(Sk), (B, Sk)))
        causal = False

    q_pos = positions if positions.ndim == 2 else positions[0]
    k_pos2 = k_pos if k_pos.ndim == 2 else k_pos[0]
    mask = _mask(q_pos, k_pos2, window, causal)
    o = _sdpa(q, k, v, mask, 1.0 / math.sqrt(spec.head_dim))
    o = o.reshape(B, S, spec.q_dim)
    y = tape.dot(f"{prefix}/wo", o, params["wo"])
    return tape.act(f"{prefix}/out", y)


def attention_prefill(params, spec: AttnSpec, x: Array, positions: Array,
                      tape: QTape, prefix: str, window=None,
                      chunk: int = 1024):
    """Inference prefill: online-softmax over KV chunks; returns (y, (k, v)).

    Peak memory ∝ ``Sq × chunk`` instead of ``Sq × Sk`` — required for the
    32k/500k shapes. No autodiff support (inference only).
    """
    B, S, _ = x.shape
    q, k, v = _qkv(params, spec, x, positions, tape, prefix)
    H, K, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    q_pos = positions if positions.ndim == 2 else positions[0]

    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # pad positions must be invalid under the causal mask → large positive
    pos_p = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=2 ** 30)
    kc = kp.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    pc = pos_p.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    qg = q.reshape(B, S, K, G, hd)

    def body(carry, xs):
        m, l, acc = carry
        kci, vci, pci = xs
        s = jnp.einsum("bqkgh,bckh->bkgqc", qg, kci,
                       preferred_element_type=jnp.float32) * scale
        valid = _mask(q_pos, pci, window, spec.causal)  # [B, S, chunk]
        vexp = valid[:, None, None, :, :]
        s = jnp.where(vexp, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # fully-masked chunks: exp(-1e30 - (-1e30)) = 1 would leak — zero it
        p = jnp.where(vexp, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckh->bkgqh", p, vci.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, K, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, K, G, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, S, spec.q_dim).astype(x.dtype)
    y = tape.dot(f"{prefix}/wo", o, params["wo"])
    return tape.act(f"{prefix}/out", y), (k, v)


class RawKVCodec:
    """Float-container KV-cache codec: today's ring buffer, verbatim.

    The codec protocol is the decode cache's storage contract:
    ``append(entry, k_new, v_new, pos)`` writes one token's K/V into slot
    ``pos % W`` and returns the updated entry; ``load(entry)`` returns
    ``(k, v, pos)`` as wide arrays for the attention math. Alternative
    codecs (``repro.serve.kv_pool.PackedKVCodec``) store int mantissas +
    per-slot DFXP exponents and quantize/dequantize at this boundary.

    ``fused_decode`` is the codec *capability flag*
    ``attention_decode`` keys on: when set, the hot decode path skips
    ``load`` entirely and calls ``fused_attention`` — the Pallas
    flash-decode kernel reading the entry's storage containers directly
    (for this codec that is plain f32; for the packed codec, int
    mantissas dequantized in the tile loads). The default instance keeps
    it off, so every existing call site retains today's exact path.

    The flag is now a **read-only property** owned by
    :func:`repro.serve.kv_pool.make_kv_pool` (the factory decides the
    decode path together with the pool layout); passing the legacy
    ``fused_decode=`` constructor argument still works for one release
    but warns.  ``tp_axis`` names the mesh axis the pool's kv-head
    dimension is sharded over (serving tensor parallelism) — the fused
    kernels shard_map themselves over it.
    """

    def __init__(self, fused_decode: Optional[bool] = None, *,
                 tp_axis: Optional[str] = None):
        if fused_decode is not None:
            warnings.warn(
                "RawKVCodec(fused_decode=...) is deprecated; build pools "
                "through repro.serve.kv_pool.make_kv_pool, which owns the "
                "decode-path choice", DeprecationWarning, stacklevel=2)
        self._fused_decode = bool(fused_decode)
        self.tp_axis = tp_axis

    @property
    def fused_decode(self) -> bool:
        """Whether decode/prefill attention runs the fused Pallas kernels
        on this codec's containers (set by the pool factory)."""
        return self._fused_decode

    def append(self, entry: dict, k_new: Array, v_new: Array,
               pos: Array, mask: Optional[Array] = None) -> dict:
        """``k_new``/``v_new``: [B, K, hd]; ``pos``: [B] int32.

        ``mask`` (bool [B], optional) suppresses the append for masked-off
        rows entirely — the continuous-batching engine decodes all slots
        every step, and rows mid-chunked-prefill (or free) must not have
        garbage written into their ring.  ``mask=None`` keeps today's
        unconditional write, bit-for-bit.
        """
        W = entry["k"].shape[1]
        slot = (pos % W).astype(jnp.int32)
        bidx = jnp.arange(pos.shape[0])
        if mask is None:
            return {"k": entry["k"].at[bidx, slot].set(k_new),
                    "v": entry["v"].at[bidx, slot].set(v_new),
                    "pos": entry["pos"].at[bidx, slot].set(
                        pos.astype(jnp.int32))}
        # masked rows write out of bounds and are dropped
        slot = jnp.where(mask, slot, W)
        return {"k": entry["k"].at[bidx, slot].set(k_new, mode="drop"),
                "v": entry["v"].at[bidx, slot].set(v_new, mode="drop"),
                "pos": entry["pos"].at[bidx, slot].set(
                    pos.astype(jnp.int32), mode="drop")}

    def append_chunk(self, entry: dict, k_new: Array, v_new: Array,
                     p0: Array, n_valid: Array) -> dict:
        """Write a prefill chunk's K/V into the ring, raw f32.

        ``k_new``/``v_new``: [B, C, K, hd] — rows ``i`` land at absolute
        positions ``p0 + i``; rows ``>= n_valid`` (ragged final chunk) and
        rows the ring would evict within this same chunk (``C`` larger
        than a windowed cap) are dropped.  ``p0 == 0`` marks the
        admission chunk: the slot's stale ring positions reset to -1
        first, so a recycled slot never leaks its previous occupant.
        """
        W = entry["k"].shape[1]
        C = k_new.shape[1]
        idx = jnp.arange(C, dtype=jnp.int32)
        pos = p0[:, None] + idx[None, :]                          # [B, C]
        keep = (idx[None, :] < n_valid[:, None]) & \
            (pos >= p0[:, None] + n_valid[:, None] - W)
        slot = jnp.where(keep, pos % W, W).astype(jnp.int32)
        bidx = jnp.arange(pos.shape[0])[:, None]
        pos_buf = jnp.where((p0 == 0)[:, None], -1, entry["pos"])
        return {"k": entry["k"].at[bidx, slot].set(k_new, mode="drop"),
                "v": entry["v"].at[bidx, slot].set(v_new, mode="drop"),
                "pos": pos_buf.at[bidx, slot].set(pos, mode="drop")}

    def load(self, entry: dict):
        return entry["k"], entry["v"], entry["pos"]

    def fused_attention(self, entry: dict, qg: Array, q_pos: Array, *,
                        scale: float, window=None, causal: bool = True):
        """Flash-decode on the raw f32 ring buffers (``width=None``).

        ``qg``: [B, K, G, hd] kv-head-major query groups; returns
        f32 [B, K, G, hd].
        """
        from repro.kernels.attn.ops import flash_decode
        return flash_decode(qg, entry["k"], entry["v"], entry["pos"], q_pos,
                            width=None, scale=scale, window=window,
                            causal=causal, tp_axis=self.tp_axis)

    def fused_prefill(self, entry: dict, qg: Array, k_new: Array,
                      v_new: Array, p0: Array, n_valid: Array, *,
                      scale: float, window=None, causal: bool = True):
        """Flash-prefill on the raw f32 ring buffers (``width=None``).

        ``qg``: [B, C, K, G, hd] chunk query groups; the chunk's own K/V
        come from ``k_new``/``v_new`` (f32), history from the entry's
        buffers.  Returns f32 [B, C, K, G, hd].
        """
        from repro.kernels.attn.ops import flash_prefill
        return flash_prefill(qg, k_new, v_new, entry["k"], entry["v"],
                             entry["pos"], p0, n_valid, width=None,
                             scale=scale, window=window, causal=causal,
                             tp_axis=self.tp_axis)


RAW_KV_CODEC = RawKVCodec()


def _replicate_attn_out(o: Array, dist) -> Array:
    """Force the attention output replicated before the ``wo`` contraction.

    Under serving tensor parallelism the KV pool — and hence the per-head
    attention output — is sharded over kv heads, while ``wo`` contracts
    over the *full* head dimension.  Left to GSPMD that contraction runs
    as sharded partial sums + psum, whose float addition order differs
    from the single-device dot.  An explicit all-gather here keeps the
    contraction replicated, which is what makes the sharded engine's
    logits bit-identical to the unsharded run (per-head attention math is
    shard-local and exact; this is the only cross-head reduction).
    """
    if dist is None or not getattr(dist, "active", False):
        return o
    from repro._jax_compat import ambient_mesh
    mesh = ambient_mesh()
    if mesh is None:
        return o
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        o, NamedSharding(mesh, PartitionSpec()))


def attention_prefill_chunk(params, spec: AttnSpec, x: Array,
                            positions: Array, cache: dict, tape: QTape,
                            prefix: str, *, n_valid: Array, window=None,
                            dist=None, codec=None):
    """One chunked-prefill step: ``C`` prompt positions against the pool.

    ``x``: [B, C, D] chunk activations at absolute positions ``positions``
    [B, C] (``positions[:, 0]`` is the chunk start ``p0``; ``p0 == 0``
    marks the admission chunk — see ``codec.append_chunk``).  ``n_valid``
    [B] masks a ragged final chunk in-kernel; rows past it carry padding
    whose outputs are garbage-by-contract.

    The chunk queries attend the slot's already-written history (ring
    entries ``0 <= pos < p0``) plus the chunk's **own** fresh K/V causally
    — the latter straight from the f32 projections, never from the pool,
    so a windowed ring cap smaller than the chunk can't evict in-window
    keys before they are attended.  The attend runs *before* the write
    (history is pre-chunk state); then ``codec.append_chunk`` quantizes
    the chunk's K/V into the pool — in packed mode the values go straight
    to int8/int16 mantissas, and with ``codec.fused_decode`` the attend is
    the Pallas flash-prefill kernel reading those containers directly, so
    f32 K/V never materializes in either direction.  Returns
    ``(y, cache')``.
    """
    codec = codec or RAW_KV_CODEC
    B, C, _ = x.shape
    q, k_new, v_new = _qkv(params, spec, x, positions, tape, prefix)
    H, K, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    p0 = positions[:, 0]
    qg = q.reshape(B, C, K, G, hd)
    kf = k_new.astype(jnp.float32)
    vf = v_new.astype(jnp.float32)
    if getattr(codec, "fused_decode", False):
        o = codec.fused_prefill(cache, qg, kf, vf, p0, n_valid, scale=scale,
                                window=window, causal=spec.causal)
    else:
        from repro.kernels.attn import ref as AR
        ck, cv, cpos = codec.load(cache)
        o = AR.chunk_attend(qg.astype(jnp.float32), ck.astype(jnp.float32),
                            cv.astype(jnp.float32), cpos, kf, vf, p0,
                            n_valid, scale=scale, window=window,
                            causal=spec.causal)
    cache = codec.append_chunk(cache, kf, vf, p0, n_valid)
    o = _replicate_attn_out(o, dist)
    o = o.reshape(B, C, spec.q_dim).astype(x.dtype)
    y = tape.dot(f"{prefix}/wo", o, params["wo"])
    return tape.act(f"{prefix}/out", y), cache


def attention_decode(params, spec: AttnSpec, x: Array, pos: Array,
                     cache: dict, tape: QTape, prefix: str, window=None,
                     dist=None, codec=None, append_mask=None):
    """One-token decode. ``x``: [B, 1, D]; ``cache``: a codec-owned entry
    (default: ``{"k","v","pos"}`` float ring buffers ``[B, W, ...]``).

    Appends the new token's K/V through the codec (slot ``pos % W``, so the
    token attends to itself), then attends over the whole buffer with a
    position-validity mask. ``pos`` may be a scalar or a per-sequence
    ``[B]``/``[B,1]`` vector — each slot decodes at its own position.
    ``append_mask`` (bool [B], optional) drops the codec append for
    masked-off rows — the chunked-prefill engine decodes all slots every
    step, and rows still mid-prefill must not be written to.
    Returns ``(y, cache')``.

    When the codec advertises ``fused_decode``, the attention runs as the
    fused Pallas flash-decode kernel (:mod:`repro.kernels.attn`) straight
    on the codec's storage containers — ``codec.load`` (and, for packed
    pools, the f32 K/V materialization it implies) never executes on the
    hot path.  The default ``RawKVCodec`` and f32 pools keep today's
    exact einsum path.

    When ``dist.cp_decode`` is set (long-context serving: the cache window
    axis is sharded over ``dist.cp_axis``), the global (non-windowed)
    attention runs context-parallel via
    :func:`repro.dist.cp_attention.cp_decode_attention` — each shard
    attends over its local slots and softmax statistics merge exactly.
    """
    codec = codec or RAW_KV_CODEC
    B = x.shape[0]
    if jnp.ndim(pos) == 0:
        positions = jnp.broadcast_to(pos, (B, 1))
    elif jnp.ndim(pos) == 1:
        positions = pos[:, None]
    else:
        positions = pos
    q, k_new, v_new = _qkv(params, spec, x, positions, tape, prefix)
    if append_mask is None:
        cache = codec.append(cache, k_new[:, 0], v_new[:, 0],
                             positions[:, 0])
    else:
        cache = codec.append(cache, k_new[:, 0], v_new[:, 0],
                             positions[:, 0], mask=append_mask)
    H, K, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    G = H // K
    scale = 1.0 / math.sqrt(hd)

    if (dist is not None and dist.active and dist.cp_decode and dist.cp_axis
            and window is None):
        from repro.dist.cp_attention import cp_decode_attention
        cache_k, cache_v, cache_pos = codec.load(cache)
        o = cp_decode_attention(q, cache_k, cache_v, cache_pos, positions,
                                num_heads=H, num_kv_heads=K, head_dim=hd,
                                cp_axes=dist.cp_axes).astype(x.dtype)
    elif getattr(codec, "fused_decode", False):
        # the fused kernel reads the pool's storage containers directly:
        # no codec.load, no f32 K/V materialization on the hot path
        qg = q.reshape(B, K, G, hd)
        o = codec.fused_attention(cache, qg, positions[:, 0], scale=scale,
                                  window=window, causal=spec.causal)
        o = o.reshape(B, 1, spec.q_dim).astype(x.dtype)
    else:
        cache_k, cache_v, cache_pos = codec.load(cache)
        qg = q.reshape(B, 1, K, G, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, cache_k,
                       preferred_element_type=jnp.float32) * scale
        q_pos = positions if positions.ndim == 2 else positions[0]
        valid = _mask(q_pos, cache_pos, window, spec.causal)  # [B, 1, W]
        valid = valid & (cache_pos >= 0)[:, None, :]          # -1 = empty slot
        s = jnp.where(valid[:, None, None, :, :], s, -1e30)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p, cache_v.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        o = o.reshape(B, 1, spec.q_dim).astype(x.dtype)
    o = _replicate_attn_out(o, dist)
    y = tape.dot(f"{prefix}/wo", o, params["wo"])
    return tape.act(f"{prefix}/out", y), cache


# ---------------------------------------------------------------------------
# feed-forward variants
# ---------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d_model, d_ff),
        "w_up": init_dense(k2, d_model, d_ff),
        "w_down": init_dense(k3, d_ff, d_model),
    }


def swiglu(params, x: Array, tape: QTape, prefix: str) -> Array:
    g = tape.dot(f"{prefix}/w_gate", x, params["w_gate"])
    u = tape.dot(f"{prefix}/w_up", x, params["w_up"])
    h = tape.act(f"{prefix}/pre", jax.nn.silu(g) * u)
    y = tape.dot(f"{prefix}/w_down", h, params["w_down"])
    return tape.act(f"{prefix}/out", y)


def init_gelu_ffn(key, d_model: int, d_ff: int) -> dict:
    k1, k2 = jax.random.split(key, 2)
    return {"w_in": init_dense(k1, d_model, d_ff),
            "w_out": init_dense(k2, d_ff, d_model),
            "b_in": jnp.zeros((d_ff,), jnp.float32),
            "b_out": jnp.zeros((d_model,), jnp.float32)}


def gelu_ffn(params, x: Array, tape: QTape, prefix: str) -> Array:
    h = tape.dot(f"{prefix}/w_in", x, params["w_in"]) + params["b_in"]
    h = tape.act(f"{prefix}/pre", jax.nn.gelu(h))
    y = tape.dot(f"{prefix}/w_out", h, params["w_out"]) + params["b_out"]
    return tape.act(f"{prefix}/out", y)


def init_maxout(key, d_in: int, d_out: int, k: int) -> dict:
    """Maxout unit (paper §2): max over k affine maps."""
    kw, = jax.random.split(key, 1)
    return {"w": jax.random.normal(kw, (k, d_in, d_out), jnp.float32)
            / math.sqrt(d_in),
            "b": jnp.zeros((k, d_out), jnp.float32)}


def maxout(params, x: Array, tape: QTape, prefix: str) -> Array:
    """h_i = max_j (b_ij + w_ij · x) — the paper's hidden unit.

    The k affine maps run as ONE [d_in, k·d_out] matmul (a single
    tile-friendly shape on the fused kernel path) followed by a
    reshape/max — same values and quantization statistics as k separate
    ``tape.dot`` calls, one kernel launch instead of k.
    """
    k, d_in, d_out = params["w"].shape
    w2 = params["w"].transpose(1, 0, 2).reshape(d_in, k * d_out)
    b2 = params["b"].reshape(k * d_out)
    z = tape.dot(f"{prefix}/w", x, w2) + b2
    h = jnp.max(z.reshape(z.shape[:-1] + (k, d_out)), axis=-2)
    return tape.act(f"{prefix}/out", h)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int) -> Array:
    return jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02


def embed(table: Array, tokens: Array, tape: QTape) -> Array:
    t = tape.weight("emb/w", table)
    return tape.act("emb/out", jnp.take(t, tokens, axis=0))


def lm_head(table_or_w: Array, x: Array, tape: QTape, *, tied: bool) -> Array:
    """Vocabulary projection through ``tape.dot`` (fused-kernel capable).

    Tied heads contract against the embedding table's last dim
    (``transpose_b`` — the dgrad-layout kernel on the fused path)."""
    logits = tape.dot("head/w", x, table_or_w, transpose_b=tied)
    return tape.act("head/logits", logits)
