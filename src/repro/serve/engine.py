"""Continuous-batching decode engine over a fixed slot array.

Replaces the lockstep loop (prefill a batch, decode everyone for exactly
``max_new`` steps) with a real request lifecycle:

  queued → admitted into a free slot (prefill) → decoding at its own
  position → finished (EOS or its own ``max_new``) → slot freed →
  next queued request admitted **mid-decode**.

Every device computation is fixed-shape and jitted once per shape:

* ``_decode`` runs over all ``max_slots`` rows each step — per-slot
  position vector (``transformer.decode_step`` with ``pos: [B]``),
  per-slot PRNG streams, one compile for the engine's lifetime.  Free
  slots decode garbage into their own cache rows; row independence means
  active slots are unaffected, and admission overwrites the row anyway.
* ``_prefill`` compiles per ``(group_size, prompt_len)``: admission
  groups queued requests of equal prompt length into one batch, so a
  burst of same-length requests costs one prefill — and an engine admitting
  B equal-length prompts into B free slots reproduces the lockstep
  engine's prefill bit-for-bit (the equivalence test's anchor).
  Variable-length prompts prefill as separate length groups, never
  padded — padding would perturb MoE capacity routing and SSM state.
  MoE models admit one request per prefill for the same reason: expert
  capacity is computed over the whole prefill batch, and the engine
  guarantees a request's tokens don't depend on who it shares with.
* ``_insert`` scatters the fresh cache entry into pool rows (axis 1) and,
  in packed mode, quantizes it first (``kv_pool.PackedKVCodec``).

The KV pool stores K/V float32 (bit-identical to ``transformer.init_cache``)
or as DFXP-packed int8/int16 mantissas with controller-managed per-slot
exponents (``cache_bits=8|16``) — halving/quartering cache HBM and hence
multiplying concurrent slot capacity.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ScaleState
from repro.core.policy import PrecisionPolicy
from repro.models import layers as L
from repro.models import transformer as T

from . import kv_pool, metrics, sampler

Array = jax.Array


@dataclasses.dataclass
class Request:
    """One generation request. ``tokens``: 1-D prompt ids."""

    uid: int
    tokens: np.ndarray
    max_new: int = 16
    eos_id: Optional[int] = None


class ServeEngine:
    """Continuous-batching engine over ``max_slots`` concurrent sequences.

    Parameters
    ----------
    cfg, policy, params: the functional model triple.
    max_slots: concurrent sequences (the decode batch shape).
    max_len: per-slot KV capacity; every request needs
        ``prompt_len + max_new <= max_len``.
    cache_bits: 0 → float32 KV pool (bit-identical to the lockstep
        engine); 8/16 → DFXP-packed mantissa pool.  With
        ``policy.fused_decode`` the decode attention runs as the fused
        Pallas flash-decode kernel straight on the pool's storage
        (packed mantissas dequantized in the tile loads — no per-layer
        f32 K/V materialization on the hot path).
    sampler_cfg: greedy / temperature / top-k, per-request PRNG streams.
    cache_cfg: overrides the packed pool's controller settings.
    """

    def __init__(self, cfg: T.ModelConfig, policy: PrecisionPolicy, params,
                 *, max_slots: int, max_len: int, cache_bits: int = 0,
                 sampler_cfg: sampler.SamplerConfig = sampler.SamplerConfig(),
                 cache_cfg: Optional[kv_pool.CacheQuantConfig] = None,
                 seed: int = 0, init_exp: float = -6.0):
        if cfg.input_mode != "tokens" or cfg.encoder_layers:
            raise ValueError("ServeEngine serves token-in decoder models")
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.cfg, self.policy, self.params = cfg, policy, params
        self.max_slots, self.max_len = max_slots, max_len
        self.sampler_cfg = sampler_cfg
        self.seed = seed
        gs = T.group_shapes(cfg)
        self.exps = ScaleState.create(gs, init_exp).exps
        self.sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                      for n, s in gs.items() if n.startswith("g:")}

        fused = bool(getattr(policy, "fused_decode", False))
        if cache_bits:
            self.cache_cfg = cache_cfg or kv_pool.CacheQuantConfig(
                width=cache_bits)
            if self.cache_cfg.width != cache_bits:
                raise ValueError("cache_bits and cache_cfg.width disagree")
            self.codec = kv_pool.PackedKVCodec(self.cache_cfg,
                                               fused_decode=fused)
        else:
            # f32 pool; with --fused-decode the raw codec still routes
            # attention through the flash-decode kernel (width=None)
            self.cache_cfg = None
            self.codec = L.RawKVCodec(fused_decode=True) if fused else None
        self._packed = bool(cache_bits)
        self._pool = kv_pool.make_pool(cfg, max_slots, max_len,
                                       self.codec if self._packed else None)

        # per-slot host state
        B = max_slots
        self._tok = np.zeros(B, np.int32)
        self._pos = np.zeros(B, np.int32)
        self._active = np.zeros(B, bool)
        self._reqs: List[Optional[Request]] = [None] * B
        self._gen: List[List[int]] = [[] for _ in range(B)]
        self._keys = np.zeros((B, 2), np.uint32)
        self._queue: collections.deque = collections.deque()
        self._results: Dict[int, np.ndarray] = {}
        self._next_uid = 0
        self._ovf = np.zeros(3, np.float64)   # harvested at request finish
        self.metrics = metrics.ServeMetrics()

        # the pool argument is donated: decode/insert rewrite it in place
        # instead of holding two full copies live (the packed pool exists
        # to shrink cache HBM — doubling it back would defeat the point)
        self._prefill = jax.jit(self._prefill_impl)   # per (g, L) shape
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self._decode = jax.jit(self._decode_impl, donate_argnums=(0,))
        self._slot_tot = jax.jit(kv_pool.slot_totals)
        # MoE prefill routes with a capacity computed over the whole batch,
        # so batching prompts would couple their routing — admit one at a
        # time to keep the solo == shared token guarantee exact
        self._admit_group_cap = 1 if cfg.num_experts else max_slots

    # -- jitted device steps ----------------------------------------------
    def _prefill_impl(self, tokens, keys):
        logits, _, cache = T.prefill(self.cfg, self.policy, self.params,
                                     {"tokens": tokens}, self.exps,
                                     self.sinks, max_cache_len=self.max_len)
        # first generated token sits at absolute position L = prompt length
        pos = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        first = sampler.sample(logits, sampler.position_keys(keys, pos),
                               self.sampler_cfg)
        return first, cache

    def _insert_impl(self, pool, entry, slots, keys):
        return kv_pool.insert(pool, entry, slots, self.codec, keys)

    def _decode_impl(self, pool, tok, pos, keys):
        logits, _, pool = T.decode_step(self.cfg, self.policy, self.params,
                                        pool, tok, pos, self.exps,
                                        self.sinks, kv_codec=self.codec)
        nxt = sampler.sample(logits, sampler.position_keys(keys, pos + 1),
                             self.sampler_cfg)
        return nxt, pool

    # -- request lifecycle -------------------------------------------------
    def submit(self, prompt, max_new: int = 16,
               eos_id: Optional[int] = None) -> int:
        """Queue one request; returns its uid."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt_len {prompt.size} + max_new {max_new} exceeds "
                f"max_len {self.max_len}")
        if self.cfg.family in ("ssm", "hybrid") and \
                prompt.size % self.cfg.ssm_chunk:
            raise ValueError(     # ssm_forward's prefill contract
                f"prompt_len {prompt.size} must be a multiple of "
                f"ssm_chunk {self.cfg.ssm_chunk} for {self.cfg.family}")
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(Request(uid, prompt, max_new, eos_id))
        self.metrics.on_submit(uid, prompt.size)
        return uid

    def _finish(self, slot: int) -> None:
        req = self._reqs[slot]
        self._results[req.uid] = np.asarray(self._gen[slot], np.int32)
        self.metrics.on_finish(req.uid)
        if self._packed:
            self._ovf += np.asarray(self._slot_tot(self._pool, slot),
                                    np.float64)
        self._active[slot] = False
        self._reqs[slot] = None

    def _maybe_finish(self, slot: int, tok: int) -> bool:
        """Finish the slot if its budget is spent or ``tok`` is its EOS."""
        req = self._reqs[slot]
        if len(self._gen[slot]) >= req.max_new or \
                (req.eos_id is not None and tok == req.eos_id):
            self._finish(slot)
            return True
        return False

    def _admit(self) -> None:
        """Fill free slots from the queue, grouping equal prompt lengths."""
        free = list(np.where(~self._active)[0])
        while self._queue and free:
            plen = self._queue[0].tokens.size
            cap = min(len(free), self._admit_group_cap)
            group: List[Request] = []
            while (self._queue and len(group) < cap
                   and self._queue[0].tokens.size == plen):
                group.append(self._queue.popleft())
            slots = [int(free.pop(0)) for _ in group]
            tokens = jnp.asarray(np.stack([r.tokens for r in group]))
            keys = jnp.stack([sampler.request_key(self.seed, r.uid)
                              for r in group])
            first, entry = self._prefill(tokens, keys)
            self._pool = self._insert(self._pool, entry,
                                      jnp.asarray(slots, jnp.int32), keys)
            first = np.asarray(first)
            for r, s, tok in zip(group, slots, first):
                self.metrics.on_admit(r.uid)
                self.metrics.on_token(r.uid)
                self._reqs[s], self._gen[s] = r, [int(tok)]
                self._tok[s], self._pos[s] = tok, plen
                self._keys[s] = np.asarray(
                    sampler.request_key(self.seed, r.uid))
                self._active[s] = True
                if self._maybe_finish(s, int(tok)):
                    free.append(s)

    def step(self) -> None:
        """Admit what fits, then decode one token on every active slot."""
        self._admit()
        if not self._active.any():
            return
        nxt, self._pool = self._decode(self._pool, jnp.asarray(self._tok),
                                       jnp.asarray(self._pos),
                                       jnp.asarray(self._keys))
        nxt = np.asarray(nxt)
        self.metrics.on_decode_step()
        for s in np.where(self._active)[0]:
            tok = int(nxt[s])
            self._gen[s].append(tok)
            self._pos[s] += 1
            self._tok[s] = tok
            self.metrics.on_token(self._reqs[s].uid)
            self._maybe_finish(s, tok)

    def run(self, max_steps: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Drive until the queue drains; returns ``{uid: generated ids}``."""
        budget = max_steps if max_steps is not None else (
            sum(t.max_new for t in list(self._queue))
            + sum(r.max_new for r in self._reqs if r is not None)
            + len(self._queue) + self.max_slots + 4)
        steps = 0
        while self._queue or self._active.any():
            if steps >= budget:
                raise RuntimeError(f"engine did not drain in {budget} steps")
            self.step()
            steps += 1
        return dict(self._results)

    # -- introspection -----------------------------------------------------
    def reset_metrics(self) -> None:
        """Start a fresh measurement window (latency/throughput/overflow).

        Aggregates otherwise span the engine's whole lifetime — on an
        engine reused across waves, ``wall_s`` includes host idle time
        between ``run()`` calls, so reset before a wave you want to
        measure in isolation.
        """
        self.metrics = metrics.ServeMetrics()
        self._ovf = np.zeros(3, np.float64)

    def cache_stats(self) -> dict:
        """Append overflow rate over finished requests + in-flight slots."""
        live = kv_pool.overflow_summary(self._pool, self._active)
        ovf = self._ovf[0] + live["cache_overflow_rate"] * \
            live["cache_appends_quantized"]
        tot = self._ovf[2] + live["cache_appends_quantized"]
        return {"cache_overflow_rate": float(ovf / tot) if tot else 0.0,
                "cache_appends_quantized": float(tot)}

    def stats(self) -> dict:
        return self.metrics.summary(extra=self.cache_stats())
