"""Serving CLI over the ``repro.serve`` continuous-batching engine.

Mixed-length prompts, per-request budgets, greedy/temperature/top-k
sampling, an optionally DFXP-packed KV-cache pool, the fused
flash-decode attention kernel (``--fused-decode``: dequantize in the
attention tile loads, no per-layer f32 K/V materialization), and
chunked prefill (``--prefill-chunk C``: immediate admission, one
C-token chunk per engine step interleaved with decode, one prefill jit
for any prompt length):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke \
      --num-requests 4 --prompt-len 8,16,32 --max-new 16 --cache-bits 8 \
      --fused-decode --prefill-chunk 8

Robustness controls: ``--queue-cap`` (reject-on-full admission),
``--deadline-ms`` (queued and in-flight expiry), and ``--chaos [SEED]``
(seeded fault-injection sweep — logit NaNs, KV bit flips, admission
delays, page squeezes — with the event log printed and optionally
written to ``--fault-log``).  A per-request status table prints at exit
either way; see ``repro.serve.engine.RequestStatus``.

``Engine`` below is the *lockstep reference*: batched prefill, then every
sequence decodes the same number of steps at one shared position. It frees
no slots and admits nothing mid-decode — kept (batch is implied by the
prompts' shape) because its greedy tokens are the bit-for-bit anchor the
float32-mode ``repro.serve.ServeEngine`` is tested against.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import ScaleState
from repro.core.policy import PrecisionPolicy
from repro.dist import MeshConfigError, serve_pod_ctx
from repro.launch.mesh import make_serve_mesh
from repro.models import transformer as T
from repro.serve import (
    EngineOptions,
    FaultHarness,
    SamplerConfig,
    ServeEngine,
    chaos_plan,
)


class Engine:
    """Lockstep reference: batched prefill + fixed-step greedy decode."""

    def __init__(self, cfg, policy, params, *, max_len: int):
        self.cfg, self.policy, self.params = cfg, policy, params
        self.max_len = max_len
        gs = T.group_shapes(cfg)
        self.exps = ScaleState.create(gs, -6.0).exps
        self.sinks = {n: jnp.zeros(s + (3,), jnp.float32)
                      for n, s in gs.items() if n.startswith("g:")}
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    def _prefill_impl(self, tokens):
        batch = {"tokens": tokens}
        logits, _, cache = T.prefill(self.cfg, self.policy, self.params,
                                     batch, self.exps, self.sinks,
                                     max_cache_len=self.max_len)
        return logits, cache

    def _decode_impl(self, cache, tok, pos):
        logits, _, cache = T.decode_step(self.cfg, self.policy, self.params,
                                         cache, tok, pos, self.exps,
                                         self.sinks)
        return logits, cache

    def generate(self, prompts: jnp.ndarray, max_new: int):
        """``prompts``: [B, S] token ids. Returns [B, max_new] (greedy)."""
        B, S = prompts.shape
        logits, cache = self._prefill(prompts)
        outs = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(max_new):
            outs.append(tok)
            logits, cache = self._decode(cache, tok, jnp.int32(S + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return jnp.stack(outs, axis=1)


def _parse_lens(spec: str):
    return [int(x) for x in spec.split(",") if x]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arithmetic", default="dfxp")
    ap.add_argument("--num-requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=0,
                    help="concurrent slots (default: min(num-requests, 4))")
    ap.add_argument("--prompt-len", default="32",
                    help="prompt length, or comma list cycled over requests "
                         "(mixed lengths prefill as separate length groups)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-bits", type=int, default=0, choices=(0, 8, 16),
                    help="KV-cache storage: 0=float32, 8/16=DFXP-packed "
                         "mantissas with per-slot controller-managed scales")
    ap.add_argument("--fused-decode", action="store_true",
                    help="run decode attention as the fused Pallas "
                         "flash-decode kernel directly on the KV pool's "
                         "storage (packed pools dequantize int mantissas "
                         "in the tile loads; no f32 K/V materialization)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: admit any request into any free "
                         "slot immediately and prefill C tokens per engine "
                         "step interleaved with decode (one jit for any "
                         "prompt length; chunk K/V quantized straight into "
                         "the packed pool). 0 = whole-prompt prefill (the "
                         "bit-for-bit reference). Attention-family archs "
                         "only; MoE/SSM stay on the whole-prompt path")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV pool: page size P in tokens (0 = "
                         "slot-major rings). Pages carry their own DFXP "
                         "exponents; requests sharing a prompt prefix map "
                         "the same pages (refcounted, copy-on-write on "
                         "divergence). Implies --prefill-chunk P unless "
                         "set. Dense global-attention archs only")
    ap.add_argument("--mesh", default="",
                    help="device mesh as DATAxMODEL (e.g. 2x1, 1x4): the "
                         "data axis shards the decode KV window (context "
                         "parallelism), the model axis shards the pool's "
                         "kv heads (tensor parallelism). Mutually "
                         "exclusive with --tp/--cp")
    ap.add_argument("--tp", type=int, default=1,
                    help="serving tensor parallelism: shard the KV pool's "
                         "kv-head axis over N devices (params replicated; "
                         "greedy streams bit-identical to single-device)")
    ap.add_argument("--cp", type=int, default=1,
                    help="serving context parallelism: shard the decode KV "
                         "window over N devices (long-context slots; exact "
                         "log-sum-exp merge). Slot-major pools only — "
                         "incompatible with --page-size")
    ap.add_argument("--sampler", default="greedy",
                    choices=("greedy", "temperature", "top_k"))
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="admission control: bound the waiting queue; a "
                         "submit finding it full resolves REJECTED (empty "
                         "result, terminal status) instead of queueing. "
                         "0 = unbounded")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline from submit; expired "
                         "requests (queued or mid-decode) resolve "
                         "TIMED_OUT with the tokens harvested so far. "
                         "0 = no deadline")
    ap.add_argument("--chaos", type=int, nargs="?", const=0, default=None,
                    metavar="SEED",
                    help="fault-injection sweep: drive a seeded random mix "
                         "of logit NaNs, KV bit flips, admission delays, "
                         "and (paged pools) a page squeeze through the "
                         "run, then print the fault log. The engine must "
                         "drain with terminal statuses either way")
    ap.add_argument("--fault-log", default="",
                    help="with --chaos: write the harness event log (JSON) "
                         "to this path")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(engine-step spans, request lifecycle instants, "
                         "fault events, queue counters) to this path — "
                         "open in chrome://tracing or ui.perfetto.dev")
    ap.add_argument("--numerics-log", default="",
                    help="write the §5 numeric-health timeline (per-layer/"
                         "per-slot KV exponents, overflow rates, controller "
                         "up/down moves) as JSONL to this path; packed "
                         "pools (--cache-bits 8|16) only")
    ap.add_argument("--numerics-every", type=int, default=0,
                    help="numerics sampling cadence in engine steps "
                         "(default: the cache controller's update "
                         "interval — one sample per decision window)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="serve the live metrics registry as Prometheus "
                         "text on http://127.0.0.1:PORT/metrics (stdlib "
                         "http.server; 0 picks an ephemeral port)")
    ap.add_argument("--metrics-out", default="",
                    help="append a final JSONL snapshot of the metrics "
                         "registry (counters/gauges/histograms) to this "
                         "path at exit")
    ap.add_argument("--profile", action="store_true",
                    help="profile kernel dispatch: per-bucket block-"
                         "selection calls, autotune cache hits/misses, "
                         "compiles and measured us, printed as a table "
                         "(and dumped to the trace when --trace-out)")
    args = ap.parse_args(argv)

    demo_chaos = args.chaos is not None and not args.smoke \
        and args.arch == "llama3_8b"
    if demo_chaos:
        # the bare `--chaos` sweep is a diagnostic demo: run it on the
        # smoke config with the stack that exercises every code path the
        # trace/numerics outputs exist to show — int8 packed pages
        # (controller moves), a deliberately tight page arena
        # (exhaustion -> preemption), a fast controller cadence
        args.smoke = True
        if args.cache_bits == 0:
            args.cache_bits = 8
        if args.page_size == 0:
            args.page_size = 4

    # mesh resolution: reject incoherent combinations here, as typed
    # MeshConfigErrors, instead of letting them surface as late jit or
    # GSPMD failures mid-serve
    tp, cp = args.tp, args.cp
    if args.mesh:
        if tp != 1 or cp != 1:
            raise MeshConfigError("--mesh and --tp/--cp are mutually "
                                  "exclusive; pick one spelling")
        try:
            cp, tp = (int(x) for x in args.mesh.lower().split("x"))
        except ValueError:
            raise MeshConfigError(
                f"--mesh {args.mesh!r} is not DATAxMODEL (e.g. 2x1, 1x4)")
    if cp > 1 and args.page_size:
        raise MeshConfigError(
            "--cp cannot shard a paged arena (--page-size): pages tile "
            "the window axis CP would shard — drop one of the two")
    dist = mesh = None
    if tp > 1 or cp > 1:
        dist = serve_pod_ctx(tp=tp, cp=cp)
        mesh = make_serve_mesh(tp=tp, cp=cp)   # raises if devices < tp*cp
        print(f"mesh: data={cp} (cp) x model={tp} (tp) over "
              f"{jax.device_count()} devices")

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    policy = PrecisionPolicy(args.arithmetic, fused_decode=args.fused_decode,
                             prefill_chunk=args.prefill_chunk,
                             page_size=args.page_size)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    lens = _parse_lens(args.prompt_len)
    slots = args.slots or min(args.num_requests, 4)
    scfg = SamplerConfig(kind=args.sampler, temperature=args.temperature,
                         top_k=args.top_k if args.sampler == "top_k" else 0)

    tracer = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer()
    num_log = None
    if args.numerics_log:
        from repro.obs import NumericsLog
        num_log = NumericsLog(args.numerics_log)
    if args.profile:
        from repro.kernels import dispatch
        dispatch.profile_enable(True)

    cache_cfg = None
    n_pages = None
    if demo_chaos and args.cache_bits:
        from repro.serve import CacheQuantConfig
        cache_cfg = CacheQuantConfig(width=args.cache_bits,
                                     update_interval=4)
        if args.page_size:
            # under-provision the arena: roughly two slots' worth of
            # pages short of full residency, so concurrent decode
            # exhausts it and the preemption path shows up on the trace
            nblocks = -(-(max(lens) + args.max_new) // args.page_size)
            n_pages = 1 + nblocks * max(slots - 2, 1)

    harness = None
    if args.chaos is not None:
        harness = FaultHarness(
            chaos_plan(args.chaos, list(range(args.num_requests)),
                       n_steps=4 * args.max_new,
                       squeeze_pages=4 if args.page_size else 0),
            seed=args.chaos)
    opts = EngineOptions(cache_bits=args.cache_bits, sampler_cfg=scfg,
                         cache_cfg=cache_cfg, n_pages=n_pages,
                         seed=args.seed,
                         queue_cap=args.queue_cap or None,
                         deadline_ms=args.deadline_ms or None,
                         faults=harness,
                         tracer=tracer, numerics_log=num_log,
                         numerics_every=args.numerics_every or None)
    max_len = max(lens) + args.max_new
    if cp > 1 and max_len % cp:
        max_len += cp - max_len % cp   # the KV window shards evenly
    eng = ServeEngine(cfg, policy, params, max_slots=slots,
                      max_len=max_len, options=opts,
                      dist=dist, mesh=mesh)
    server = None
    if args.metrics_port is not None:
        from repro.obs import start_http_server
        server = start_http_server(eng.metrics.registry, args.metrics_port)
        print(f"metrics: http://127.0.0.1:{server.server_address[1]}/metrics")
    uids = []
    for i in range(args.num_requests):
        plen = lens[i % len(lens)]
        prompt = jax.random.randint(jax.random.PRNGKey(1000 + i), (plen,), 0,
                                    cfg.vocab_size)
        uids.append(eng.submit(prompt, max_new=args.max_new))
    out = eng.run()
    stats = eng.stats()
    print(f"served {stats['requests_finished']} requests, "
          f"{stats['new_tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s, "
          f"ttft mean {stats['ttft_mean_s'] * 1e3:.0f}ms)")
    print("stats:", json.dumps({k: round(v, 4) if isinstance(v, float) else v
                                for k, v in stats.items()}))
    print("sample:", out[0][:8].tolist())
    print(f"{'uid':>5} {'status':>10} {'tokens':>7} {'preempts':>9}")
    for u in uids:
        st = eng.status(u)
        tr = eng.metrics.traces[u]
        print(f"{u:>5} {st.value if st else '?':>10} {out[u].size:>7} "
              f"{tr.preempts:>9}")
    if harness is not None:
        print("faults:", json.dumps(harness.summary()["event_counts"]))
        if args.fault_log:
            with open(args.fault_log, "w") as f:
                json.dump(harness.summary(), f, indent=2)
            print(f"fault log written to {args.fault_log}")
    if args.profile:
        from repro.kernels import dispatch
        if tracer is not None:
            dispatch.profile_trace_counters(tracer)
        print("dispatch profile:")
        print(dispatch.profile_table())
    if tracer is not None:
        spans = len(tracer.span_names())
        tracer.export(args.trace_out)
        print(f"trace: {spans} spans, {len(tracer.events)} events -> "
              f"{args.trace_out}")
    if num_log is not None:
        from repro.obs import count_moves
        print(f"numerics: {len(num_log.records)} records, "
              f"{count_moves(num_log.records)} controller moves -> "
              f"{args.numerics_log}")
        num_log.close()
    if args.metrics_out:
        eng.metrics.registry.snapshot_jsonl(args.metrics_out,
                                            {"final": True})
        print(f"metrics snapshot appended to {args.metrics_out}")
    if server is not None:
        server.shutdown()
    return out


if __name__ == "__main__":
    main()
