"""Fault-tolerant checkpointing without external deps (no orbax offline).

Layout per step::

    <dir>/step_000100/
        manifest.json      # tree structure, per-leaf shape/dtype/CRC32/file
        <leaf-id>.npy      # one .npy per leaf (host-gathered global array)
        _COMMITTED         # written last: restore ignores torn checkpoints

Design points for the 1000-node story:
  * **Elastic restore**: arrays are stored as *global* content + the
    manifest records logical shape/dtype only. ``restore_tree`` device_puts
    onto whatever mesh/sharding the *new* job provides — restarting on a
    different pod count (after node loss) reshards transparently.
  * **Integrity**: every leaf file carries a CRC32 in the manifest,
    verified on restore; a flipped bit on disk surfaces as a typed
    :class:`LeafCorruptError` naming the leaf instead of silently loading
    garbage into the optimizer.
  * **Durability**: every leaf file and the manifest are fsync'd, the
    directory is fsync'd, the tmp dir is atomically renamed into place,
    and only then is ``_COMMITTED`` written (and fsync'd).  A power cut
    at any point leaves either the previous checkpoint or a torn,
    ignored directory — never a committed lie.
  * **Async**: ``save_async`` snapshots to host memory synchronously
    (cheap) and writes files on a background thread, overlapping the next
    step.  A background-write failure is captured and re-raised on the
    next :meth:`~CheckpointManager.wait` / ``save_async`` — never
    swallowed.
  * **Retry**: transient write failures back off and retry
    (``retries``/``backoff_s``) before giving up.
  * **Retention**: keeps the newest ``keep`` committed checkpoints; the
    newest committed dir is never deleted, even mid-save of its successor.
  * Multi-host note: in a real multi-controller job each host would write
    only the shards it owns (`jax.experimental.multihost_utils`); in this
    single-controller container the process gathers full arrays.

PackedArray leaves (packed storage mode) round-trip transparently —
they're ordinary pytree nodes whose leaves are int16 mantissas + exps.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Optional

import jax
import numpy as np

Array = jax.Array


class CheckpointError(RuntimeError):
    """Base class for checkpoint integrity/IO failures."""


class LeafMismatchError(CheckpointError):
    """Checkpoint structure does not match the restore template
    (leaf count, or a leaf's shape/dtype), naming the offending leaf."""


class LeafCorruptError(CheckpointError):
    """A leaf file's bytes do not match the manifest CRC32."""


class CheckpointWriteError(CheckpointError):
    """A (possibly background) checkpoint write failed after retries."""


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts) or "<root>"


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_tree(tree: Any, path: str, *, fail_hook: Optional[Callable] = None,
              ) -> None:
    """Synchronous atomic save of a pytree of arrays.

    Write ordering (the durability contract): leaves + manifest into a
    ``.tmp`` dir, fsync every file, fsync the dir, ``os.replace`` into
    place, fsync the parent, and only then write + fsync ``_COMMITTED``.
    A crash anywhere before the marker leaves a torn dir that
    ``all_steps`` ignores.

    ``fail_hook(i)`` — fault-injection point for the chaos harness,
    called before writing leaf ``i``; it may raise to simulate a writer
    dying mid-save.
    """
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {"treedef": str(treedef), "leaves": []}
    for i, (leaf_path, leaf) in enumerate(leaves):
        if fail_hook is not None:
            fail_hook(i)
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        with open(fpath, "rb") as f:
            crc = zlib.crc32(f.read())
        _fsync_file(fpath)
        manifest["leaves"].append(
            {"file": fname, "name": _leaf_name(leaf_path),
             "shape": list(arr.shape), "dtype": str(arr.dtype),
             "crc32": crc})
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    parent = os.path.dirname(os.path.abspath(path))
    _fsync_dir(parent)
    cpath = os.path.join(path, "_COMMITTED")
    with open(cpath, "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(path)


def restore_tree(template: Any, path: str, shardings: Any = None, *,
                 verify: bool = True) -> Any:
    """Restore into ``template``'s structure; reshard onto ``shardings``.

    ``template`` may hold arrays or ShapeDtypeStructs; ``shardings`` (a
    matching pytree of NamedShardings, or None) controls placement — pass
    the *new* mesh's shardings to reshard elastically.

    Raises typed :class:`CheckpointError`\\ s naming the offending leaf:
    :class:`LeafMismatchError` on a leaf-count/shape/dtype mismatch with
    the template, :class:`LeafCorruptError` when a leaf file fails its
    manifest CRC32 (``verify=False`` skips the CRC pass only).
    """
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if len(manifest["leaves"]) != len(leaves_t):
        raise LeafMismatchError(
            f"checkpoint {path} has {len(manifest['leaves'])} leaves, "
            f"template has {len(leaves_t)}")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_t))
    out = []
    for meta, tmpl, sh in zip(manifest["leaves"], leaves_t, shard_leaves):
        name = meta.get("name", meta["file"])
        fpath = os.path.join(path, meta["file"])
        try:
            with open(fpath, "rb") as f:
                raw = f.read()
        except OSError as e:
            raise LeafCorruptError(
                f"leaf {name!r}: cannot read {fpath}: {e}") from e
        if verify and "crc32" in meta:
            crc = zlib.crc32(raw)
            if crc != meta["crc32"]:
                raise LeafCorruptError(
                    f"leaf {name!r}: CRC32 mismatch in {fpath} "
                    f"(manifest {meta['crc32']:#010x}, file {crc:#010x})")
        try:
            arr = np.load(io.BytesIO(raw))
        except Exception as e:
            raise LeafCorruptError(
                f"leaf {name!r}: {fpath} is not a loadable .npy: {e}") from e
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise LeafMismatchError(
                f"leaf {name!r}: checkpoint shape {tuple(arr.shape)} != "
                f"template shape {tuple(tmpl.shape)}")
        if np.dtype(meta["dtype"]) != np.dtype(tmpl.dtype):
            raise LeafMismatchError(
                f"leaf {name!r}: checkpoint dtype {meta['dtype']} != "
                f"template dtype {np.dtype(tmpl.dtype)}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, *, retries: int = 2,
                 backoff_s: float = 0.05):
        self.dir = directory
        self.keep = keep
        self.retries = retries
        self.backoff_s = backoff_s
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._inject_fail_saves = 0     # chaos harness: fail next N attempts

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self):
        steps = []
        for d in os.listdir(self.dir):
            if not d.startswith("step_"):
                continue
            try:
                step = int(d.split("_", 1)[1])
            except ValueError:
                continue               # .tmp / quarantined dirs
            if os.path.exists(os.path.join(self.dir, d, "_COMMITTED")):
                steps.append(step)
        return sorted(steps)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- fault injection (train/faults.CkptTear) ---------------------------
    def inject_failure(self, count: Optional[int] = None) -> None:
        """Make the next ``count`` save *attempts* die mid-write (default:
        enough to exhaust the retry budget, so the failure surfaces)."""
        self._inject_fail_saves = (count if count is not None
                                   else self.retries + 1)

    def _fail_hook(self, leaf_i: int) -> None:
        if self._inject_fail_saves > 0 and leaf_i == 1:
            self._inject_fail_saves -= 1
            raise CheckpointWriteError(
                "injected writer death mid-save (chaos harness)")

    # -- save/restore ------------------------------------------------------
    def _save_with_retry(self, step: int, tree: Any) -> None:
        path = self._step_dir(step)
        for attempt in range(self.retries + 1):
            try:
                save_tree(tree, path, fail_hook=self._fail_hook)
                return
            except Exception as e:
                shutil.rmtree(path + ".tmp", ignore_errors=True)
                if attempt == self.retries:
                    raise CheckpointWriteError(
                        f"checkpoint save of step {step} failed after "
                        f"{attempt + 1} attempts: {e}") from e
                time.sleep(self.backoff_s * (2 ** attempt))

    def save(self, step: int, tree: Any) -> None:
        self._save_with_retry(step, tree)
        self._gc()

    def save_async(self, step: int, tree: Any) -> None:
        """Snapshot to host now; write in the background.

        Raises any pending error from the *previous* background write
        (via the implicit :meth:`wait`) before starting the new one.
        """
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def _bg():
            try:
                self._save_with_retry(step, host_tree)
                self._gc()
            except BaseException as e:          # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_bg, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Join any in-flight background save; re-raise its failure."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore ``step`` (raises on any integrity error), or — with
        ``step=None`` — the newest committed step that passes
        verification, falling back to older committed steps past corrupt
        ones (:meth:`restore_latest`)."""
        if step is not None:
            return restore_tree(template, self._step_dir(step), shardings)
        tree, _ = self.restore_latest(template, shardings)
        return tree

    def restore_latest(self, template: Any, shardings: Any = None):
        """Restore the newest committed checkpoint that verifies clean.

        Returns ``(tree, step)``.  A committed dir that fails restore
        (CRC corruption, torn content) is quarantined — renamed to
        ``corrupt_<name>`` so it is never retried but the evidence
        survives — and the walk falls back to the previous committed
        step.  Raises ``FileNotFoundError`` when no committed checkpoint
        exists and :class:`CheckpointError` when all of them are corrupt.
        """
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        last_err: Optional[CheckpointError] = None
        for s in reversed(steps):
            path = self._step_dir(s)
            try:
                return restore_tree(template, path, shardings), s
            except CheckpointError as e:
                last_err = e
                quarantine = os.path.join(
                    self.dir, f"corrupt_{os.path.basename(path)}")
                shutil.rmtree(quarantine, ignore_errors=True)
                try:
                    os.replace(path, quarantine)
                except OSError:
                    shutil.rmtree(path, ignore_errors=True)
        raise CheckpointError(
            f"all {len(steps)} committed checkpoints in {self.dir} failed "
            f"verification; newest error: {last_err}")

    def _gc(self) -> None:
        """Prune to the newest ``keep`` committed steps.

        The newest committed dir is never deleted — even with
        ``keep=0``/``keep=1`` while its successor is still mid-save
        (uncommitted dirs are invisible to ``all_steps``, so the newest
        *committed* step stays the restore anchor until the successor's
        ``_COMMITTED`` lands).
        """
        if not self.keep:
            return
        steps = self.all_steps()
        for s in steps[:-max(self.keep, 1)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
