"""Data-pipeline determinism/sharding + serve-engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.data import SyntheticImages, SyntheticLM
from repro.launch.serve import Engine
from repro.models import transformer as T


def test_lm_deterministic_in_seed_step():
    a = SyntheticLM(1000, 32, 8, seed=3).batch(17)
    b = SyntheticLM(1000, 32, 8, seed=3).batch(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(1000, 32, 8, seed=4).batch(17)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_labels_shifted():
    b = SyntheticLM(1000, 32, 8, seed=0).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_lm_host_sharding_disjoint():
    h0 = SyntheticLM(1000, 16, 8, seed=5, host_id=0, num_hosts=2)
    h1 = SyntheticLM(1000, 16, 8, seed=5, host_id=1, num_hosts=2)
    assert h0.host_batch == h1.host_batch == 4
    t0, t1 = h0.batch(0)["tokens"], h1.batch(0)["tokens"]
    assert not np.array_equal(t0, t1)   # different streams per host


def test_images_resume_bit_identical():
    d = SyntheticImages()
    x1 = d.batch(42, 32)["x"]
    x2 = SyntheticImages().batch(42, 32)["x"]
    np.testing.assert_array_equal(x1, x2)


def test_engine_greedy_deterministic():
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, PrecisionPolicy("float32"), params, max_len=48)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab_size)
    out1 = eng.generate(prompts, max_new=6)
    out2 = eng.generate(prompts, max_new=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_engine_matches_teacher_forcing():
    """Greedy decode == argmax of full forward at every position."""
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    pol = PrecisionPolicy("float32")
    eng = Engine(cfg, pol, params, max_len=64)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0,
                                 cfg.vocab_size)
    out = np.asarray(eng.generate(prompts, max_new=4))

    toks = prompts
    for i in range(4):
        logits, _, _ = T.forward(cfg, pol, params, {"tokens": toks},
                                 eng.exps, eng.sinks, mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == int(out[0, i]), f"step {i}: {nxt} != {out[0, i]}"
        toks = jnp.concatenate([toks, jnp.array([[nxt]])], axis=1)


def test_serve_cli_constructs_serve_engine(capsys):
    """The CLI drives the repro.serve engine end-to-end (mixed lengths)."""
    from repro.launch.serve import main
    main(["--arch", "llama3_8b", "--smoke", "--arithmetic", "float32",
          "--num-requests", "2", "--prompt-len", "4,6", "--max-new", "2",
          "--slots", "2", "--cache-bits", "8"])
    out = capsys.readouterr().out
    assert "served 2 requests" in out
    assert "tok/s" in out
