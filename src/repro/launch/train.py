"""Training driver: calibrate → supervised DFXP train, fault-tolerant.

Fault-tolerance contract (the serve engine's, mirrored for training):
  * every step resolves to an outcome — OK / SKIPPED (device-side
    sentinel tripped, update discarded in-jit) / ROLLED_BACK (skip
    budget exhausted → restore last committed checkpoint, keep the
    advanced data cursor) / HALTED (rollback failed twice → diagnostic
    bundle) — and a per-run outcome table prints at exit;
  * checkpoint every ``--ckpt-every`` steps (async, atomic, CRC32'd,
    fsync'd, keeps ``--keep``); the saved tree covers params/opt/DFXP
    scales + §5 windows, the stochastic-rounding PRNG key, dist
    error-feedback buffers, and the data cursor — resume is bit-exact;
  * SIGTERM/SIGINT (preemption) → synchronous final checkpoint → 143;
  * restart with the same ``--ckpt-dir`` resumes from the latest clean
    committed step, walking past (and quarantining) corrupt ones;
  * ``--chaos [SEED]`` runs a seeded fault plan (NaN gradients, loss
    spikes, checkpoint tears, param bit flips) through the harness; the
    run must still resolve every step and exit 0.

CPU-runnable example:
  PYTHONPATH=src python -m repro.launch.train --arch granite_moe_1b \
      --smoke --steps 50 --global-batch 8 --seq-len 64 --arithmetic dfxp
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core.policy import PrecisionPolicy
from repro.data import SyntheticLM
from repro.models import transformer as T
from repro.optim.opt import OptConfig, adamw_init, sgd_init
from repro.train import (FaultHarness, Kill, StepOutcome, TrainSupervisor,
                         chaos_plan, init_train_state)
from repro.train.calibrate import calibrate


def build_policy(args) -> PrecisionPolicy:
    return PrecisionPolicy(
        arithmetic=args.arithmetic, comp_width=args.comp_width,
        update_width=args.update_width, update_interval=args.update_interval,
        storage=args.storage,
        max_overflow_rate=args.max_overflow_rate,
        fused_matmul=getattr(args, "fused_matmul", False))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_moe_1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--arithmetic", default="dfxp",
                    choices=["float32", "float16", "bfloat16", "fixed",
                             "dfxp"])
    ap.add_argument("--comp-width", type=int, default=10)
    ap.add_argument("--update-width", type=int, default=12)
    ap.add_argument("--update-interval", type=int, default=20)
    ap.add_argument("--max-overflow-rate", type=float, default=1e-4)
    ap.add_argument("--storage", default="sim", choices=["sim", "packed"])
    ap.add_argument("--fused-matmul", action="store_true",
                    help="route QTape.dot through the fused Pallas qmatmul "
                         "(fwd+dgrad+wgrad custom-VJP kernels; bit-identical "
                         "to the composite, compiled on TPU)")
    ap.add_argument("--calibrate-steps", type=int, default=5)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"])
    # -- resilience ---------------------------------------------------------
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3,
                    help="retained committed checkpoints (newest never GC'd)")
    ap.add_argument("--resume", default="auto",
                    choices=["auto", "never", "must"],
                    help="auto: resume when a committed checkpoint exists; "
                         "never: start fresh; must: fail fast if nothing "
                         "committed is restorable")
    ap.add_argument("--skip-budget", type=int, default=3,
                    help="consecutive sentinel-skipped steps tolerated "
                         "before rolling back to the last checkpoint")
    ap.add_argument("--runaway-ovf", type=float, default=0.0,
                    help="per-tensor-class §5 overflow-rate sentinel "
                         "threshold (0 disables)")
    ap.add_argument("--grad-compress-bits", type=int, default=0,
                    help="run gradients through error-feedback compression "
                         "at this width (residuals are checkpointed)")
    ap.add_argument("--chaos", nargs="?", type=int, const=0, default=None,
                    metavar="SEED",
                    help="run a seeded fault plan through the train harness "
                         "and print the fault log at exit")
    ap.add_argument("--kill-at", type=int, default=0,
                    help="SIGKILL the process at this data cursor (the CI "
                         "train-resume smoke's crash injection)")
    ap.add_argument("--fault-log", default="",
                    help="write the harness fault/event log as JSON here")
    ap.add_argument("--bundle-dir", default="",
                    help="where a HALTED run writes its diagnostic bundle "
                         "(default: <ckpt-dir>/bundle)")
    # -- observability ------------------------------------------------------
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--numerics-log", default="",
                    help="write the §5 numeric-health timeline (per-tensor-"
                         "class exponents, overflow rates, controller "
                         "up/down moves) as JSONL to this path")
    ap.add_argument("--numerics-every", type=int, default=0,
                    help="numerics sampling cadence in steps (default: the "
                         "controller's --update-interval)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    policy = build_policy(args)
    gs = T.group_shapes(cfg)
    opt_cfg = OptConfig(kind=args.optimizer, lr=args.lr,
                        lr_decay_steps=max(args.steps, 1000))
    key = jax.random.PRNGKey(args.seed)
    data = SyntheticLM(cfg.vocab_size, args.seq_len, args.global_batch,
                       seed=args.seed)

    def loss_fn(p, b, s, exps):
        return T.loss_fn(cfg, policy, p, b, exps, s)

    # --- calibration (paper §9.3), then reinitialize ------------------------
    init_exp = -8.0
    if policy.dynamic and args.calibrate_steps:
        obs_policy = dataclasses.replace(policy, arithmetic="observe",
                                         storage="sim")

        def obs_loss(p, b, s, exps):
            return T.loss_fn(cfg, obs_policy, p, b, exps, s)

        params0 = T.init_params(cfg, key)
        batches = ({k: jnp.asarray(v) for k, v in data.batch(i).items()}
                   for i in range(args.calibrate_steps))
        init_exp = calibrate(obs_loss, params0, gs, policy, opt_cfg,
                             batches, steps=args.calibrate_steps)
        print(f"calibrated {len(init_exp)} scale groups")

    params = T.init_params(cfg, jax.random.fold_in(key, 1))
    state = init_train_state(params, sgd_init(params) if
                             args.optimizer == "sgd" else adamw_init(params),
                             gs, policy, init_exp=init_exp)

    num_log = None
    if args.numerics_log:
        from repro.obs import NumericsLog
        num_log = NumericsLog(args.numerics_log)
    from repro.obs import MetricsRegistry, Tracer
    tracer = Tracer()
    metrics = MetricsRegistry()

    # --- fault harness ------------------------------------------------------
    faults = []
    if args.chaos is not None:
        faults = chaos_plan(args.chaos, n_steps=args.steps,
                            burst=args.skip_budget + 1)
        print(f"chaos plan (seed {args.chaos}): "
              f"{[type(f).__name__ for f in faults]}")
    if args.kill_at:
        faults.append(Kill(step=args.kill_at))
    harness = (FaultHarness(faults, seed=args.chaos or 0, tracer=tracer,
                            metrics=metrics) if faults else None)

    mgr = (CheckpointManager(args.ckpt_dir, keep=args.keep)
           if args.ckpt_dir else None)
    bundle_dir = args.bundle_dir or (
        args.ckpt_dir + "/bundle" if args.ckpt_dir else "train_bundle")

    def batch_fn(cursor):
        return {k: jnp.asarray(v) for k, v in data.batch(cursor).items()}

    sup = TrainSupervisor(
        loss_fn, gs, policy, opt_cfg, state,
        batch_fn=batch_fn, rng=key,
        manager=mgr, ckpt_every=args.ckpt_every,
        skip_budget=args.skip_budget,
        runaway_ovf=args.runaway_ovf or None,
        compress_bits=args.grad_compress_bits or None,
        microbatches=args.microbatches,
        faults=harness, tracer=tracer, metrics=metrics,
        numerics_log=num_log, numerics_every=args.numerics_every,
        bundle_dir=bundle_dir)

    # --- resume -------------------------------------------------------------
    if args.resume != "never" and mgr is not None:
        at = sup.resume()
        if at is not None:
            print(f"resumed from cursor {at}")
        elif args.resume == "must":
            print("error: --resume must, but nothing restorable",
                  file=sys.stderr)
            return sys.exit(2)

    stop = {"now": False}

    def _preempt(signum, frame):
        stop["now"] = True

    signal.signal(signal.SIGTERM, _preempt)
    signal.signal(signal.SIGINT, _preempt)

    # --- supervised loop ----------------------------------------------------
    t0 = time.perf_counter()
    remaining = max(args.steps - sup.cursor, 0)
    summary = sup.run(remaining, stop=lambda: stop["now"],
                      log_every=args.log_every)
    dt = time.perf_counter() - t0

    if stop["now"] and not sup.halted:
        print(f"preempted at cursor {sup.cursor}: final checkpoint written")

    # --- per-run outcome table (mirrors launch/serve.py) --------------------
    print(f"trained {summary['steps_committed']} steps in {dt:.1f}s "
          f"({summary['attempts']} attempts)")
    print(f"{'outcome':>12} {'count':>6}")
    for o in StepOutcome:
        print(f"{o.value:>12} {summary['outcomes'][o.value]:>6}")
    if summary["final_loss"] is not None:
        print(f"final loss: {summary['final_loss']:.4f}")
    print("summary:", json.dumps(
        {k: v for k, v in summary.items() if k != "outcomes"}, default=str))
    if harness is not None:
        print("faults:", json.dumps(harness.summary()["event_counts"]))
        if args.fault_log:
            with open(args.fault_log, "w") as f:
                json.dump({"harness": harness.summary(),
                           "run": summary}, f, indent=2, default=str)
            print(f"fault log written to {args.fault_log}")
    if num_log is not None:
        from repro.obs import count_moves
        print(f"numerics: {len(num_log.records)} records, "
              f"{count_moves(num_log.records)} controller moves -> "
              f"{args.numerics_log}")
        num_log.close()
    if sup.halted:
        print(f"HALTED: diagnostic bundle at {bundle_dir}", file=sys.stderr)
        return sys.exit(3)
    if stop["now"]:
        return sys.exit(143)
    print("done")
    return sup.state


if __name__ == "__main__":
    main()
