"""Continuous-batching serving with mixed-length prompts + int8 KV cache.

Six requests with three different prompt lengths share four slots: equal
lengths prefill together, the rest queue and get admitted as decoding
slots free up. The KV pool stores int8 DFXP mantissas with per-slot
controller-managed scales.

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    serve_main(["--arch", "llama3_8b", "--smoke", "--arithmetic", "dfxp",
                "--num-requests", "6", "--slots", "4",
                "--prompt-len", "8,16,32", "--max-new", "16",
                "--cache-bits", "8"])
