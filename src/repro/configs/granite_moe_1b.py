"""granite-moe-1b-a400m [moe]: 32 experts top-8, every layer MoE.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b", family="moe", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=8, head_dim=64, d_ff=512,
    # vocab 49155 padded to a multiple of 256 for 16-way vocab TP
    vocab_size=49408, num_experts=32, top_k=8, moe_d_ff=512,
    moe_period=1, rope_theta=1e4, tie_embeddings=True)

SMOKE = ModelConfig(
    name="granite-smoke", family="moe", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=64, vocab_size=512,
    num_experts=8, top_k=4, moe_d_ff=64, moe_period=1, tie_embeddings=True)

CELLS = ("train_4k", "prefill_32k", "decode_32k")
