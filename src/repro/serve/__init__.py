"""repro.serve — continuous batching over a DFXP-packed KV-cache pool."""
from .engine import Request, ServeEngine  # noqa: F401
from .kv_pool import (  # noqa: F401
    CacheQuantConfig,
    PackedKVCodec,
    insert,
    make_pool,
    overflow_summary,
)
from .metrics import RequestTrace, ServeMetrics  # noqa: F401
from .sampler import SamplerConfig, request_key, sample  # noqa: F401
