"""Assigned input shapes (4 per architecture) + ShapeDtypeStruct builders.

``input_specs`` returns weak-type-correct, shardable stand-ins (no device
allocation) for every model input of a given (arch, shape) cell — the same
pattern the dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

_I32 = jnp.int32
_F32 = jnp.float32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: T.ModelConfig, shape: ShapeSpec) -> dict:
    """Model-input stand-ins for one cell. For decode shapes this is the
    serve-step input: one new token + a full cache of ``seq_len``."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch: dict = {}
        if cfg.input_mode == "tokens":
            batch["tokens"] = sds((B, S), _I32)
        else:
            batch["embeds"] = sds((B, S, cfg.d_model), _F32)
            if cfg.mrope_sections:
                batch["positions"] = sds((3, B, S), _I32)
        if cfg.encoder_layers:
            batch["src_embeds"] = sds((B, S, cfg.d_model), _F32)
        if shape.kind == "train":
            batch["labels"] = sds((B, S), _I32)
        return {"batch": batch}
    # decode: cache of seq_len tokens + one new token
    src_len = S if cfg.encoder_layers else 0
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, B, S, src_len=src_len))
    tok = (sds((B,), _I32) if cfg.input_mode == "tokens"
           else sds((B, 1, cfg.d_model), _F32))
    return {"cache": cache, "tokens": tok, "pos": sds((), _I32)}
