"""Quickstart: the paper in one file — train the same maxout network under
fp32 / fp16 / fixed-20 / DFXP-10/12 and watch low precision match fp32.

Runs in ~2 minutes on CPU:
    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy
from repro.data import SyntheticImages
from repro.models import maxout as MX
from repro.optim.opt import OptConfig, sgd_init
from repro.train import init_train_state, make_train_step
from repro.train.calibrate import calibrate

STEPS = 150
cfg = MX.MaxoutConfig(hidden=(64, 64), pieces=3)
opt_cfg = OptConfig(kind="sgd", lr=0.1, lr_decay_steps=2000,
                    max_col_norm=1.9365)
data = SyntheticImages()
key = jax.random.PRNGKey(0)
gs = MX.group_shapes(cfg)


def run(policy, init_exp=-8.0):
    params = MX.init_params(cfg, jax.random.PRNGKey(7))
    state = init_train_state(params, sgd_init(params), gs, policy,
                             init_exp=init_exp)

    def loss_fn(p, b, s, exps):
        return MX.loss_fn(cfg, policy, p, b, exps, s,
                          rng=jax.random.PRNGKey(1))

    step = jax.jit(make_train_step(loss_fn, gs, policy, opt_cfg))
    for i in range(STEPS):
        b = data.batch(i, 64)
        state, m = step(state, {"x": jnp.asarray(b["x"]),
                                "y": jnp.asarray(b["y"])}, key)
    ev = data.eval_set(1024)
    acc = MX.accuracy(cfg, policy, state.params if policy.storage == "sim"
                      else jax.tree.map(lambda x: x, state.params),
                      {"x": jnp.asarray(ev["x"]), "y": jnp.asarray(ev["y"])},
                      state.scale.exps,
                      {n: jnp.zeros(s + (3,), jnp.float32)
                       for n, s in gs.items() if n.startswith("g:")})
    return float(m["loss"]), float(acc)


def main():
    # calibrate DFXP scales first (paper §9.3)
    dfxp = PrecisionPolicy("dfxp", comp_width=10, update_width=12,
                           update_interval=10)
    obs = dataclasses.replace(dfxp, arithmetic="observe")
    params0 = MX.init_params(cfg, key)

    def obs_loss(p, b, s, exps):
        return MX.loss_fn(cfg, obs, p, b, exps, s, rng=jax.random.PRNGKey(1))

    batches = ({"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}
               for b in (data.batch(i, 64) for i in range(10)))
    init_exp = calibrate(obs_loss, params0, gs, dfxp, opt_cfg, batches,
                         steps=8)

    rows = [
        ("float32 (baseline)", PrecisionPolicy("float32"), -8.0),
        ("float16", PrecisionPolicy("float16"), -8.0),
        ("fixed point 20/20", PrecisionPolicy("fixed", comp_width=20,
                                              update_width=20), -8.0),
        ("dfxp 10/12 (paper)", dfxp, init_exp),
    ]
    print(f"{'format':22s} {'final loss':>10s} {'eval acc':>9s}")
    for name, pol, ie in rows:
        loss, acc = run(pol, ie)
        print(f"{name:22s} {loss:10.4f} {acc:9.3f}")


if __name__ == "__main__":
    main()
