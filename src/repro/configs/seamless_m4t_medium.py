"""seamless-m4t-medium [audio]: enc-dec backbone; audio frontend is a stub
(input_specs provides precomputed frame embeddings). [arXiv:2308.11596]

Simplification (documented): RoPE positions instead of the original
sinusoidal/relative scheme.
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", num_layers=12,
    encoder_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    head_dim=64, d_ff=4096, vocab_size=256256, ffn_kind="gelu",  # 256206 padded to %256 for vocab TP
    rope_theta=1e4, tie_embeddings=False)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec", num_layers=3, encoder_layers=2,
    d_model=128, num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256,
    vocab_size=512, ffn_kind="gelu", tie_embeddings=False)

# full attention -> long_500k skipped; decode runs (it has a decoder stack)
CELLS = ("train_4k", "prefill_32k", "decode_32k")
