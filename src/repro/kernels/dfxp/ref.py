"""Pure-jnp oracle for the DFXP quantize kernel (== core.quant.fixed_round)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant import exact_pow2


def dfxp_quantize_ref(x, e, *, width: int):
    """Returns (y, stats[2]) — reference for kernels.dfxp."""
    step = exact_pow2(e)
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))
    m = jnp.round(x.astype(jnp.float32) / step)
    ovf = jnp.sum((m > qmax) | (m < qmin), dtype=jnp.float32)
    ovfh = jnp.sum((m > qmax / 2) | (m < qmin / 2), dtype=jnp.float32)
    y = (jnp.clip(m, qmin, qmax) * step).astype(x.dtype)
    return y, jnp.stack([ovf, ovfh])
