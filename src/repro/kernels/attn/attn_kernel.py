"""Pallas TPU kernel: fused flash-decode attention over a packed KV pool.

Single-query (decode) GQA attention computed **directly on the pool's
storage containers**: tiles of int8/int16 K/V mantissas stream from HBM,
are dequantized in-register against the per-layer/per-slot power-of-two
step (``value = mantissa * 2**e``, the
:class:`repro.serve.kv_pool.PackedKVCodec` layout), and feed an
online-softmax accumulator — so the f32 K/V never materializes and an
int8 cache really does read 4× fewer HBM bytes than float32 (the win
``codec.load`` + einsum throws away by widening first).

Grid layout (compiled path)::

        grid = (B, K, nsplit)            nsplit = W_padded / block_w

        q     [B, K, G, hd]   -> tile [G, hd]        (one kv-head's group)
        k/v   [B, W, K, hd]   -> tile [block_w, hd]  (int8/int16/f32)
        pos   [B, W]          -> tile [1, block_w]   (ring positions)
        out   [B, K, G, hd]   <- written on the last split

The split axis is innermost/sequential: VMEM scratch carries the running
``(m, l, acc)`` — partial max, softmax denominator, weighted-value
numerator — across splits (flash combine: ``corr = exp(m_old - m_new)``
rescales both accumulators), and the final reduction ``acc / l`` happens
once on the last split.  Masked lanes (empty slots ``pos < 0``, future
positions, outside the sliding window) contribute an exact 0, and a
ragged last split is handled **in-kernel** by a slot-index bounds mask
(lanes ``>= W`` are dropped and their V rows zeroed) — the wrapper never
pads the K/V buffers, because a ``jnp.pad`` copy of the whole pool per
layer per token would reintroduce exactly the HBM round-trip this kernel
exists to eliminate.

Interpret mode (any non-TPU backend) instead runs ONE grid step on
full-shape blocks and executes :func:`repro.kernels.attn.ref.attend`
verbatim on the dequantized arrays — identical ops on identical shapes,
which makes the fused kernel **bit**-identical to the composite on CPU
(the same contract the qmatmul family keeps, and what the serve tests
pin).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as R

try:  # TPU-specific memory spaces; without them interpret mode falls back
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover — to the scratch-free batched body
    pltpu = None
    _VMEM = None


def _dequant(tile, step, width):
    """Tile load: int mantissas × power-of-two step (``width=None`` → raw)."""
    if width is None:
        return tile.astype(jnp.float32)
    return tile.astype(jnp.float32) * step


def _split_kernel(qpos_ref, steps_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, width, scale: float, window,
                  causal: bool, nsplit: int, G: int, hd: int, block_w: int,
                  W: int):
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, -jnp.inf, m_ref.dtype)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qf = q_ref[...].reshape(G, hd)
    kf = _dequant(k_ref[...].reshape(block_w, hd), steps_ref[0, 0], width)
    vf = _dequant(v_ref[...].reshape(block_w, hd), steps_ref[0, 1], width)
    pos = pos_ref[...]                          # [1, block_w] int32
    # ragged tail: lanes past the true window length read out-of-bounds
    # garbage — mask them by global slot index, and zero their V rows so
    # the 0-probability × garbage product in the PV dot stays an exact 0
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, block_w), 1)
    inb = r * block_w + lane < W
    vf = jnp.where(inb.reshape(block_w, 1), vf, 0.0)
    d = qpos_ref[0, 0] - pos
    valid = inb & (pos >= 0)
    if causal:
        valid = valid & (d >= 0)
    if window:
        valid = valid & (d < window)

    s = jax.lax.dot_general(qf, kf, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid, s, -1e30)              # [G, block_w]
    m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_ref[...] - m_new)          # exp(-inf - m) == 0 on init
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, vf, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(r == nsplit - 1)
    def _done():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out.reshape(1, 1, G, hd).astype(o_ref.dtype)


def _batched_kernel(qpos_ref, steps_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                    *, width, scale: float, window, causal: bool):
    """One grid step, full-shape blocks: ref.attend on the loaded arrays."""
    exp = (slice(None), None, None, None)
    kf = _dequant(k_ref[...], steps_ref[...][:, 0][exp], width)
    vf = _dequant(v_ref[...], steps_ref[...][:, 1][exp], width)
    o_ref[...] = R.attend(q_ref[...], kf, vf, pos_ref[...], qpos_ref[:, 0],
                          scale=scale, window=window, causal=causal)


@functools.partial(jax.jit, static_argnames=(
    "width", "block_w", "scale", "window", "causal", "interpret"))
def flash_decode_call(q, k, v, pos, qpos, steps, *, width, block_w: int,
                      scale: float, window, causal: bool, interpret: bool):
    """Blocked flash-decode over the raw (unpadded) pool buffers.

    ``q``: f32 [B, K, G, hd] · ``k``/``v``: int8/int16/f32 [B, W, K, hd] ·
    ``pos``: int32 [B, W] · ``qpos``: int32 [B, 1] · ``steps``: f32
    [B, 2] dequant steps ``[2**k_e, 2**v_e]`` (ignored for
    ``width=None``).  Returns f32 [B, K, G, hd].  ``W`` need not be a
    ``block_w`` multiple — the ragged tail is masked in-kernel.
    ``block_w >= W`` in interpret mode runs the single-step full-shape
    body (bit-identical to ``ref.attend``).
    """
    B, K, G, hd = q.shape
    W = k.shape[1]
    out_shape = jax.ShapeDtypeStruct((B, K, G, hd), jnp.float32)

    if interpret and (block_w >= W or _VMEM is None):
        # no pltpu → the split path's VMEM scratch is unavailable; the
        # full-shape body is the same math, just unsplit
        return pl.pallas_call(
            functools.partial(_batched_kernel, width=width, scale=scale,
                              window=window, causal=causal),
            out_shape=out_shape,
            interpret=True,
        )(qpos, steps, q, k, v, pos)
    if _VMEM is None:  # pragma: no cover — compiled TPU implies pltpu
        raise RuntimeError(
            "split-K flash-decode needs jax.experimental.pallas.tpu "
            "memory spaces for its VMEM scratch")

    nsplit = pl.cdiv(W, block_w)
    return pl.pallas_call(
        functools.partial(_split_kernel, width=width, scale=scale,
                          window=window, causal=causal, nsplit=nsplit,
                          G=G, hd=hd, block_w=block_w, W=W),
        grid=(B, K, nsplit),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, r: (b, 0)),           # qpos
            pl.BlockSpec((1, 2), lambda b, h, r: (b, 0)),           # steps
            pl.BlockSpec((1, 1, G, hd), lambda b, h, r: (b, h, 0, 0)),
            pl.BlockSpec((1, block_w, 1, hd), lambda b, h, r: (b, r, h, 0)),
            pl.BlockSpec((1, block_w, 1, hd), lambda b, h, r: (b, r, h, 0)),
            pl.BlockSpec((1, block_w), lambda b, h, r: (b, r)),     # pos
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, r: (b, h, 0, 0)),
        out_shape=out_shape,
        scratch_shapes=[_VMEM((G, 1), jnp.float32),    # running max
                        _VMEM((G, 1), jnp.float32),    # denominator
                        _VMEM((G, hd), jnp.float32)],  # numerator
        interpret=interpret,
    )(qpos, steps, q, k, v, pos)


# -- paged variant: one extra block-table indirection ---------------------
#
# The paged pool (repro.serve.paged) stores K/V as [n_pages, P, K, hd]
# arenas with per-PAGE exponents and maps logical token blocks through a
# per-request block table bt [B, nblocks].  The split axis becomes the
# page axis: split r of batch row b streams physical page bt[b, r] —
# expressed as a scalar-prefetch index_map (PrefetchScalarGridSpec), so
# the gather happens in the tile DMA, not as a host-side copy of the
# arena.  No ragged-tail mask is needed (Wp = nblocks·P exactly); rows
# the request never wrote — including every row of the null page 0 —
# carry pos == -1 and mask out like empty ring slots.


def _paged_split_kernel(bt_ref, qpos_ref, steps_ref, q_ref, k_ref, v_ref,
                        pos_ref, o_ref, m_ref, l_ref, acc_ref, *, width,
                        scale: float, window, causal: bool, nblocks: int,
                        G: int, hd: int, P: int):
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, -jnp.inf, m_ref.dtype)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qf = q_ref[...].reshape(G, hd)
    kf = _dequant(k_ref[...].reshape(P, hd), steps_ref[0, 0], width)
    vf = _dequant(v_ref[...].reshape(P, hd), steps_ref[0, 1], width)
    pos = pos_ref[...]                          # [1, P] logical positions
    d = qpos_ref[0, 0] - pos
    valid = pos >= 0
    if causal:
        valid = valid & (d >= 0)
    if window:
        valid = valid & (d < window)

    s = jax.lax.dot_general(qf, kf, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid, s, -1e30)              # [G, P]
    m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, vf, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(r == nblocks - 1)
    def _done():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out.reshape(1, 1, G, hd).astype(o_ref.dtype)


def _paged_batched_kernel(bt_ref, qpos_ref, steps_ref, q_ref, k_ref, v_ref,
                          pos_ref, o_ref, *, width, scale: float, window,
                          causal: bool):
    """One grid step, full shapes: the ref composite through the gather."""
    bt = bt_ref[...]
    kf = jnp.take(k_ref[...], bt, axis=0).astype(jnp.float32)
    vf = jnp.take(v_ref[...], bt, axis=0).astype(jnp.float32)
    if width is not None:
        kf = kf * jnp.take(steps_ref[...][:, 0], bt)[..., None, None, None]
        vf = vf * jnp.take(steps_ref[...][:, 1], bt)[..., None, None, None]
    B, nblocks, P = kf.shape[:3]
    shp = (B, nblocks * P) + kf.shape[3:]
    o_ref[...] = R.attend(q_ref[...], kf.reshape(shp), vf.reshape(shp),
                          pos_ref[...], qpos_ref[:, 0], scale=scale,
                          window=window, causal=causal)


@functools.partial(jax.jit, static_argnames=(
    "width", "scale", "window", "causal", "interpret", "force_split"))
def flash_decode_paged_call(q, k, v, bt, pos, qpos, steps, *, width,
                            scale: float, window, causal: bool,
                            interpret: bool, force_split: bool = False):
    """Blocked flash-decode through a per-request block table.

    ``q``: f32 [B, K, G, hd] · ``k``/``v``: int8/int16/f32
    [n_pages, P, K, hd] page arenas · ``bt``: int32 [B, nblocks] ·
    ``pos``: int32 [B, nblocks·P] logical positions (-1 = empty) ·
    ``qpos``: int32 [B, 1] · ``steps``: f32 [n_pages, 2] per-page dequant
    steps.  Returns f32 [B, K, G, hd].  Interpret mode runs the
    full-shape gather body (bit-identical to
    ``ref.paged_decode_attention_ref``) unless ``force_split`` exercises
    the scalar-prefetch split path (same math, split-order softmax).
    """
    B, K, G, hd = q.shape
    P = k.shape[1]
    nblocks = bt.shape[1]
    out_shape = jax.ShapeDtypeStruct((B, K, G, hd), jnp.float32)

    if interpret and not force_split:
        return pl.pallas_call(
            functools.partial(_paged_batched_kernel, width=width, scale=scale,
                              window=window, causal=causal),
            out_shape=out_shape,
            interpret=True,
        )(bt, qpos, steps, q, k, v, pos)
    if pltpu is None:  # pragma: no cover — compiled TPU implies pltpu
        raise RuntimeError(
            "paged flash-decode needs jax.experimental.pallas.tpu for "
            "scalar-prefetch block-table index maps")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                   # bt rides ahead of tiles
        grid=(B, K, nblocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, r, bt: (b, 0)),        # qpos
            pl.BlockSpec((1, 2), lambda b, h, r, bt: (bt[b, r], 0)),  # steps
            pl.BlockSpec((1, 1, G, hd), lambda b, h, r, bt: (b, h, 0, 0)),
            pl.BlockSpec((1, P, 1, hd),
                         lambda b, h, r, bt: (bt[b, r], 0, h, 0)),   # k page
            pl.BlockSpec((1, P, 1, hd),
                         lambda b, h, r, bt: (bt[b, r], 0, h, 0)),   # v page
            pl.BlockSpec((1, P), lambda b, h, r, bt: (b, r)),        # pos
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, r, bt: (b, h, 0, 0)),
        scratch_shapes=[_VMEM((G, 1), jnp.float32),    # running max
                        _VMEM((G, 1), jnp.float32),    # denominator
                        _VMEM((G, hd), jnp.float32)],  # numerator
    )
    return pl.pallas_call(
        functools.partial(_paged_split_kernel, width=width, scale=scale,
                          window=window, causal=causal, nblocks=nblocks,
                          G=G, hd=hd, P=P),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(bt, qpos, steps, q, k, v, pos)
