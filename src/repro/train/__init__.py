"""Training: DFXP train step, state, loop."""
from .state import TrainState, init_train_state, param_group_shapes  # noqa: F401
from .step import make_train_step, quantize_param  # noqa: F401
