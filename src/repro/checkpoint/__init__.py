"""Elastic checkpointing: manifest + per-leaf arrays, restore-with-reshard."""
from .manager import (CheckpointError, CheckpointManager,  # noqa: F401
                      CheckpointWriteError, LeafCorruptError,
                      LeafMismatchError, restore_tree, save_tree)
