"""Core of the paper: numeric formats, quantizers, DFXP scale control."""
from .formats import (  # noqa: F401
    BFLOAT16,
    FLOAT8_E4M3,
    FLOAT8_E5M2,
    FLOAT16,
    FLOAT32,
    FLOAT_FORMATS,
    DynamicFixedPoint,
    FixedPoint,
    FloatFormat,
    Format,
    container_exact_bits,
)
from .packed import PackedArray, pack, pack_overflow_stats, unpack  # noqa: F401
from .policy import (  # noqa: F401
    DFXP_10_12,
    FIXED_20,
    HALF_FLOAT,
    SINGLE_FLOAT,
    PrecisionPolicy,
)
from .quant import (  # noqa: F401
    fixed_round,
    float_round,
    new_sink,
    q_stats,
    q_value,
    qbound,
    ste_quant,
)
from .scale import ScaleState, accumulate, calibrate_exp, controller_step  # noqa: F401
from .tape import QTape, null_tape  # noqa: F401
