# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# `dispatch` is the production entry point: differentiable fused DFXP
# matmul (custom-VJP fwd/dgrad/wgrad) with autotuned block selection
# and backend detection. The per-kernel packages stay importable on
# their own for tests/benchmarks.
from .dispatch import fused_dot, tape_dot  # noqa: F401
