"""gemma3-27b [dense]: 5:1 local:global attention, 262k vocab.

Local layers: sliding window 1024, rope theta 10k; every 6th layer global
(theta 1M). 62 layers = 10 full (5L+1G) super-blocks + 2 trailing local.
[hf:google/gemma-3-27b-pt]
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense", num_layers=62, d_model=5376,
    num_heads=32, num_kv_heads=16, head_dim=128, d_ff=21504,
    vocab_size=262144, window=1024, local_global_pattern=5,
    local_rope_theta=1e4, rope_theta=1e6, embed_scale=True,
    qk_norm=True, tie_embeddings=True)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense", num_layers=7, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    window=16, local_global_pattern=2, local_rope_theta=1e4,
    embed_scale=True, qk_norm=True, tie_embeddings=True)

# 5/6 layers sub-quadratic (window cache); global layers decode O(S) with a
# sequence-sharded cache -> long_500k runs (DESIGN.md §6)
CELLS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
