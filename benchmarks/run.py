# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Paper artifacts (Table 3, Figures 1-4) train the maxout network under
# each arithmetic on the scaled synthetic task; ``derived`` is the final
# loss normalized by the fp32 baseline (the paper's normalized test error).
# Kernel rows report microseconds per call; ``derived`` is MFLOP for
# matmuls. Run with: PYTHONPATH=src python -m benchmarks.run [--quick]
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="table3 + kernels only")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from . import kernels_bench, paper_tables

    suites = [
        ("table3", paper_tables.table3_formats),
        ("fig1", paper_tables.fig1_radix),
        ("fig2", paper_tables.fig2_comp_width),
        ("fig3", paper_tables.fig3_update_width),
        ("fig4", paper_tables.fig4_overflow_rate),
        ("kernels", kernels_bench.run),
    ]
    if args.quick:
        suites = [s for s in suites if s[0] in ("table3", "kernels")]
    if args.only:
        suites = [s for s in suites if s[0] in args.only.split(",")]

    print("name,us_per_call,derived")
    for name, fn in suites:
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]:.4f}", flush=True)
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0,0  # {e}", file=sys.stderr)
            raise


if __name__ == '__main__':
    main()
