"""Unit + property tests for the core quantization machinery (paper §3-§7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    from hypothesis.extra import numpy as hnp
except ImportError:  # only the @given property tests need hypothesis
    class _MissingStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = hnp = _MissingStrategies()

    def given(**kwargs):
        return pytest.mark.skip(reason="optional hypothesis dep not installed")

    def settings(**kwargs):
        return lambda f: f

from repro.core import (
    FLOAT8_E4M3,
    FLOAT16,
    DynamicFixedPoint,
    PrecisionPolicy,
    ScaleState,
    accumulate,
    calibrate_exp,
    controller_step,
    fixed_round,
    float_round,
    new_sink,
    pack,
    q_stats,
    q_value,
    qbound,
    ste_quant,
    unpack,
)

jax.config.update("jax_enable_x64", False)

finite_f32 = hnp.arrays(
    np.float32,
    st.integers(1, 64),
    elements=st.floats(-1e4, 1e4, width=32, allow_nan=False, allow_infinity=False),
)


# ---------------------------------------------------------------------------
# fixed_round properties
# ---------------------------------------------------------------------------

@given(x=finite_f32, width=st.integers(2, 24), e=st.integers(-20, 5))
@settings(deadline=None, max_examples=60)
def test_fixed_round_on_grid_and_bounded(x, width, e):
    y, (ovf, ovfh) = fixed_round(jnp.asarray(x), width, jnp.float32(e))
    y = np.asarray(y, np.float64)
    step = 2.0 ** e
    qmax, qmin = (2 ** (width - 1) - 1) * step, -(2 ** (width - 1)) * step
    # every output is an exact grid point within range
    k = y / step
    np.testing.assert_allclose(k, np.round(k), atol=0)
    assert y.max(initial=qmin) <= qmax + 1e-9
    assert y.min(initial=qmax) >= qmin - 1e-9
    # error bound: |x - y| <= step/2 for non-overflowing values
    m = np.round(x.astype(np.float64) / step)
    inside = (m <= 2 ** (width - 1) - 1) & (m >= -(2 ** (width - 1)))
    np.testing.assert_array_less(np.abs(x[inside] - y[inside]), step / 2 + 1e-12)
    # overflow counts match a numpy oracle
    assert float(ovf) == np.sum(~inside)
    mh = 2 ** (width - 1) - 1
    assert float(ovfh) == np.sum((m > mh / 2) | (m < -(2 ** (width - 1)) / 2))


@given(x=finite_f32, width=st.integers(3, 16), e=st.integers(-12, 3))
@settings(deadline=None, max_examples=40)
def test_fixed_round_idempotent(x, width, e):
    y1, _ = fixed_round(jnp.asarray(x), width, jnp.float32(e))
    y2, _ = fixed_round(y1, width, jnp.float32(e))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_fixed_round_stochastic_unbiased():
    key = jax.random.PRNGKey(0)
    x = jnp.full((20000,), 0.3)
    y, _ = fixed_round(x, 8, jnp.float32(0), stochastic=True, key=key)
    assert abs(float(y.mean()) - 0.3) < 0.02  # E[y] = x
    assert set(np.unique(np.asarray(y))) <= {0.0, 1.0}


# ---------------------------------------------------------------------------
# float emulation
# ---------------------------------------------------------------------------

def test_float_round_fp16_matches_cast():
    x = jnp.asarray(np.random.RandomState(0).randn(256).astype(np.float32) * 100)
    np.testing.assert_array_equal(
        np.asarray(float_round(x, FLOAT16)),
        np.asarray(x.astype(jnp.float16).astype(jnp.float32)),
    )


def test_float_round_generic_agrees_with_cast_fp16():
    # the generic (e,m) path should agree with hardware fp16 on normals
    from repro.core.formats import FloatFormat
    generic = FloatFormat("generic_fp16", 5, 10)
    x = jnp.asarray(np.random.RandomState(1).uniform(2**-10, 1e4, 512).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(float_round(x, generic)),
        np.asarray(x.astype(jnp.float16).astype(jnp.float32)),
        rtol=0, atol=0,
    )


def test_float8_saturates():
    y = float_round(jnp.array([1e9, -1e9]), FLOAT8_E4M3)
    assert float(y[0]) == FLOAT8_E4M3.maxval
    assert float(y[1]) == -FLOAT8_E4M3.maxval


# ---------------------------------------------------------------------------
# qbound: forward/backward format split + sink statistics
# ---------------------------------------------------------------------------

def test_qbound_forward_uses_act_format_backward_uses_grad_format():
    fmt_a, fmt_g = DynamicFixedPoint(8), DynamicFixedPoint(4)
    x = jnp.array([0.30, 2.0])

    def f(x, sink):
        y = qbound(x, fmt_a, fmt_g, jnp.float32(-4), jnp.float32(-1), sink)
        return jnp.sum(y * jnp.array([1.0, 0.3]))

    y = qbound(x, fmt_a, fmt_g, jnp.float32(-4), jnp.float32(-1), new_sink())
    np.testing.assert_allclose(np.asarray(y), [0.3125, 2.0])  # 8-bit grid @ 2^-4
    g, s = jax.grad(f, argnums=(0, 1))(x, new_sink())
    # cotangents (1.0, 0.3) on the 4-bit grid @ 2^-1: 1.0, 0.5
    np.testing.assert_allclose(np.asarray(g), [1.0, 0.5])
    assert float(s[2]) == 2.0  # n_total

def test_qbound_sink_counts_backward_overflow():
    fmt = DynamicFixedPoint(8)  # qmax 127

    def f(x, sink):
        y = qbound(x, fmt, fmt, jnp.float32(0), jnp.float32(0), sink)
        return jnp.sum(y * jnp.array([1.0, 500.0, 80.0]))

    g, s = jax.grad(f, argnums=(0, 1))(jnp.ones(3), new_sink())
    assert float(s[0]) == 1.0          # 500 overflows qmax=127
    assert float(s[1]) == 2.0          # 500 and 80 overflow at half scale
    assert float(s[2]) == 3.0
    np.testing.assert_allclose(np.asarray(g), [1.0, 127.0, 80.0])


def test_qbound_scan_stacks_per_layer_stats():
    fmt = DynamicFixedPoint(8)

    def loss(x, sinks):
        def body(c, s):
            return qbound(c, fmt, fmt, jnp.float32(-4), jnp.float32(-4), s) * 2.0, None
        out, _ = jax.lax.scan(body, x, sinks)
        return jnp.sum(out)

    sinks = jnp.zeros((6, 3))
    _, gs = jax.jit(jax.grad(loss, argnums=(0, 1)))(jnp.ones(4) * 0.5, sinks)
    assert gs.shape == (6, 3)
    np.testing.assert_allclose(np.asarray(gs[:, 2]), 4.0)  # n_total per layer


def test_ste_quant_identity_gradient():
    fmt = DynamicFixedPoint(6)
    g = jax.grad(lambda w: jnp.sum(ste_quant(w, fmt, jnp.float32(-2)) * 3.0))(
        jnp.array([0.3, 10.0]))
    np.testing.assert_allclose(np.asarray(g), [3.0, 3.0])


# ---------------------------------------------------------------------------
# scale controller (paper §5 rule)
# ---------------------------------------------------------------------------

def _state(e0=-8.0):
    return ScaleState.create({"g": ()}, init_exp=e0)


def test_controller_raises_scale_on_overflow():
    st = accumulate(_state(), {"g": jnp.array([50.0, 60.0, 10000.0])})
    st = controller_step(st, max_overflow_rate=1e-4, apply=jnp.bool_(True))
    assert float(st.exps["g"]) == -7.0
    assert float(st.acc["g"][2]) == 0.0  # reset


def test_controller_lowers_scale_when_half_safe():
    st = accumulate(_state(), {"g": jnp.array([0.0, 0.0, 10000.0])})
    st = controller_step(st, max_overflow_rate=1e-4, apply=jnp.bool_(True))
    assert float(st.exps["g"]) == -9.0


def test_controller_holds_scale_in_band():
    # no overflow at e, but halving would overflow too much
    st = accumulate(_state(), {"g": jnp.array([0.0, 50.0, 10000.0])})
    st = controller_step(st, max_overflow_rate=1e-4, apply=jnp.bool_(True))
    assert float(st.exps["g"]) == -8.0


def test_controller_apply_false_keeps_accumulating():
    st = accumulate(_state(), {"g": jnp.array([5.0, 5.0, 100.0])})
    st = controller_step(st, max_overflow_rate=1e-4, apply=jnp.bool_(False))
    assert float(st.exps["g"]) == -8.0
    assert float(st.acc["g"][2]) == 100.0


def test_controller_converges_on_gaussian():
    """End-to-end: controller walks the scale to cover a N(0, 100) group."""
    width = 10
    fmt = DynamicFixedPoint(width)
    key = jax.random.PRNGKey(0)
    st = ScaleState.create({"g": ()}, init_exp=0.0)
    for i in range(60):
        key, k = jax.random.split(key)
        x = jax.random.normal(k, (4096,)) * 100.0
        st = accumulate(st, {"g": q_stats(x, fmt, st.exps["g"])})
        st = controller_step(st, max_overflow_rate=1e-3, apply=jnp.bool_(True))
    e = float(st.exps["g"])
    # qmax*2^e should sit a bit above ~3.3 sigma = 330: e ~ log2(330/511) ≈ -0.6
    assert -2.0 <= e <= 1.0
    # and quantization error is small relative to the signal
    y = q_value(jax.random.normal(key, (4096,)) * 100.0, fmt, st.exps["g"])
    x = jax.random.normal(key, (4096,)) * 100.0
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.01


def test_calibrate_exp_headroom():
    e = calibrate_exp(jnp.float32(100.0), width=10, margin_bits=1)
    qmax = 2 ** 9 - 1
    assert qmax * 2.0 ** float(e) >= 200.0  # fits with 1 bit margin
    assert qmax * 2.0 ** (float(e) - 2) < 100.0  # not wastefully wide


# ---------------------------------------------------------------------------
# packed storage
# ---------------------------------------------------------------------------

@given(e=st.integers(-12, 0), width=st.sampled_from([8, 12, 16]))
@settings(deadline=None, max_examples=20)
def test_pack_unpack_roundtrip_on_grid(e, width):
    step = 2.0 ** e
    qmax = 2 ** (width - 1) - 1
    k = np.random.RandomState(0).randint(-qmax, qmax, 128)
    x = jnp.asarray(k * step, jnp.float32)
    p = pack(x, width, jnp.float32(e))
    np.testing.assert_array_equal(np.asarray(unpack(p)), np.asarray(x))


def test_pack_container_dtypes():
    assert pack(jnp.ones(4), 8, jnp.float32(0)).mantissa.dtype == jnp.int8
    assert pack(jnp.ones(4), 12, jnp.float32(0)).mantissa.dtype == jnp.int16
    assert pack(jnp.ones(4), 16, jnp.float32(0)).mantissa.dtype == jnp.int16


def test_policy_validation():
    with pytest.raises(ValueError):
        PrecisionPolicy(arithmetic="nope")
    with pytest.raises(ValueError):
        PrecisionPolicy(arithmetic="dfxp", comp_width=10, storage="packed",
                        compute_dtype="bfloat16")  # bf16 holds <=9 bits
    PrecisionPolicy(arithmetic="dfxp", comp_width=9, storage="packed",
                    compute_dtype="bfloat16")  # ok
