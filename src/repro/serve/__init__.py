"""repro.serve — continuous batching over a DFXP-packed KV-cache pool."""
from .engine import (  # noqa: F401
    EngineOptions,
    Request,
    RequestStatus,
    ServeEngine,
)
from .faults import (  # noqa: F401
    AdmitDelay,
    FaultHarness,
    KVBitFlip,
    LogitNaN,
    PageSqueeze,
    chaos_plan,
)
from .kv_pool import (  # noqa: F401
    CacheQuantConfig,
    KVPool,
    PackedKVCodec,
    insert,
    make_kv_pool,
    make_pool,
    numerics_snapshot,
    overflow_summary,
    slot_overflow_rates,
)
from .metrics import RequestTrace, ServeMetrics  # noqa: F401
from .paged import PageAllocator, PagedKVCodec, PageExhausted  # noqa: F401
from .sampler import SamplerConfig, guard_logits, request_key, sample  # noqa: F401
