"""Pure-jnp oracle for the quantized matmul family — differentiable.

The forward semantics match the fused kernel exactly; the VJP semantics
match its custom backward: straight-through gradients through the operand
rounding (quantized co-operands), with an optional gradient-side rounding
of the cotangent (``grad_width``) mirroring ``qbound``.  ``jax.grad`` of
:func:`qmatmul_ref` is therefore the bit-level oracle for the fused
dgrad/wgrad kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import exact_pow2


def _q(x, e, width):
    step = exact_pow2(e)
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))
    return jnp.clip(jnp.round(x.astype(jnp.float32) / step), qmin, qmax) * step


@functools.lru_cache(maxsize=None)
def _make_ste(width):
    """Operand rounding with a straight-through (identity) backward."""

    @jax.custom_vjp
    def ste(x, e):
        return _q(x, e, width)

    def fwd(x, e):
        return _q(x, e, width), None

    def bwd(_, ct):
        return ct, jnp.float32(0)

    ste.defvjp(fwd, bwd)
    return ste


@functools.lru_cache(maxsize=None)
def _make_gsite(width):
    """Identity forward; rounds the cotangent on the way back (qbound-style)."""

    @jax.custom_vjp
    def gs(y, e_g):
        del e_g
        return y

    def fwd(y, e_g):
        return y, (e_g,)

    def bwd(res, ct):
        (e_g,) = res
        return _q(ct, e_g, width), jnp.float32(0)

    gs.defvjp(fwd, bwd)
    return gs


def qmatmul_ref(a, b, e_a, e_b, *, width: int, quant_a: bool = True,
                quant_b: bool = True, transpose_b: bool = False,
                grad_width=None, e_g=0.0):
    aq = _make_ste(width)(a, jnp.asarray(e_a, jnp.float32)) if quant_a else a
    bq = _make_ste(width)(b, jnp.asarray(e_b, jnp.float32)) if quant_b else b
    if transpose_b:
        c = jax.lax.dot_general(aq, bq, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    else:
        c = jnp.dot(aq, bq, preferred_element_type=jnp.float32)
    c = c.astype(a.dtype)
    if grad_width is not None:
        c = _make_gsite(grad_width)(c, jnp.asarray(e_g, jnp.float32))
    return c
