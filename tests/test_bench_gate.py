"""benchmarks.check_regression: the CI bench-gate comparison logic."""
import json

import pytest

from benchmarks.check_regression import compare, main, merge_min


def _payload(rows, tiny=True):
    return {"meta": {"backend": "cpu", "tiny": tiny},
            "rows": [{"name": r[0], "us_per_call": r[1], "derived": 1.0,
                      "kind": r[2] if len(r) > 2 else "time"}
                     for r in rows]}


BASE = _payload([("a_jnp", 100.0), ("a_fused", 120.0),
                 ("b_jnp", 50.0), ("b_fused", 60.0), ("c", 400.0)])


def test_identical_runs_pass():
    assert compare(BASE, BASE) == []


def test_uniform_machine_slowdown_passes():
    """A 3x slower CI machine shifts every row; the median normalization
    must cancel it completely."""
    fresh = _payload([(r["name"], r["us_per_call"] * 3.0)
                      for r in BASE["rows"]])
    assert compare(BASE, fresh) == []


def test_single_row_regression_fails():
    rows = [(r["name"], r["us_per_call"]) for r in BASE["rows"]]
    rows[1] = ("a_fused", 120.0 * 1.6)          # one row 60% slower
    problems = compare(BASE, _payload(rows))
    assert len(problems) == 1 and "a_fused" in problems[0]
    # and it sits inside the tolerance band when the band is widened
    assert compare(BASE, _payload(rows), tolerance=0.8) == []


def test_missing_row_fails_even_when_fast():
    fresh = _payload([(r["name"], r["us_per_call"])
                      for r in BASE["rows"][:-1]])
    problems = compare(BASE, fresh)
    assert problems == ["missing row: c"]


def test_extra_fresh_rows_are_fine():
    fresh = _payload([(r["name"], r["us_per_call"])
                      for r in BASE["rows"]] + [("new_pair", 10.0)])
    assert compare(BASE, fresh) == []


def test_shape_mismatch_refuses_to_compare():
    fresh = _payload([(r["name"], r["us_per_call"])
                      for r in BASE["rows"]], tiny=False)
    problems = compare(BASE, fresh)
    assert any("shape mismatch" in p for p in problems)


def test_empty_baseline_fails():
    assert compare(_payload([]), BASE) == ["committed baseline has no rows"]


def test_merge_min_takes_per_row_floor(tmp_path):
    """A one-run throttle spike on a single row disappears in the merge
    (the retry path's defense); a real regression present in both runs
    survives."""
    spiky = _payload([("a_jnp", 100.0), ("a_fused", 120.0 * 3.0),
                      ("b_jnp", 50.0), ("b_fused", 60.0),
                      ("c", 400.0 * 2.0)])
    real = _payload([("a_jnp", 100.0), ("a_fused", 120.0),
                     ("b_jnp", 50.0), ("b_fused", 60.0),
                     ("c", 400.0 * 2.0)])       # c slow in BOTH runs
    p1, p2 = tmp_path / "r1.json", tmp_path / "r2.json"
    p1.write_text(json.dumps(spiky))
    p2.write_text(json.dumps(real))
    merged = merge_min([str(p1), str(p2)])
    assert compare(BASE, merged) != []          # c's regression survives
    vals = {r["name"]: r["us_per_call"] for r in merged["rows"]}
    assert vals["a_fused"] == 120.0             # spike cancelled
    assert vals["c"] == 800.0


MEM_BASE = _payload([("a_jnp", 100.0), ("a_fused", 120.0), ("c", 400.0),
                     ("mem_int8_paged", 4096.0, "mem"),
                     ("mem_int8_slot", 8192.0, "mem")])


def test_mem_rows_gate_on_direct_ratio():
    """kind=mem rows are byte counts: a 3x-slower machine leaves them
    unchanged (pass), but bytes/request growing past the band fails even
    when every timing row is clean."""
    rows = [("a_jnp", 300.0), ("a_fused", 360.0), ("c", 1200.0),
            ("mem_int8_paged", 4096.0, "mem"), ("mem_int8_slot", 8192.0,
                                                "mem")]
    assert compare(MEM_BASE, _payload(rows)) == []
    rows[3] = ("mem_int8_paged", 4096.0 * 1.3, "mem")   # >25% more bytes
    problems = compare(MEM_BASE, _payload(rows))
    assert len(problems) == 1 and "memory regression" in problems[0]
    assert "mem_int8_paged" in problems[0]
    assert compare(MEM_BASE, _payload(rows), mem_tolerance=0.5) == []


def test_mem_rows_excluded_from_time_median():
    """Two mem rows at ratio 1.0 must not drag the median under a uniform
    timing slowdown (3 time rows at 3x + 2 mem rows at 1x: a mem-counting
    median would flag every time row)."""
    rows = [("a_jnp", 300.0), ("a_fused", 360.0), ("c", 1200.0),
            ("mem_int8_paged", 4096.0, "mem"),
            ("mem_int8_slot", 8192.0, "mem")]
    assert compare(MEM_BASE, _payload(rows)) == []


def test_mem_row_missing_fails():
    fresh = _payload([("a_jnp", 100.0), ("a_fused", 120.0), ("c", 400.0),
                      ("mem_int8_slot", 8192.0, "mem")])
    assert "missing row: mem_int8_paged" in compare(MEM_BASE, fresh)


@pytest.mark.parametrize("regress", [False, True])
def test_cli_exit_codes(tmp_path, regress):
    cpath, fpath = tmp_path / "c.json", tmp_path / "f.json"
    rows = [(r["name"], r["us_per_call"] * (2.0 if regress and
                                            r["name"] == "c" else 1.0))
            for r in BASE["rows"]]
    cpath.write_text(json.dumps(BASE))
    fpath.write_text(json.dumps(_payload(rows)))
    rc = main(["--committed", str(cpath), "--fresh", str(fpath)])
    assert rc == (1 if regress else 0)
