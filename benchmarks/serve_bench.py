"""Serving benchmarks: sequential vs continuous-batched, f32 vs packed,
fused vs unfused decode attention, whole-prompt vs chunked prefill.

Rows follow the repo convention ``(name, us_per_call, derived)`` where
``us_per_call`` is microseconds per generated token and ``derived`` is the
aggregate tok/s.  The ``serve_mem_*`` rows carry a fourth ``"mem"`` kind
field: their value column is **pool HBM bytes per request** (slot-major:
the full ``max_len`` reservation one slot holds; paged: page size × the
wave's peak resident pages / requests) and ``derived`` is the whole
arena in MB — deterministic at fixed shapes, so the regression gate
diffs them as direct ratios instead of median-normalized times.  Four
time comparisons matter:

* ``serve_sequential_f32`` vs ``serve_batched_f32`` — the continuous-
  batching win: N requests through 1 slot vs N slots.
* ``serve_batched_f32`` vs ``serve_batched_int8``/``int16`` — the packed
  KV-pool tax/win. On CPU the packing math is overhead; on an HBM-bound
  accelerator the 4×/2× smaller cache is the capacity multiplier.
* ``serve_batched_*`` vs ``serve_batched_*_fused`` — the flash-decode
  kernel (``--fused-decode``) vs the ``codec.load`` + einsum composite,
  per cache width. On CPU the fused rows time interpret-mode Pallas
  (reference semantics, slower); on a compiled TPU backend the fused
  int8/int16 rows are where the smaller cache turns into decode
  *bandwidth* — no per-layer f32 K/V materialization on the hot path
  (``benchmarks/roofline.py --kv-report`` prints the expected ratios).
* ``serve_batched_*`` vs ``serve_batched_*_chunked`` — the chunked
  prefill scheduler (``--prefill-chunk``): mixed-length requests admit
  immediately and prefill one chunk per step interleaved with decode,
  ONE prefill jit total, vs the grouped whole-prompt path compiling per
  (group, length).  The bench prompt mix has non-partnered lengths, so
  the chunked rows also price the TTFT scheduling the gate protects.

The ``serve_sharded_*`` rows time the mesh-sharded engine (TP over the
KV pool's head axis; CP over the decode window) in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count`` forcing the mesh's
device count — ``us_per_call`` stays microseconds per generated token
and ``derived`` is **per-device** tok/s (aggregate / mesh size), the
scaling number the nightly lane tracks.  On CPU the virtual devices
share cores, so these rows price the sharding machinery (shard_map
dispatch, o-gather, constraint re-application), not real-accelerator
scaling; the gate keeps them honest the same way as every other row.

``tiny=True`` is the CI smoke contract (2 mixed-length requests, int8
cache, every request finishing with its full budget — execution, not
perf) AND the recording protocol of the committed ``BENCH_serve.json``:
the CI bench-regression gate (``benchmarks/check_regression.py``) diffs a
fresh ``--tiny`` run against the committed file row-by-row, so the
baseline must be recorded at the same shapes.  Tiny records tp2 sharded
rows only; the full (nightly) shapes add tp4 and cp2.

Each timed row also captures the engine's ``repro.obs`` metrics-registry
snapshot (TTFT / queue-wait / tok-per-request histograms, counters) into
the module-level ``OBS`` dict — ``benchmarks/run.py`` persists it as the
``"obs"`` key of ``BENCH_serve.json`` and ``benchmarks/make_report.py
--serve-json`` renders the histograms from it.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.models import transformer as T
from repro.serve import ServeEngine

# row name -> obs metrics-registry snapshot of that row's measured waves
# (filled by run(); persisted into BENCH_serve.json by benchmarks/run.py)
OBS: dict = {}


def _wave(eng, prompts, max_new):
    uids = [eng.submit(p, max_new=max_new) for p in prompts]
    t0 = time.perf_counter()
    out = eng.run()
    dt = time.perf_counter() - t0
    assert set(uids) <= set(out), "request dropped"
    assert all(len(out[u]) == max_new for u in uids), "short generation"
    return sum(len(out[u]) for u in uids), dt


def _drive(cfg, params, prompts, max_new, *, slots, cache_bits, fused=False,
           chunk=0, waves=1, page=0):
    eng = ServeEngine(cfg, PrecisionPolicy("float32", fused_decode=fused,
                                           prefill_chunk=chunk,
                                           page_size=page),
                      params, max_slots=slots,
                      max_len=max(len(p) for p in prompts) + max_new,
                      cache_bits=cache_bits)
    _wave(eng, prompts, max_new)            # warmup: pays every compile
    eng.reset_metrics()
    best = None
    for _ in range(waves):                  # best-of: the gate's metric —
        toks, dt = _wave(eng, prompts, max_new)   # shared CI machines
        if best is None or dt < best[1]:          # jitter the mean badly
            best = (toks, dt)
    # obs snapshot spans every measured wave (warmup excluded by the reset)
    return best + (eng.metrics.registry.snapshot(),)


def run(tiny: bool = False):
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    if tiny:
        lens, max_new, slots, chunk = (5, 9), 4, 2, 4
    else:
        lens, max_new, slots, chunk = \
            (16, 32, 32, 16, 32, 32, 16, 32), 24, 4, 16
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i),
                                             (plen,), 0, cfg.vocab_size))
               for i, plen in enumerate(lens)]

    rows = []
    OBS.clear()
    variants = [("serve_sequential_f32", 1, 0, False, 0),
                ("serve_batched_f32", slots, 0, False, 0),
                ("serve_batched_f32_fused", slots, 0, True, 0),
                ("serve_batched_f32_chunked", slots, 0, False, chunk),
                ("serve_batched_int8", slots, 8, False, 0),
                ("serve_batched_int8_fused", slots, 8, True, 0),
                ("serve_batched_int8_chunked", slots, 8, False, chunk),
                ("serve_batched_int8_chunked_fused", slots, 8, True, chunk),
                ("serve_batched_int16", slots, 16, False, 0),
                ("serve_batched_int16_fused", slots, 16, True, 0)]
    for name, n_slots, bits, fused, pc in variants:
        toks, dt, snap = _drive(cfg, params, prompts, max_new, slots=n_slots,
                                cache_bits=bits, fused=fused, chunk=pc,
                                waves=3 if tiny else 1)
        OBS[name] = snap
        rows.append((name, dt / toks * 1e6, toks / dt))
    rows += _memory_rows(cfg, params, prompts, max_new, slots=slots,
                         page=chunk)
    rows += _sharded_rows(lens, max_new, slots, tiny=tiny)
    return rows


_SHARDED_DRIVER = """
import dataclasses
import json
import time

import jax
import numpy as np

from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.dist import serve_pod_ctx
from repro.launch.mesh import make_serve_mesh
from repro.models import transformer as T
from repro.serve import EngineOptions, ServeEngine

tp, cp, bits, fused = {tp}, {cp}, {bits}, {fused}
lens, max_new, slots, waves = {lens}, {max_new}, {slots}, {waves}
cfg = configs.get_smoke("llama3_8b")
if tp > cfg.num_kv_heads:
    cfg = dataclasses.replace(cfg, num_kv_heads=tp)
params = T.init_params(cfg, jax.random.PRNGKey(0))
prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i),
                                         (n,), 0, cfg.vocab_size))
           for i, n in enumerate(lens)]
max_len = max(lens) + max_new
if max_len % cp:
    max_len += cp - max_len % cp          # CP shards the window evenly
eng = ServeEngine(cfg, PrecisionPolicy("float32", fused_decode=fused),
                  params, max_slots=slots, max_len=max_len,
                  options=EngineOptions(cache_bits=bits),
                  dist=serve_pod_ctx(tp=tp, cp=cp),
                  mesh=make_serve_mesh(tp=tp, cp=cp))

def wave():
    uids = [eng.submit(p, max_new=max_new) for p in prompts]
    t0 = time.perf_counter()
    out = eng.run()
    dt = time.perf_counter() - t0
    assert all(len(out[u]) == max_new for u in uids), "short generation"
    return sum(len(out[u]) for u in uids), dt

wave()                                    # warmup: pays every compile
best = None
for _ in range(waves):
    toks, dt = wave()
    if best is None or dt < best[1]:
        best = (toks, dt)
print(json.dumps({{"toks": best[0], "dt": best[1]}}))
"""


def _sharded_rows(lens, max_new, slots, *, tiny):
    """Mesh-sharded engine rows, one subprocess per mesh shape.

    The device-count flag must be set before jax initializes, hence the
    subprocess (the bench process itself already holds 1 device).  The
    timer brackets only ``eng.run()`` inside the child — interpreter and
    compile startup never touch the row.
    """
    import json as _json
    import os
    import subprocess
    import sys

    variants = [("serve_sharded_tp2_f32", 2, 1, 0, False),
                ("serve_sharded_tp2_int8_fused", 2, 1, 8, True)]
    if not tiny:
        variants += [("serve_sharded_tp4_int8_fused", 4, 1, 8, True),
                     ("serve_sharded_cp2_f32", 1, 2, 0, False)]
    rows = []
    for name, tp, cp, bits, fused in variants:
        ndev = tp * cp
        script = _SHARDED_DRIVER.format(
            tp=tp, cp=cp, bits=bits, fused=fused, lens=tuple(lens),
            max_new=max_new, slots=slots, waves=3 if tiny else 1)
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{ndev} " + env.get("XLA_FLAGS", "")).strip()
        res = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        if res.returncode != 0:
            raise RuntimeError(f"{name} driver failed:\n{res.stderr}")
        out = _json.loads(res.stdout.strip().splitlines()[-1])
        rows.append((name, out["dt"] / out["toks"] * 1e6,
                     out["toks"] / out["dt"] / ndev))
    return rows


def _memory_rows(cfg, params, prompts, max_new, *, slots, page):
    """Pool HBM bytes/request, paged-vs-slot, f32/int8 — the capacity
    comparison the paged pool exists for.  Slot-major reserves the
    worst-case ``max_len`` ring per slot up front; paged residency is
    the wave's peak page count, measured by actually serving the wave
    (page size == the chunk size the timed ``*_chunked`` rows use).
    ``kind="mem"``: the CI gate diffs these rows as direct ratios."""
    from repro.serve import paged as paged_mod

    max_len = max(len(p) for p in prompts) + max_new
    rows = []
    for bits in (0, 8):
        tag = "f32" if bits == 0 else f"int{bits}"
        eng = ServeEngine(cfg, PrecisionPolicy("float32"), params,
                          max_slots=slots, max_len=max_len,
                          cache_bits=bits)
        per_req = float(paged_mod.slot_nbytes(eng._pool))
        rows.append((f"serve_mem_{tag}_slot", per_req,
                     per_req * slots / 1e6, "mem"))
        eng = ServeEngine(cfg, PrecisionPolicy("float32",
                                               prefill_chunk=page,
                                               page_size=page),
                          params, max_slots=slots, max_len=max_len,
                          cache_bits=bits)
        _wave(eng, prompts, max_new)
        st = eng.stats()
        page_b = paged_mod.page_nbytes(eng._pool)
        per_req = page_b * st["pages_in_use_peak"] / len(prompts)
        rows.append((f"serve_mem_{tag}_paged", per_req,
                     page_b * eng._alloc.n_pages / 1e6, "mem"))
    return rows
