"""Mamba2 (SSD — state-space duality) block, chunked matmul form + decode.

Per head h with log-decay ``a_t = dt_t * A`` (A < 0), state ``h_t ∈ R^{P×N}``:

    h_t = exp(a_t) h_{t-1} + dt_t * x_t ⊗ B_t
    y_t = C_t · h_t + D * x_t

The chunked (SSD) form computes, per chunk of length Q, the intra-chunk
contribution as masked matmuls ``(C Bᵀ ⊙ decay) X`` and carries the chunk
state with a short ``lax.scan`` — MXU-friendly, O(S·Q) instead of O(S²).

DFXP integration: the recurrent state accumulates across the whole sequence
(like parameters across steps — paper §6), so it is quantized at the
*update* width at chunk boundaries (``tape.state``); everything else uses
the computation width.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.tape import QTape

from .layers import init_dense, rmsnorm

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_model: int
    state: int            # N
    headdim: int = 64     # P
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def heads(self):
        return self.d_inner // self.headdim

    @property
    def conv_dim(self):
        return self.d_inner + 2 * self.state

    @property
    def in_proj_dim(self):
        # z (gate), x, B, C, dt
        return 2 * self.d_inner + 2 * self.state + self.heads


def init_ssm(key, spec: SSMSpec) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    H = spec.heads
    return {
        "in_proj": init_dense(k1, spec.d_model, spec.in_proj_dim),
        "conv_w": jax.random.normal(k2, (spec.conv_kernel, spec.conv_dim),
                                    jnp.float32) / math.sqrt(spec.conv_kernel),
        "conv_b": jnp.zeros((spec.conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k3, (H,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "norm_w": jnp.ones((spec.d_inner,), jnp.float32),
        "out_proj": init_dense(jax.random.fold_in(k1, 7), spec.d_inner,
                               spec.d_model),
    }


def _split_in_proj(spec: SSMSpec, zxbcdt: Array):
    di, N, H = spec.d_inner, spec.state, spec.heads
    z, x, B, C, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N,
                                        2 * di + 2 * N], axis=-1)
    return z, x, B, C, dt


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv; ``x``: [B, S, C], ``w``: [K, C].

    Expressed as a grouped ``lax.conv`` (one HBM pass) rather than K shifted
    reads — the shifted-add form cost 4× input traffic in the compiled HLO
    (EXPERIMENTS.md §Perf, zamba2 iteration 2).
    """
    K, C = w.shape
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w[:, None, :].astype(jnp.float32),
        window_strides=(1,), padding=[(K - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C)
    return jax.nn.silu(y + b).astype(x.dtype)


def ssm_forward(params, spec: SSMSpec, u: Array, tape: QTape, prefix: str,
                return_cache: bool = False):
    """Training/prefill forward, chunked SSD. ``u``: [B, S, D].

    With ``return_cache``, also returns the decode cache (last ``K-1``
    pre-conv inputs + final SSM state) so decoding can continue.
    """
    B_, S, _ = u.shape
    H, P, N, Q = spec.heads, spec.headdim, spec.state, spec.chunk
    S_orig = S
    if S % Q:
        # pad to a chunk multiple; causality keeps real outputs unaffected,
        # and the pad positions' dt is masked to zero below so the final
        # chunk's state contribution (and hence the decode cache) is
        # exactly the state after S_orig real tokens
        pad = Q - S % Q
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        S = S + pad

    zxbcdt = tape.dot(f"{prefix}/in_proj", u, params["in_proj"])
    z, x_raw, B_raw, C_raw, dt = _split_in_proj(spec, zxbcdt)
    # conv per piece (same depthwise weights, sliced) — avoids the
    # concat→conv→split round-trip that dominated HBM traffic (§Perf)
    di = spec.d_inner
    w, b = params["conv_w"], params["conv_b"]
    x = _causal_conv(x_raw, w[:, :di], b[:di])
    Bm = _causal_conv(B_raw, w[:, di:di + N], b[di:di + N])
    Cm = _causal_conv(C_raw, w[:, di + N:], b[di + N:])
    x = tape.act(f"{prefix}/x", x)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))                # [H]
    if S != S_orig:
        # ragged tail: a pad token must neither decay the state (a = 0 →
        # exp(a) = 1) nor contribute to it (dt = 0 kills its x⊗B term);
        # valid positions' outputs are untouched (cumsum is a prefix op
        # and the intra-chunk mask is causal)
        valid = (jnp.arange(S) < S_orig)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)
    a = dt * A                                                       # [B,S,H]

    nc = S // Q
    xc = x.reshape(B_, nc, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B_, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B_, nc, Q, N).astype(jnp.float32)
    ac = a.reshape(B_, nc, Q, H)
    dtc = dt.reshape(B_, nc, Q, H)

    acum = jnp.cumsum(ac, axis=2)                                    # [B,nc,Q,H]

    # intra-chunk: Y[i] = sum_{j<=i} exp(acum_i - acum_j) (C_i·B_j) dt_j x_j
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                   preferred_element_type=jnp.float32)               # [B,nc,Q,Q]
    diff = acum[:, :, :, None, :] - acum[:, :, None, :, :]           # [B,nc,Q,Q,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(causal[None, None, :, :, None],
                  jnp.exp(diff), 0.0) * G[..., None] * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc,
                         preferred_element_type=jnp.float32)

    # per-chunk final state contribution: sum_j exp(acum_Q - acum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)                # [B,nc,Q,H]
    hc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                    decay_to_end * dtc, Bc, xc,
                    preferred_element_type=jnp.float32)              # [B,nc,H,P,N]

    # carry chunk states
    def body(h_prev, xs):
        hc_i, a_end = xs                                             # a_end: [B,H]
        h_prev = tape.state(f"{prefix}/state", h_prev, record=False)
        h_new = jnp.exp(a_end)[:, :, None, None] * h_prev + hc_i
        return h_new, h_prev

    a_end = acum[:, :, -1, :]                                        # [B,nc,H]
    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    h_last, h_in = jax.lax.scan(
        body, h0,
        (hc.transpose(1, 0, 2, 3, 4), a_end.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                             # [B,nc,H,P,N]
    tape.record_state_stats(f"{prefix}/state", h_in)

    # inter-chunk: Y[i] += C_i · (exp(acum_i) h_prev_chunk)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         Cc, h_in, jnp.exp(acum),
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter + params["D"][None, None, None, :, None]
         * xc).reshape(B_, S, spec.d_inner)
    y = y[:, :S_orig]
    y = tape.act(f"{prefix}/y", y.astype(u.dtype))
    y = rmsnorm(y * jax.nn.silu(z[:, :S_orig]), params["norm_w"])
    out = tape.dot(f"{prefix}/out_proj", y, params["out_proj"])
    out = tape.act(f"{prefix}/out", out)
    if return_cache:
        K = spec.conv_kernel
        need = K - 1
        take = min(need, S_orig)   # the last *real* pre-conv inputs
        lo = S_orig - take
        tail = jnp.concatenate(
            [x_raw[:, lo:S_orig], B_raw[:, lo:S_orig],
             C_raw[:, lo:S_orig]], axis=-1)
        if take < need:            # very short prompt: fresh-state zeros
            tail = jnp.pad(tail, ((0, 0), (need - take, 0), (0, 0)))
        return out, {"conv": tail, "state": h_last}
    return out, None


def init_ssm_cache(spec: SSMSpec, batch: int) -> dict:
    return {
        "conv": jnp.zeros((batch, spec.conv_kernel - 1, spec.conv_dim),
                          jnp.float32),
        "state": jnp.zeros((batch, spec.heads, spec.headdim, spec.state),
                           jnp.float32),
    }


def ssm_decode(params, spec: SSMSpec, u: Array, cache: dict, tape: QTape,
               prefix: str):
    """One-token recurrent step. ``u``: [B, 1, D] → (y [B,1,D], cache')."""
    B_ = u.shape[0]
    H, P, N = spec.heads, spec.headdim, spec.state

    zxbcdt = tape.dot(f"{prefix}/in_proj", u, params["in_proj"])
    z, x, Bm, Cm, dt = _split_in_proj(spec, zxbcdt)

    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)                      # [B,1,conv]
    conv_buf = jnp.concatenate([cache["conv"], xbc], axis=1)         # [B,K,conv]
    w = params["conv_w"]
    out = jnp.einsum("bkc,kc->bc", conv_buf, w) + params["conv_b"]
    xbc1 = jax.nn.silu(out)[:, None, :]
    x, Bm, Cm = jnp.split(xbc1, [spec.d_inner, spec.d_inner + N], axis=-1)
    x = tape.act(f"{prefix}/x", x)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # [B,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = dt * A                                                       # [B,H]

    xh = x[:, 0].reshape(B_, H, P).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)                                # [B,N]
    Cv = Cm[:, 0].astype(jnp.float32)

    h = tape.state(f"{prefix}/state", cache["state"])
    h = (jnp.exp(a)[:, :, None, None] * h
         + jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bv))
    y = jnp.einsum("bn,bhpn->bhp", Cv, h) + params["D"][None, :, None] * xh
    y = y.reshape(B_, 1, spec.d_inner).astype(u.dtype)
    y = tape.act(f"{prefix}/y", y)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"])
    out = tape.dot(f"{prefix}/out_proj", y, params["out_proj"])
    out = tape.act(f"{prefix}/out", out)
    return out, {"conv": conv_buf[:, 1:], "state": h}
