"""qwen2-vl-72b [vlm]: backbone only; patch embeddings are stub inputs
(input_specs provides precomputed mixed embeddings + M-RoPE position ids).
[arXiv:2409.12191]
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="dense", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=29568,
    vocab_size=152064, input_mode="embeds", mrope_sections=(16, 24, 24),
    rope_theta=1e6, tie_embeddings=False)

SMOKE = ModelConfig(
    name="qwen2vl-smoke", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    input_mode="embeds", mrope_sections=(4, 6, 6), tie_embeddings=False)

CELLS = ("train_4k", "prefill_32k", "decode_32k")
