"""Kernel dispatch: routes quantized matmuls onto the fused Pallas path.

This is the production entry point for the DFXP matmul family.  It owns
four concerns the kernels themselves stay agnostic of:

  * **differentiability** — :func:`fused_dot` wraps the forward kernel in
    a ``jax.custom_vjp`` whose backward runs two more Pallas kernels:
    dgrad (``q_g(ct) @ q(B)^T``, layout ``nt``) and wgrad
    (``q(A)^T @ q_g(ct)``, layout ``tn``), with the cotangent's DFXP
    rounding fused into the tile loads (``grad_width``), matching the
    ``qbound`` numerics;
  * **shape collapsing** — batched/ND left operands ``[..., K]`` are
    flattened to ``[M, K]`` around the kernel call (reshape is exact and
    linear, so autodiff through it is free);
  * **block selection** — shape-bucketed, with a small measured autotune
    cache: on compiled backends the first matmul in a bucket times a
    handful of candidate tilings on dummy operands and the winner is
    cached; in interpret mode (no real perf to measure) the shared
    heuristic is cached instead;
  * **backend detection** — compiled Pallas on TPU, interpret elsewhere,
    resolved once per process (``_tiling.default_interpret``).

The same machinery dispatches the fused decode-attention kernel
(:mod:`repro.kernels.attn`): :func:`attn_blocks_for` picks the split-K
size from the same measured cache, keyed ``("attn", Ŵ, G, hd, width)``.

Measured entries **persist across processes**: every successful timing
is serialized to ``.cache/autotune.json`` (override the path with the
``REPRO_AUTOTUNE_CACHE`` env var) and loaded back on import, so a
compiled-TPU autotune run survives restarts instead of re-timing every
bucket per process.  Heuristic fallbacks are never persisted — only
numbers an actual backend produced.

``QTape.dot`` calls :func:`tape_dot` when the policy enables the fused
path (``PrecisionPolicy.fused_matmul``); numerics are bit-identical to
the ``ste_quant`` + ``jnp.matmul`` composite it replaces.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp

from repro.kernels._tiling import (default_interpret, mm_blocks,
                                   resolve_interpret, round_up)
from repro.kernels.qmatmul.ops import qmm

Array = jax.Array


# ---------------------------------------------------------------------------
# shape-bucketed block selection with a measured autotune cache
# ---------------------------------------------------------------------------

# Candidate (block_r, block_c, block_d) tilings tried by the autotuner,
# filtered per shape to fit the operands and a VMEM budget.
_CANDIDATES = [
    (128, 128, 128), (128, 128, 256), (128, 128, 512),
    (128, 256, 128), (256, 128, 128), (256, 256, 128),
    (128, 256, 256), (512, 128, 128), (128, 512, 128),
]
# Candidate split-K sizes (block_w) for the flash-decode attention kernel.
_ATTN_CANDIDATES = [128, 256, 512, 1024, 2048]
_VMEM_BUDGET = 8 * 1024 * 1024  # bytes of f32 tiles per grid step

_AUTOTUNE: Dict[str, object] = {"measure": True, "reps": 3}
_BLOCK_CACHE: Dict[tuple, Tuple[int, ...]] = {}
_MEASURED: Set[tuple] = set()   # keys whose blocks came from a real timing

# -- dispatch profiling (behind --profile; one dict check when off) ----------
#
# Per bucket key: block-selection call count, autotune cache hit/miss
# split, kernel compiles + wall µs spent inside the measurement loops,
# and the blocks chosen.  Selection runs at trace time (jit caches the
# result), so recording here never touches a per-token path; with
# profiling off the only cost is the ``_PROFILE["enabled"]`` check.
_PROFILE: Dict[str, bool] = {"enabled": False}
_PROF: Dict[tuple, dict] = {}
_COMPILES = [0]                 # bumped by the _measure* loops


def profile_enable(on: bool = True) -> None:
    """Turn dispatch profiling on/off (``launch.serve --profile``,
    ``benchmarks/run.py --profile``)."""
    _PROFILE["enabled"] = bool(on)


def reset_profile() -> None:
    _PROF.clear()
    _COMPILES[0] = 0


def profile_stats() -> Dict[tuple, dict]:
    """Copy of the per-bucket profile: ``{key: {calls, hits, misses,
    compiles, measure_us, blocks}}`` (empty unless profiling ran)."""
    return {k: dict(v) for k, v in _PROF.items()}


def _prof(key: tuple, *, hit: bool, blocks=None, measure_us: float = 0.0,
          compiles: int = 0) -> None:
    if not _PROFILE["enabled"]:
        return
    d = _PROF.get(key)
    if d is None:
        d = _PROF[key] = {"calls": 0, "hits": 0, "misses": 0,
                          "compiles": 0, "measure_us": 0.0, "blocks": None}
    d["calls"] += 1
    if hit:
        d["hits"] += 1
    else:
        d["misses"] += 1
    d["compiles"] += compiles
    d["measure_us"] += measure_us
    if blocks is not None:
        d["blocks"] = tuple(blocks)


def profile_table() -> str:
    """The dispatch profile as an aligned text table (one row per bucket)."""
    rows = [("bucket", "calls", "hit", "miss", "compiles", "measure_ms",
             "blocks")]
    for key in sorted(_PROF, key=str):
        d = _PROF[key]
        rows.append(("|".join(map(str, key)), str(d["calls"]),
                     str(d["hits"]), str(d["misses"]), str(d["compiles"]),
                     f"{d['measure_us'] / 1e3:.2f}",
                     "x".join(map(str, d["blocks"] or ()))))
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    return "\n".join("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
                     for r in rows)


def profile_trace_counters(tracer) -> None:
    """Dump the profile onto a :class:`repro.obs.Tracer` as counter events
    (one multi-series counter per bucket, on the ``dispatch`` track)."""
    for key in sorted(_PROF, key=str):
        d = _PROF[key]
        tracer.counter("dispatch/" + "|".join(map(str, key)),
                       {"calls": d["calls"], "hits": d["hits"],
                        "misses": d["misses"], "compiles": d["compiles"],
                        "measure_us": d["measure_us"]}, tid="dispatch")


def _bucket(n: int) -> int:
    """Round up to the next power of two (min 8) — the cache granularity."""
    b = 8
    while b < n:
        b *= 2
    return b


def autotune_cache() -> Dict[tuple, Tuple[int, int, int]]:
    """The live {(kind, R̂, Ĉ, D̂): blocks} cache (mutable; compiled path
    only — interpret mode always uses exact full-shape blocks)."""
    return _BLOCK_CACHE


def reset_autotune() -> None:
    _BLOCK_CACHE.clear()
    _MEASURED.clear()


# -- persistence ------------------------------------------------------------

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_CACHE_DEFAULT = os.path.join(".cache", "autotune.json")


def _cache_path(path: Optional[str] = None) -> str:
    return path or os.environ.get(_CACHE_ENV) or _CACHE_DEFAULT


def save_autotune(path: Optional[str] = None) -> Optional[str]:
    """Serialize the *measured* entries to the autotune cache file.

    Called automatically whenever a measurement lands in the cache;
    heuristic fallbacks are excluded (they cost nothing to recompute and
    would shadow a future real measurement).  Entries already on disk are
    merged, not clobbered — successive/concurrent processes measure
    different buckets and each must keep the others' work.  Returns the
    path written, or None when there is nothing measured to persist.
    """
    entries = {"|".join(map(str, key)): list(_BLOCK_CACHE[key])
               for key in sorted(_MEASURED, key=str) if key in _BLOCK_CACHE}
    if not entries:
        return None
    p = _cache_path(path)
    try:
        with open(p) as f:
            on_disk = json.load(f)
        if isinstance(on_disk, dict):
            entries = {**on_disk, **entries}
    except Exception:
        pass
    d = os.path.dirname(p)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(p, "w") as f:
        json.dump(entries, f, indent=1, sort_keys=True)
    return p


def _valid_entry(key: tuple, blocks: tuple) -> bool:
    """Semantic check on a persisted entry: arity, positivity, VMEM fit.

    Guards against hand-edited files, entries written by a different
    version, or measurements from hardware with other limits — a bad
    entry would otherwise be trusted forever (loaded entries count as
    measured, so nothing ever re-measures the bucket).
    """
    if key[0] == "attn":
        return (len(key) == 5 and len(blocks) == 1 and blocks[0] > 0
                and _attn_fits(blocks[0], key[2], key[3], key[4] or None))
    if key[0] == "prefill":
        return (len(key) == 5 and len(blocks) == 1 and blocks[0] > 0
                and _prefill_fits(blocks[0], key[1], key[2], key[3],
                                  key[4] or None))
    if key[0] in ("nn", "nt", "tn"):
        return (len(key) == 4 and len(blocks) == 3
                and all(b > 0 for b in blocks)
                and _fits(blocks, key[1], key[2], key[3]))
    return False


def load_autotune(path: Optional[str] = None) -> int:
    """Load persisted measurements into the live cache (run at import).

    Returns the number of entries loaded; missing/corrupt files and
    entries that fail :func:`_valid_entry` load 0/are skipped (a stale
    cache must never break dispatch — worst case we re-measure).
    """
    p = _cache_path(path)
    if not os.path.exists(p):
        return 0
    try:
        with open(p) as f:
            data = json.load(f)
        items = [((parts[0],) + tuple(int(x) for x in parts[1:]),
                  tuple(int(b) for b in blocks))
                 for ks, blocks in data.items()
                 for parts in [ks.split("|")]]
    except Exception:   # wrong shape, truncated, hand-edited, unreadable —
        return 0        # a stale cache must never break dispatch
    n = 0
    for key, blocks in items:
        if not _valid_entry(key, blocks):
            continue
        _BLOCK_CACHE[key] = blocks
        _MEASURED.add(key)
        n += 1
    return n


def set_autotune(measure: Optional[bool] = None,
                 reps: Optional[int] = None) -> None:
    if measure is not None:
        _AUTOTUNE["measure"] = measure
    if reps is not None:
        _AUTOTUNE["reps"] = reps


def _fits(blocks, R, C, D) -> bool:
    br, bc, bd = blocks
    # reject blocks larger than the 128-aligned problem (candidates are
    # all 128-multiples, so this is "no pure-padding tiles")
    if (br > round_up(R, 128) or bc > round_up(C, 128)
            or bd > round_up(D, 128)):
        return False
    vmem = 4 * (br * bd + bd * bc + 2 * br * bc)
    return vmem <= _VMEM_BUDGET


def _measure(kind: str, R: int, C: int, D: int, width) -> Optional[tuple]:
    """Time candidate tilings on dummy operands; return the fastest.

    None when no candidate compiled/timed (non-TPU backend) — the caller
    falls back to the heuristic and does NOT persist the entry.
    """
    if kind == "nn":
        sa, sb = (R, D), (D, C)
    elif kind == "nt":
        sa, sb = (R, D), (C, D)
    else:
        sa, sb = (D, R), (D, C)
    a = jnp.zeros(sa, jnp.float32)
    b = jnp.zeros(sb, jnp.float32)
    e = jnp.float32(0.0)
    best, best_t = None, float("inf")
    reps = max(1, int(_AUTOTUNE["reps"]))
    cands = [c for c in _CANDIDATES if _fits(c, R, C, D)]
    if not cands:
        cands = [mm_blocks(kind, R, C, D)]
    for blocks in cands:
        fn = lambda: qmm(a, b, e, e, kind=kind, width_a=width,
                         width_b=width, blocks=blocks, interpret=False)
        try:
            jax.block_until_ready(fn())  # compile
        except Exception:  # tiling rejected by the compiler — skip
            continue
        _COMPILES[0] += 1
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        t = time.perf_counter() - t0
        if t < best_t:
            best, best_t = blocks, t
    return best


def blocks_for(kind: str, R: int, C: int, D: int, *, interpret: bool,
               width=10) -> tuple:
    """Cached block choice for a shape bucket (measured on compiled TPU).

    In interpret mode the blocks are the exact operand dims (one grid
    step, zero padding): the kernel body then executes literally the
    composite's dot on the composite's shapes, which is what makes the
    fused path *bit*-identical to the jnp composite — f32 accumulation
    order on CPU backends depends on operand shapes, so padding or
    splitting the reduction would drift ULPs on raw (straight-through)
    operands.  Compiled TPU tilings come from the measured autotune
    cache instead; there the MXU accumulation contract is the spec.
    """
    if interpret:
        _prof(("mm", kind, "interp"), hit=True, blocks=(R, C, D))
        return R, C, D
    key = (kind, _bucket(R), _bucket(C), _bucket(D))
    blocks = _BLOCK_CACHE.get(key)
    if blocks is None:
        n0, t0 = _COMPILES[0], time.perf_counter()
        measured = (_measure(kind, key[1], key[2], key[3], width)
                    if _AUTOTUNE["measure"] else None)
        _prof(key, hit=False, blocks=measured or mm_blocks(kind, R, C, D),
              measure_us=(time.perf_counter() - t0) * 1e6,
              compiles=_COMPILES[0] - n0)
        blocks = measured or mm_blocks(kind, R, C, D)
        _BLOCK_CACHE[key] = blocks
        if measured:
            _MEASURED.add(key)
            save_autotune()
    else:
        _prof(key, hit=True, blocks=blocks)
    return blocks


# ---------------------------------------------------------------------------
# decode-attention split selection (repro.kernels.attn)
# ---------------------------------------------------------------------------

def _attn_fits(block_w: int, G: int, hd: int, width) -> bool:
    kv_bytes = 1 if (width or 32) <= 8 else (2 if (width or 32) <= 16 else 4)
    vmem = (2 * block_w * hd * kv_bytes          # k + v tiles
            + 4 * (2 * G * block_w               # scores + probs
                   + 2 * G * hd                  # q tile + acc scratch
                   + 2 * G)                      # m/l scratch
            + 4 * block_w)                       # pos tile
    return vmem <= _VMEM_BUDGET


def _measure_attn(W: int, G: int, hd: int, width) -> Optional[tuple]:
    """Time candidate split sizes for one attention bucket (compiled only)."""
    from repro.core.packed import container_dtype
    from repro.kernels.attn.ops import flash_decode
    B, K = 1, 8
    dt = jnp.float32 if width is None else container_dtype(width)
    q = jnp.zeros((B, K, G, hd), jnp.float32)
    kv = jnp.zeros((B, W, K, hd), dt)
    pos = jnp.zeros((B, W), jnp.int32)
    qp = jnp.full((B,), W - 1, jnp.int32)
    e = jnp.zeros((B,), jnp.float32)
    reps = max(1, int(_AUTOTUNE["reps"]))
    best, best_t = None, float("inf")
    cands = [c for c in _ATTN_CANDIDATES
             if c <= round_up(W, 128) and _attn_fits(c, G, hd, width)]
    for bw in cands:
        fn = lambda: flash_decode(q, kv, kv, pos, qp, e, e, width=width,
                                  scale=1.0, block_w=bw, interpret=False)
        try:
            jax.block_until_ready(fn())  # compile
        except Exception:  # tiling rejected by the compiler — skip
            continue
        _COMPILES[0] += 1
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        t = time.perf_counter() - t0
        if t < best_t:
            best, best_t = (bw,), t
    return best


def attn_blocks_for(W: int, G: int, hd: int, *, width=None,
                    interpret: bool) -> int:
    """Split-K size (``block_w``) for the flash-decode kernel.

    Interpret mode returns the whole window — one grid step on exact
    full-shape blocks, which is the bit-equality contract against
    ``attn/ref.py`` (see :func:`blocks_for` for why padding/splitting
    would drift ULPs on CPU).  Compiled buckets key on (Ŵ, G, hd, width)
    and come from the measured cache, heuristic fallback
    ``min(512, Ŵ→128)``.
    """
    if interpret:
        _prof(("attn", "interp"), hit=True, blocks=(W,))
        return W
    key = ("attn", _bucket(W), G, hd, width or 0)
    blocks = _BLOCK_CACHE.get(key)
    if blocks is None:
        n0, t0 = _COMPILES[0], time.perf_counter()
        measured = (_measure_attn(key[1], G, hd, width)
                    if _AUTOTUNE["measure"] else None)
        blocks = measured or (min(512, round_up(W, 128)),)
        _prof(key, hit=False, blocks=blocks,
              measure_us=(time.perf_counter() - t0) * 1e6,
              compiles=_COMPILES[0] - n0)
        _BLOCK_CACHE[key] = blocks
        if measured:
            _MEASURED.add(key)
            save_autotune()
    else:
        _prof(key, hit=True, blocks=blocks)
    return blocks[0]


# ---------------------------------------------------------------------------
# chunked-prefill split selection (repro.kernels.attn flash_prefill)
# ---------------------------------------------------------------------------

# Representative history length the prefill autotuner measures at: the
# bucket key deliberately drops W (the split size barely depends on it —
# it tiles the history walk), so one measurement serves every pool depth.
_PREFILL_MEASURE_W = 4096


def _prefill_fits(block_w: int, C: int, G: int, hd: int, width) -> bool:
    kv_bytes = 1 if (width or 32) <= 8 else (2 if (width or 32) <= 16 else 4)
    rows = C * G
    vmem = (2 * block_w * hd * kv_bytes          # k + v history tiles
            + 4 * (2 * rows * max(block_w, C)    # scores + probs
                   + 2 * rows * hd               # q tile + acc scratch
                   + 2 * rows                    # m/l scratch
                   + 2 * C * hd)                 # f32 chunk k/v tiles
            + 4 * block_w)                       # pos tile
    return vmem <= _VMEM_BUDGET


def _measure_prefill(C: int, G: int, hd: int, width) -> Optional[tuple]:
    """Time candidate split sizes for one prefill bucket (compiled only)."""
    from repro.core.packed import container_dtype
    from repro.kernels.attn.ops import flash_prefill
    B, K, W = 1, 8, _PREFILL_MEASURE_W
    dt = jnp.float32 if width is None else container_dtype(width)
    q = jnp.zeros((B, C, K, G, hd), jnp.float32)
    kn = jnp.zeros((B, C, K, hd), jnp.float32)
    kv = jnp.zeros((B, W, K, hd), dt)
    pos = jnp.zeros((B, W), jnp.int32)
    p0 = jnp.full((B,), W, jnp.int32)
    nv = jnp.full((B,), C, jnp.int32)
    e = jnp.zeros((B,), jnp.float32)
    reps = max(1, int(_AUTOTUNE["reps"]))
    best, best_t = None, float("inf")
    cands = [c for c in _ATTN_CANDIDATES
             if c <= round_up(W, 128) and _prefill_fits(c, C, G, hd, width)]
    for bw in cands:
        fn = lambda: flash_prefill(q, kn, kn, kv, kv, pos, p0, nv, e, e,
                                   width=width, scale=1.0, block_w=bw,
                                   interpret=False)
        try:
            jax.block_until_ready(fn())  # compile
        except Exception:  # tiling rejected by the compiler — skip
            continue
        _COMPILES[0] += 1
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        t = time.perf_counter() - t0
        if t < best_t:
            best, best_t = (bw,), t
    return best


def prefill_blocks_for(W: int, C: int, G: int, hd: int, *, width=None,
                       interpret: bool) -> int:
    """History split size (``block_w``) for the flash-prefill kernel.

    Interpret mode returns the whole window — one grid step on exact
    full-shape blocks, the bit-equality contract against
    ``attn/ref.chunk_attend``.  Compiled buckets key on
    ``("prefill", C, G, hd, width)`` — W is deliberately not part of the
    key (see ``_PREFILL_MEASURE_W``) — and come from the same persisted
    measured cache as the decode splits; heuristic fallback
    ``min(512, Ŵ→128)``.
    """
    if interpret:
        _prof(("prefill", "interp"), hit=True, blocks=(W,))
        return W
    key = ("prefill", C, G, hd, width or 0)
    blocks = _BLOCK_CACHE.get(key)
    if blocks is None:
        n0, t0 = _COMPILES[0], time.perf_counter()
        measured = (_measure_prefill(C, G, hd, width)
                    if _AUTOTUNE["measure"] else None)
        blocks = measured or (min(512, round_up(W, 128)),)
        _prof(key, hit=False, blocks=blocks,
              measure_us=(time.perf_counter() - t0) * 1e6,
              compiles=_COMPILES[0] - n0)
        _BLOCK_CACHE[key] = blocks
        if measured:
            _MEASURED.add(key)
            save_autotune()
    else:
        _prof(key, hit=True, blocks=blocks)
    return blocks[0]


# ---------------------------------------------------------------------------
# paged-attention split validation (repro.kernels.attn *_paged)
# ---------------------------------------------------------------------------

def paged_attn_blocks_for(P: int, G: int, hd: int, *, width=None,
                          interpret: bool) -> int:
    """Split size for the paged flash-decode kernel — always the page.

    The paged grid walks the block table one physical page per step, so
    the page size *is* the split size and there is nothing to tune; this
    is the dispatch layer's validation hook instead: a ``--page-size``
    whose (P, hd) tile would bust the VMEM budget fails loudly at the
    first call, not as a compiler OOM deep in a serve step.  Interpret
    mode has no VMEM and accepts any page.
    """
    _prof(("paged_attn", P, G, hd, width or 0), hit=True, blocks=(P,))
    if not interpret and not _attn_fits(P, G, hd, width):
        raise ValueError(
            f"page_size {P} (G={G}, hd={hd}, width={width}) exceeds the "
            f"{_VMEM_BUDGET >> 20}MB VMEM tile budget of the paged "
            "flash-decode kernel; use a smaller --page-size")
    return P


def paged_prefill_blocks_for(P: int, C: int, G: int, hd: int, *, width=None,
                             interpret: bool) -> int:
    """Split size for the paged flash-prefill kernel — always the page.

    Same contract as :func:`paged_attn_blocks_for`, with the chunk's
    ``C·G`` score rows included in the fit check.
    """
    _prof(("paged_prefill", P, C, G, hd, width or 0), hit=True, blocks=(P,))
    if not interpret and not _prefill_fits(P, C, G, hd, width):
        raise ValueError(
            f"page_size {P} (C={C}, G={G}, hd={hd}, width={width}) exceeds "
            f"the {_VMEM_BUDGET >> 20}MB VMEM tile budget of the paged "
            "flash-prefill kernel; use a smaller --page-size or chunk")
    return P


# ---------------------------------------------------------------------------
# differentiable fused matmul
# ---------------------------------------------------------------------------

def _qmm_auto(a, b, e_a, e_b, *, kind, width_a, width_b, cast, out_dtype,
              interpret):
    """qmm with dispatch-selected blocks for the (collapsed) 2D shapes."""
    if kind == "nn":
        (R, D), C = a.shape, b.shape[1]
    elif kind == "nt":
        (R, D), C = a.shape, b.shape[0]
    else:
        (D, R), C = a.shape, b.shape[1]
    blocks = blocks_for(kind, R, C, D, interpret=interpret,
                        width=width_a or width_b)
    return qmm(a, b, e_a, e_b, kind=kind, width_a=width_a, width_b=width_b,
               blocks=blocks, cast=cast, out_dtype=out_dtype,
               interpret=interpret)


@functools.lru_cache(maxsize=None)
def _make_fused(width_a, width_b, grad_width, transpose_b: bool,
                cast, interpret: bool):
    """Build the custom-VJP fused matmul for one static configuration.

    Forward: ``q(a) @ q(b)`` (or ``q(a) @ q(b)^T`` with ``transpose_b``),
    each quantization optional (``width=None`` → raw operand, matching
    the straight-through composite).  Backward (STE through the operand
    rounding, quantized co-operands):

        da = q_g(ct) @ q(b)[^T]          db = q(a)^T @ q_g(ct)

    with ``q_g`` the optional ``grad_width`` cotangent rounding.
    """
    fwd_kind = "nt" if transpose_b else "nn"

    def _forward(a, b, e_a, e_b):
        return _qmm_auto(a, b, e_a, e_b, kind=fwd_kind, width_a=width_a,
                         width_b=width_b, cast=cast, out_dtype=a.dtype,
                         interpret=interpret)

    @jax.custom_vjp
    def fused(a, b, e_a, e_b, e_g):
        del e_g
        return _forward(a, b, e_a, e_b)

    def fwd(a, b, e_a, e_b, e_g):
        return _forward(a, b, e_a, e_b), (a, b, e_a, e_b, e_g)

    def bwd(res, ct):
        a, b, e_a, e_b, e_g = res
        if transpose_b:
            # y[M,V] = qa[M,D] @ qb[V,D]^T
            da = _qmm_auto(ct, b, e_g, e_b, kind="nn", width_a=grad_width,
                           width_b=width_b, cast=cast, out_dtype=a.dtype,
                           interpret=interpret)
            db = _qmm_auto(ct, a, e_g, e_a, kind="tn", width_a=grad_width,
                           width_b=width_a, cast=cast, out_dtype=b.dtype,
                           interpret=interpret)
        else:
            # y[M,N] = qa[M,K] @ qb[K,N]
            da = _qmm_auto(ct, b, e_g, e_b, kind="nt", width_a=grad_width,
                           width_b=width_b, cast=cast, out_dtype=a.dtype,
                           interpret=interpret)
            db = _qmm_auto(a, ct, e_a, e_g, kind="tn", width_a=width_a,
                           width_b=grad_width, cast=cast, out_dtype=b.dtype,
                           interpret=interpret)
        return (da, db, jnp.zeros_like(e_a), jnp.zeros_like(e_b),
                jnp.zeros_like(e_g))

    fused.defvjp(fwd, bwd)
    return fused


def fused_dot(a, b, e_a, e_b, *, width: int, grad_width: Optional[int] = None,
              e_g=0.0, quant_a: bool = True, quant_b: bool = True,
              transpose_b: bool = False, cast=jnp.float32,
              interpret: Optional[bool] = None) -> Array:
    """Differentiable fused DFXP matmul ``q(a) @ q(b)[^T]``.

    ``a``: [..., K] (leading dims collapsed around the kernel), ``b``:
    [K, N] (or [N, K] with ``transpose_b``).  ``grad_width`` enables the
    fused cotangent rounding (exponent ``e_g``) in both backward kernels;
    ``quant_a=False`` / ``quant_b=False`` pass that operand through raw —
    the straight-through composite contract used by ``QTape.dot``.
    """
    interpret = resolve_interpret(interpret)
    f = _make_fused(width if quant_a else None, width if quant_b else None,
                    grad_width, transpose_b, cast, interpret)
    e_a = jnp.asarray(e_a, jnp.float32)
    e_b = jnp.asarray(e_b, jnp.float32)
    e_g = jnp.asarray(e_g, jnp.float32)
    lead = a.shape[:-1]
    a2 = a.reshape(-1, a.shape[-1]) if a.ndim != 2 else a
    y = f(a2, b, e_a, e_b, e_g)
    return y.reshape(*lead, y.shape[-1]) if a.ndim != 2 else y


def tape_dot(x, w, e_w, *, width: int, transpose_b: bool = False,
             interpret: Optional[bool] = None) -> Array:
    """The ``QTape.dot`` fused path: raw activations × quantized weight.

    Bit-identical to the composite ``jnp.matmul(x, ste_quant(w))`` — the
    activation operand and the backward cotangent are *not* re-rounded
    here (the surrounding ``tape.act`` sites already hold them on the
    DFXP grid), and the weight gradient passes straight through, exactly
    like ``ste_quant``'s identity backward.
    """
    return fused_dot(x, w, 0.0, e_w, width=width, quant_a=False,
                     transpose_b=transpose_b, cast=x.dtype,
                     interpret=interpret)


__all__ = ["fused_dot", "tape_dot", "blocks_for", "attn_blocks_for",
           "prefill_blocks_for", "paged_attn_blocks_for",
           "paged_prefill_blocks_for", "autotune_cache", "reset_autotune",
           "set_autotune", "save_autotune", "load_autotune",
           "default_interpret", "profile_enable", "reset_profile",
           "profile_stats", "profile_table", "profile_trace_counters"]

load_autotune()   # persisted measurements survive process restarts
