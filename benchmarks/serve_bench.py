"""Serving benchmarks: sequential vs continuous-batched, f32 vs packed,
fused vs unfused decode attention.

Rows follow the repo convention ``(name, us_per_call, derived)`` where
``us_per_call`` is microseconds per generated token and ``derived`` is the
aggregate tok/s. Three comparisons matter:

* ``serve_sequential_f32`` vs ``serve_batched_f32`` — the continuous-
  batching win: N requests through 1 slot vs N slots.
* ``serve_batched_f32`` vs ``serve_batched_int8``/``int16`` — the packed
  KV-pool tax/win. On CPU the packing math is overhead; on an HBM-bound
  accelerator the 4×/2× smaller cache is the capacity multiplier.
* ``serve_batched_*`` vs ``serve_batched_*_fused`` — the flash-decode
  kernel (``--fused-decode``) vs the ``codec.load`` + einsum composite,
  per cache width. On CPU the fused rows time interpret-mode Pallas
  (reference semantics, slower); on a compiled TPU backend the fused
  int8/int16 rows are where the smaller cache turns into decode
  *bandwidth* — no per-layer f32 K/V materialization on the hot path
  (``benchmarks/roofline.py --kv-report`` prints the expected ratios).

``tiny=True`` is the CI smoke contract: 2 mixed-length requests, int8
cache, asserting every request finishes with its full budget — execution,
not perf.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import configs
from repro.core.policy import PrecisionPolicy
from repro.models import transformer as T
from repro.serve import ServeEngine


def _wave(eng, prompts, max_new):
    uids = [eng.submit(p, max_new=max_new) for p in prompts]
    t0 = time.perf_counter()
    out = eng.run()
    dt = time.perf_counter() - t0
    assert set(uids) <= set(out), "request dropped"
    assert all(len(out[u]) == max_new for u in uids), "short generation"
    return sum(len(out[u]) for u in uids), dt


def _drive(cfg, params, prompts, max_new, *, slots, cache_bits, fused=False):
    eng = ServeEngine(cfg, PrecisionPolicy("float32", fused_decode=fused),
                      params, max_slots=slots,
                      max_len=max(len(p) for p in prompts) + max_new,
                      cache_bits=cache_bits)
    _wave(eng, prompts, max_new)            # warmup: pays every compile
    eng.reset_metrics()
    return _wave(eng, prompts, max_new)     # steady-state wave


def run(tiny: bool = False):
    cfg = configs.get_smoke("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    if tiny:
        lens, max_new, slots = (5, 9), 4, 2
    else:
        lens, max_new, slots = (16, 32, 32, 16, 32, 32, 16, 32), 24, 4
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i),
                                             (plen,), 0, cfg.vocab_size))
               for i, plen in enumerate(lens)]

    rows = []
    variants = [("serve_sequential_f32", 1, 0, False),
                ("serve_batched_f32", slots, 0, False),
                ("serve_batched_f32_fused", slots, 0, True),
                ("serve_batched_int8", slots, 8, False),
                ("serve_batched_int8_fused", slots, 8, True),
                ("serve_batched_int16", slots, 16, False),
                ("serve_batched_int16_fused", slots, 16, True)]
    for name, n_slots, bits, fused in variants:
        toks, dt = _drive(cfg, params, prompts, max_new,
                          slots=n_slots, cache_bits=bits, fused=fused)
        rows.append((name, dt / toks * 1e6, toks / dt))
    return rows
