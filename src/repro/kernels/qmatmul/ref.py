"""Pure-jnp oracle for the quantized matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant import exact_pow2


def _q(x, e, width):
    step = exact_pow2(e)
    qmax = float(2 ** (width - 1) - 1)
    qmin = -float(2 ** (width - 1))
    return jnp.clip(jnp.round(x.astype(jnp.float32) / step), qmin, qmax) * step


def qmatmul_ref(a, b, e_a, e_b, *, width: int):
    aq = _q(a, e_a, width)
    bq = _q(b, e_b, width)
    return jnp.dot(aq, bq, preferred_element_type=jnp.float32).astype(a.dtype)
