"""Packed int-mantissa storage for parameters/optimizer state (beyond paper).

The paper *simulates* narrow storage inside float32 containers (§7). On real
hardware the 12-bit parameter store is the point: a 400B-parameter model's
masters + momentum shrink from 3.2 TB (f32) to 1.6 TB (int16) — the
difference between fitting a 256-chip v5e pod or not.

``PackedArray`` is a pytree holding an int8/int16 mantissa tensor plus its
group's log2-step. ``pack``/``unpack`` are elementwise and fuse with the
surrounding optimizer math, so wide intermediates never materialize at full
model size.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .quant import exact_pow2

Array = jax.Array


def container_dtype(width: int):
    if width <= 8:
        return jnp.int8
    if width <= 16:
        return jnp.int16
    return jnp.int32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedArray:
    """int mantissa + log2-step; represents ``mantissa * 2**exp``."""

    mantissa: Array                     # int8/int16/int32
    exp: Array                          # f32 scalar (integer-valued)
    width: int = dataclasses.field(metadata=dict(static=True), default=16)

    @property
    def shape(self):
        return self.mantissa.shape

    @property
    def size(self):
        return self.mantissa.size


def qrange(width: int):
    """(qmax, qmin) of a two's-complement ``width``-bit mantissa."""
    return float(2 ** (width - 1) - 1), -float(2 ** (width - 1))


def _overflow_counts(m: Array, width: int, axes=None, mask=None):
    """(n_ovf, n_ovf_at_half_scale) over ``axes`` — the §5 controller pair.

    Counting matches ``quant.fixed_round``, including the asymmetric
    two's-complement range: ``qmin = -(qmax + 1)`` is representable and
    must not count as overflow.  ``mask`` (bool, broadcastable to ``m``)
    restricts the count to selected elements — the chunked KV append
    counts only the rows it actually writes.
    """
    qmax, qmin = qrange(width)
    over = (m > qmax) | (m < qmin)
    overh = (m > qmax / 2) | (m < qmin / 2)
    if mask is not None:
        over = over & mask
        overh = overh & mask
    ovf = jnp.sum(over, axis=axes, dtype=jnp.float32)
    ovfh = jnp.sum(overh, axis=axes, dtype=jnp.float32)
    return ovf, ovfh


def pack(x: Array, width: int, e: Array, *, stochastic_key=None) -> PackedArray:
    e = jnp.asarray(e, jnp.float32)
    step = exact_pow2(e)
    qmax, qmin = qrange(width)
    m = x.astype(jnp.float32) / step
    if stochastic_key is not None:
        u = jax.random.uniform(stochastic_key, m.shape, jnp.float32)
        m = jnp.floor(m + u)
    else:
        m = jnp.round(m)
    m = jnp.clip(m, qmin, qmax)
    return PackedArray(m.astype(container_dtype(width)), e, width)


def pack_rows(x: Array, width: int, e: Array, *, stochastic_keys=None):
    """Per-row pack with per-row overflow statistics.

    ``x``: [B, ...]; ``e``: [B] log2-steps; ``stochastic_keys``: optional
    [B, 2] PRNG keys giving every row an independent rounding stream.
    Returns ``(mantissa int[B, ...], stats f32[B, 3])`` where stats is the
    ``(n_overflow, n_overflow_at_half_scale, n_total)`` triple per row —
    what the serve-time KV-cache controller accumulates per slot.
    """
    qmax, qmin = qrange(width)
    e = jnp.asarray(e, jnp.float32)
    step = exact_pow2(e).reshape(e.shape + (1,) * (x.ndim - 1))
    m = x.astype(jnp.float32) / step
    if stochastic_keys is not None:
        u = jax.vmap(lambda k: jax.random.uniform(k, m.shape[1:]))(
            stochastic_keys)
        m = jnp.floor(m + u)
    else:
        m = jnp.round(m)
    axes = tuple(range(1, x.ndim))
    ovf, ovfh = _overflow_counts(m, width, axes=axes)
    total = jnp.full(ovf.shape, float(m[0].size), jnp.float32)
    stats = jnp.stack([ovf, ovfh, total], axis=-1)
    m = jnp.clip(m, qmin, qmax).astype(container_dtype(width))
    return m, stats


def unpack(p: PackedArray, dtype=jnp.float32) -> Array:
    return (p.mantissa.astype(jnp.float32) * exact_pow2(p.exp)).astype(dtype)


def pack_overflow_stats(x: Array, width: int, e: Array) -> Array:
    """Same (ovf, ovf_half, total) triple as quant.fixed_round, for packing."""
    e = jnp.asarray(e, jnp.float32)
    m = jnp.round(x.astype(jnp.float32) / exact_pow2(e))
    ovf, ovfh = _overflow_counts(m, width)
    return jnp.stack([ovf, ovfh, jnp.float32(x.size)])
