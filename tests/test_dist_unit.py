"""Unit tests for repro.dist: ShardingRules resolution, the compressed
all_to_all bits sweep, and the CP-attention single-device fallback.

Multi-device cases run in subprocesses (same contract as tests/test_dist.py,
whose ``_run_subprocess`` helper is reused here: the main pytest process
must keep seeing 1 device)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from test_dist import _run_subprocess

from repro import configs
from repro.dist.context import DistCtx, multi_pod_ctx, single_pod_ctx
from repro.dist.cp_attention import cp_decode_attention
from repro.dist.sharding import ShardingRules
from repro.models import transformer as T

P = jax.sharding.PartitionSpec


def _rules(**kw):
    # 1×1 mesh on the single CPU device: axis *names* resolve exactly as on
    # the 16×16 production mesh, and size-1 axes divide everything, so spec
    # resolution is tested without forcing a device count.
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return ShardingRules(mesh, **kw)


def _specs(tree_shardings):
    flat = jax.tree_util.tree_flatten_with_path(tree_shardings)[0]
    out = {}
    for path, sh in flat:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "name",
                                                       getattr(p, "idx", "")))))
        out["/".join(parts)] = sh.spec
    return out


def test_sharding_rules_param_resolution():
    cfg = configs.get_smoke("granite_moe_1b")
    params = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    sp = _specs(_rules().params_shardings(params))

    assert sp["embed"] == P("model")                       # vocab TP
    assert sp["final_norm"] == P()                         # replicated
    # stacked MoE experts: [L, E, D, F] → EP over model, FSDP over data
    assert sp["stages/dec/stacked/1:moe/w_gate"] == P(None, "model", "data")
    assert sp["stages/dec/stacked/1:moe/w_down"] == P(None, "model", None,
                                                      "data")
    assert sp["stages/dec/stacked/1:moe/router"] == P()    # routing replicated
    # attention projections: up-type [L, D, Hhd] vs down-type [L, Hhd, D]
    assert sp["stages/dec/stacked/0:attn/wq"] == P(None, "data", "model")
    assert sp["stages/dec/stacked/0:attn/wo"] == P(None, "model", "data")
    assert sp["stages/dec/stacked/0:attn/norm"] == P()


def test_sharding_rules_divisibility_guard():
    import types

    rules = _rules()
    # pretend we're on the 2×16×16 production mesh without forcing devices
    rules.mesh = types.SimpleNamespace(shape={"pod": 2, "data": 16,
                                              "model": 16})
    # 24 experts don't divide model=16 → the EP entry drops to replicated,
    # while the divisible FSDP dim keeps its axis
    assert rules._guard(("model", "data", None), (24, 64, 4)) == \
        P(None, "data")
    # tuple entries use the product of their axis sizes (pod×data = 32)
    assert rules._guard((("pod", "data"), None), (64, 8)) == \
        P(("pod", "data"))
    assert rules._guard((("pod", "data"), None), (48, 8)) == P()


def test_sharding_rules_batch_and_cache():
    rules = _rules()
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "positions": jax.ShapeDtypeStruct((3, 8, 32), jnp.int32)}
    sp = _specs(rules.batch_shardings(batch))
    assert sp["tokens"] == P("data")
    assert sp["positions"] == P(None, "data")              # M-RoPE layout

    cfg = configs.get_smoke("granite_moe_1b")
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 8, 64))
    sp = _specs(rules.cache_shardings(cache))
    assert sp["dec/0:attn/k"] == P(None, "data")           # batch on axis 1

    # long-context: the window axis shards instead of the batch
    seq_rules = _rules(shard_batch=False, seq_shard_cache=True)
    sp = _specs(seq_rules.cache_shardings(cache))
    assert sp["dec/0:attn/k"] == P(None, None, "data")
    assert sp["dec/0:attn/pos"] == P(None, None, "data")


def test_dist_ctx_factories():
    assert not DistCtx().active
    sp = single_pod_ctx()
    assert sp.active and sp.ep_axis == "model" and sp.cp_axes == ("data",)
    mp = multi_pod_ctx()
    assert mp.token_axes == ("pod", "data")
    assert mp.fsdp_axis == "data"                          # FSDP stays in-pod


def test_cp_attention_monolithic_fallback_matches_reference():
    """Without a mesh, cp_decode_attention == the plain masked softmax."""
    B, W, H, K, hd = 2, 16, 4, 2, 8
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(kq, (B, 1, H, hd))
    ck = jax.random.normal(kk, (B, W, K, hd))
    cv = jax.random.normal(kv, (B, W, K, hd))
    pos = jnp.broadcast_to(jnp.arange(W), (B, W)).astype(jnp.int32)
    pos = pos.at[:, -2:].set(-1)
    q_pos = jnp.full((B, 1), 10, jnp.int32)

    out = cp_decode_attention(q, ck, cv, pos, q_pos, num_heads=H,
                              num_kv_heads=K, head_dim=hd, cp_axes=())

    G = H // K
    qg = q.reshape(B, 1, K, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck) / math.sqrt(hd)
    valid = (pos >= 0) & (q_pos - pos >= 0)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bkgqs,bskh->bqkgh", p, cv).reshape(B, 1, H * hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.multidevice
def test_compressed_all_to_all_bits_sweep():
    """Reconstruction error of the int-lane all_to_all strictly improves
    with bit width, and 16-bit is near-exact for well-scaled activations."""
    out = _run_subprocess("""
        import math
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, AxisType
        from repro.dist.compress import compressed_all_to_all
        mesh = jax.make_mesh((8,), ("ep",), axis_types=(AxisType.Auto,))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 32)) * 0.3
        amax = float(jnp.max(jnp.abs(x)))

        def run(bits):
            e = math.ceil(math.log2(amax / (2 ** (bits - 1) - 1)))
            f = lambda v: compressed_all_to_all(
                v, jnp.float32(e), bits, "ep", split_axis=0, concat_axis=1)
            return jax.jit(jax.shard_map(
                f, in_specs=P(None, "ep"), out_specs=P(None, "ep"),
                check_vma=False))(x)

        ref_f = lambda v: jax.lax.all_to_all(
            v, "ep", split_axis=0, concat_axis=1, tiled=True)
        with jax.set_mesh(mesh):
            ref = jax.jit(jax.shard_map(
                ref_f, in_specs=P(None, "ep"), out_specs=P(None, "ep"),
                check_vma=False))(x)
            err = {b: float(jnp.abs(run(b) - ref).max()) for b in (8, 16)}
        assert err[16] < err[8], err
        assert err[16] < 1e-3 * amax, err
        assert err[8] < 2e-2 * amax, err
        print("OK", err[8], err[16])
    """)
    assert "OK" in out


@pytest.mark.multidevice
def test_compress_tree_psum_multidevice():
    """Per-leaf-scaled tree compression mean-reduces each leaf correctly
    even when leaf magnitudes differ by orders."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, AxisType
        from repro.dist.compress import compress_tree
        mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        g = {"w": jax.random.normal(k1, (8, 64)),
             "b": jax.random.normal(k2, (8, 16)) * 1e-4}
        r = jax.tree.map(jnp.zeros_like, g)
        f = lambda g, r: compress_tree(g, r, 16, axis_name="data")
        with jax.set_mesh(mesh):
            gh, rn = jax.jit(jax.shard_map(
                f, in_specs=(P("data"), P("data")),
                out_specs=(P("data"), P("data")), check_vma=False))(g, r)
        for name in ("w", "b"):
            true = jnp.broadcast_to(g[name].mean(0), g[name].shape)
            rel = float(jnp.abs(gh[name] - true).max() /
                        jnp.abs(true).max())
            assert rel < 1e-3, (name, rel)
        print("OK")
    """)
    assert "OK" in out
