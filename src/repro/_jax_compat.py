"""Backfill newer jax mesh/shard_map APIs onto older jax (0.4.x).

The repo (and its tests) are written against the current jax surface:
``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``, ``jax.shard_map``,
``jax.sharding.AxisType`` and ``jax.sharding.get_abstract_mesh``. On jax
0.4.x those live elsewhere (``jax.experimental.shard_map``, the ``Mesh``
context manager) or don't exist. :func:`install` patches the gaps in the
``jax`` namespace — strictly additive and idempotent: on a jax that already
has an attribute, that attribute is left untouched.

Installed automatically by ``src/sitecustomize.py`` (any process started
with ``PYTHONPATH=src``) and by ``repro.dist`` on import, so both the pytest
main process and the ``python -c`` subprocess tests get it before they touch
the mesh APIs. Importing jax here does NOT initialize a backend: XLA reads
``XLA_FLAGS`` lazily at first device use, so callers that force a host
device count after this module loads still get it (verified by the
multi-device subprocess tests).
"""
from __future__ import annotations

import contextlib
import enum

import jax


def _physical_mesh():
    """The ambient mesh set by ``with mesh:`` / the ``set_mesh`` shim."""
    from jax._src import mesh as mesh_lib

    return mesh_lib.thread_resources.env.physical_mesh


def ambient_mesh():
    """Current ambient mesh, or ``None`` when no mesh is active.

    Works on both old jax (physical resource env) and new jax
    (``get_abstract_mesh``); repo code uses this instead of calling either
    API directly.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        mesh = get()
        return None if mesh is None or getattr(mesh, "empty", False) else mesh
    mesh = _physical_mesh()
    return None if mesh.empty else mesh


def install() -> None:
    if getattr(jax, "_repro_compat_installed", False):
        return
    jax._repro_compat_installed = True

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    _orig_make_mesh = jax.make_mesh

    def make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kwargs):
        # axis_types (Auto/Explicit/Manual) only exists on newer jax; the
        # repo always passes Auto, which is 0.4.x's only behavior — drop it.
        del axis_types
        return _orig_make_mesh(axis_shapes, axis_names, *args, **kwargs)

    import inspect

    if "axis_types" not in inspect.signature(_orig_make_mesh).parameters:
        jax.make_mesh = make_mesh

    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = _physical_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _orig_shard_map

        def shard_map(f, mesh=None, *, in_specs, out_specs, check_vma=None,
                      check_rep=None, auto=frozenset()):
            if mesh is None:
                mesh = _physical_mesh()
                if mesh.empty:
                    raise ValueError(
                        "jax.shard_map without an explicit mesh requires an "
                        "ambient mesh (enter one with jax.set_mesh(mesh))")
            rep = True
            if check_vma is not None:
                rep = check_vma
            elif check_rep is not None:
                rep = check_rep
            return _orig_shard_map(f, mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_rep=rep,
                                   auto=auto)

        jax.shard_map = shard_map


install()
