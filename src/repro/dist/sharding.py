"""ShardingRules — logical param/activation names → ``PartitionSpec``s.

One place resolves every jit boundary sharding (train state, inference
params, batches, decode caches) from leaf *names* and shapes, so the model
code never hard-codes axis names and the dry-run can swap meshes freely.

Axis roles (matching :mod:`repro.dist.context`):
  * ``dp``   — batch/token axis: ``data``, or ``("pod", "data")`` across
    pods (the pure-DP pod axis composes with in-pod data parallelism);
  * ``tp``   — ``model``: tensor-parallel feature/vocab/head shards and the
    expert-parallel axis for MoE banks;
  * ``fsdp`` — ``data``: parameter sharding, always within a pod.

Resolution is name-aware (embed/head/MoE/down-vs-up projections) with a
divisibility guard: an axis whose size doesn't evenly divide the dimension
is dropped (replicated) rather than producing an invalid sharding — small
smoke configs and production configs resolve through the same table.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# 2D weights whose *first* dim is the contraction (fan-in) feature axis that
# upstream tensor parallelism already sharded → shard dim0 over tp.
_DOWN_PROJ = {"w_down", "w_out", "wo", "out_proj"}


def _path_parts(path) -> Tuple[str, ...]:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return tuple(parts)


class ShardingRules:
    def __init__(self, mesh, *, multi_pod: bool = False,
                 shard_batch: bool = True, seq_shard_cache: bool = False):
        self.mesh = mesh
        self.multi_pod = multi_pod
        self.shard_batch = shard_batch
        self.seq_shard_cache = seq_shard_cache
        self.dp = ("pod", "data") if multi_pod else "data"
        self.tp = "model"
        self.fsdp = "data"
        # context-parallel KV-window axis: in-pod only, matching
        # DistCtx.cp_axis — the 500k cache must never be gathered across
        # the slow inter-pod links (pods hold replicas instead).
        self.cp = "data"

    # -- helpers ----------------------------------------------------------
    def _axis_size(self, entry) -> int:
        if entry is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        return math.prod(self.mesh.shape[n] for n in names)

    def _guard(self, entries, shape) -> P:
        """Drop axes that don't divide their dim; build the PartitionSpec."""
        out = []
        for i, e in enumerate(entries[:len(shape)]):
            ok = e is not None and self._axis_size(e) > 0 and \
                shape[i] % self._axis_size(e) == 0
            out.append(e if ok else None)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def _named(self, entries, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self._guard(tuple(entries), shape))

    # -- parameters / train state -----------------------------------------
    def _param_entries(self, parts: Tuple[str, ...], shape) -> tuple:
        leaf = parts[-1]
        stacked = "stacked" in parts          # leading scan-layer axis
        base = shape[1:] if stacked and len(shape) > 1 else shape
        nd = len(base)
        in_moe = any(p.endswith(":moe") for p in parts) and \
            "shared" not in parts

        if nd < 2:                              # norms, biases, scalars
            ent: tuple = (None,) * nd
        elif "embed" in parts:                  # [V, D] — vocab TP
            ent = (self.tp, None)
        elif "head" in parts:                   # [D, V]
            ent = (None, self.tp)
        elif in_moe and leaf in ("w_gate", "w_up") and nd == 3:
            ent = (self.tp, self.fsdp, None)    # [E, D, F]: EP × FSDP
        elif in_moe and leaf == "w_down" and nd == 3:
            ent = (self.tp, None, self.fsdp)    # [E, F, D]
        elif in_moe and leaf == "router":
            ent = (None,) * nd                  # routing is replicated
        elif nd == 2 and leaf in _DOWN_PROJ:
            ent = (self.tp, self.fsdp)
        elif nd == 2:
            ent = (self.fsdp, self.tp)          # up-projections / qkv
        elif nd == 3 and leaf == "w":
            ent = (None, self.fsdp, self.tp)    # maxout [k, D, F]
        else:
            ent = (None,) * nd
        if stacked and len(shape) > nd:
            ent = (None,) + ent
        return ent

    def params_shardings(self, params):
        """NamedSharding tree for a bare parameter pytree."""
        return jax.tree_util.tree_map_with_path(
            lambda p, leaf: self._named(
                self._param_entries(_path_parts(p), leaf.shape), leaf.shape),
            params)

    def state_shardings(self, state):
        """NamedSharding tree for a full ``TrainState`` (eval_shape) pytree.

        Optimizer state mirrors the parameter tree (same trailing names →
        same specs); scale state and the step counter are replicated.
        """
        def spec(path, leaf):
            parts = _path_parts(path)
            if parts and parts[0] in ("scale", "step"):
                return NamedSharding(self.mesh, P())
            return self._named(self._param_entries(parts, leaf.shape),
                               leaf.shape)
        return jax.tree_util.tree_map_with_path(spec, state)

    # -- batches -----------------------------------------------------------
    def batch_shardings(self, batch):
        """Token batches: batch dim over ``dp`` (M-RoPE positions carry the
        batch on axis 1)."""
        def spec(path, leaf):
            nd = len(leaf.shape)
            parts = _path_parts(path)
            if not self.shard_batch or nd == 0:
                ent: tuple = (None,) * nd
            elif parts and parts[-1] == "positions" and nd == 3:
                ent = (None, self.dp) + (None,) * (nd - 2)
            else:
                ent = (self.dp,) + (None,) * (nd - 1)
            return self._named(ent, leaf.shape)
        return jax.tree_util.tree_map_with_path(spec, batch)

    # -- decode caches ------------------------------------------------------
    def cache_shardings(self, cache):
        """Decode caches: stacked-layer leaves [L, B, ...] shard the batch;
        with ``seq_shard_cache`` the KV ring-buffer *window* axis shards
        over ``cp`` instead (context parallelism for 500k windows — decode
        then runs :func:`repro.dist.cp_attention.cp_decode_attention`
        over the same axis)."""
        bdim = self.dp if self.shard_batch else None

        def spec(path, leaf):
            parts = _path_parts(path)
            leafname = parts[-1] if parts else ""
            nd = len(leaf.shape)
            if leafname == "enc_memory":
                ent: tuple = (bdim,) + (None,) * (nd - 1)
            elif (self.seq_shard_cache and nd >= 3
                  and leafname in ("k", "v", "pos")):
                ent = (None, None, self.cp) + (None,) * (nd - 3)
            elif nd >= 2:
                ent = (None, bdim) + (None,) * (nd - 2)
            else:
                ent = (None,) * nd
            return self._named(ent, leaf.shape)
        return jax.tree_util.tree_map_with_path(spec, cache)

    # -- serve KV pools -----------------------------------------------------
    def pool_shardings(self, pool):
        """NamedSharding tree for a serve KV pool (raw/slot-major/paged).

        The pool is the serving engine's HBM-bound tensor; its layout is
        derived here rather than assumed host-side, so the same engine
        code runs single-device and sharded:

        * K/V storage (``k``/``v`` raw, ``k_m``/``v_m`` mantissas) shards
          the **kv-head** axis over ``tp`` — slot-major ``[L, B, W, K,
          hd]`` and paged arenas ``[L, n_pages, P, K, hd]`` both carry it
          at axis 3.  Per-head attention math never contracts across
          heads, so a head-sharded pool is bit-exact;
        * with ``seq_shard_cache`` (context parallelism), slot-major
          storage and ``pos`` additionally shard the ring **window** axis
          over ``cp`` — the layout
          :func:`repro.dist.cp_attention.cp_decode_attention` merges
          exactly.  Paged pools never CP-shard (pages already tile the
          window; the combination is rejected upstream);
        * exponents, §5 counters, block tables, and every non-attention
          entry replicate — they are per-slot/per-page scalars the
          controller must see whole.

        The divisibility guard applies as everywhere else: an axis that
        does not divide its dim (e.g. 4-way ``tp`` over 2 kv heads) is
        dropped to replicated, and the fused kernels fall back to their
        unsharded call on the same condition.
        """
        tp = self.tp if self.tp in self.mesh.shape else None
        cp = self.cp if (self.seq_shard_cache
                         and self.cp in self.mesh.shape) else None

        def replicate(sub):
            return jax.tree_util.tree_map(
                lambda x: self._named((None,) * len(x.shape), x.shape), sub)

        def entry_specs(entry):
            paged = "bt" in entry
            out = {}
            for name, leaf in entry.items():
                nd = len(leaf.shape)
                if name in ("k", "v", "k_m", "v_m") and nd == 5:
                    win = None if paged else cp
                    ent: tuple = (None, None, win, tp, None)
                elif not paged and name == "pos" and nd == 3:
                    ent = (None, None, cp)
                else:
                    ent = (None,) * nd
                out[name] = self._named(ent, leaf.shape)
            return out

        def is_attn(e):
            return isinstance(e, dict) and "pos" in e and \
                ("k" in e or "k_m" in e)

        return {sname: {bkey: entry_specs(e) if is_attn(e) else replicate(e)
                        for bkey, e in sc.items()}
                for sname, sc in pool.items()}

    # -- introspection ------------------------------------------------------
    def describe(self, tree) -> Dict[str, str]:
        """Human-readable ``{path: spec}`` map (for dry-run reports/tests)."""
        out: Dict[str, str] = {}
        flat = jax.tree_util.tree_flatten_with_path(
            self.params_shardings(tree))[0]
        for path, sh in flat:
            out["/".join(_path_parts(path))] = str(sh.spec)
        return out
