"""Fault-tolerant checkpointing without external deps (no orbax offline).

Layout per step::

    <dir>/step_000100/
        manifest.json      # tree structure, shapes, dtypes, leaf → file
        <leaf-id>.npy      # one .npy per leaf (host-gathered global array)
        _COMMITTED         # written last: restore ignores torn checkpoints

Design points for the 1000-node story:
  * **Elastic restore**: arrays are stored as *global* content + the
    manifest records logical shape/dtype only. ``restore_tree`` device_puts
    onto whatever mesh/sharding the *new* job provides — restarting on a
    different pod count (after node loss) reshards transparently.
  * **Atomicity**: `_COMMITTED` marker written after all leaves; the
    manager's `latest()` skips uncommitted dirs, so a preemption mid-save
    falls back to the previous step.
  * **Async**: `save_async` snapshots to host memory synchronously (cheap)
    and writes files on a background thread, overlapping the next step.
  * **Retention**: keeps the newest ``keep`` committed checkpoints.
  * Multi-host note: in a real multi-controller job each host would write
    only the shards it owns (`jax.experimental.multihost_utils`); in this
    single-controller container the process gathers full arrays.

PackedArray leaves (packed storage mode) round-trip transparently —
they're ordinary pytree nodes whose leaves are int16 mantissas + exps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

Array = jax.Array


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_tree(tree: Any, path: str) -> None:
    """Synchronous atomic save of a pytree of arrays."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    manifest = {"treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


def restore_tree(template: Any, path: str, shardings: Any = None) -> Any:
    """Restore into ``template``'s structure; reshard onto ``shardings``.

    ``template`` may hold arrays or ShapeDtypeStructs; ``shardings`` (a
    matching pytree of NamedShardings, or None) controls placement — pass
    the *new* mesh's shardings to reshard elastically.
    """
    leaves_t, treedef = _flatten(template)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert len(manifest["leaves"]) == len(leaves_t), \
        f"checkpoint has {len(manifest['leaves'])} leaves, template {len(leaves_t)}"
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_t))
    out = []
    for meta, tmpl, sh in zip(manifest["leaves"], leaves_t, shard_leaves):
        arr = np.load(os.path.join(path, meta["file"]))
        assert tuple(arr.shape) == tuple(tmpl.shape), (arr.shape, tmpl.shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self):
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "_COMMITTED")):
                steps.append(int(d.split("_")[1]))
        return sorted(steps)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any) -> None:
        save_tree(tree, self._step_dir(step))
        self._gc()

    def save_async(self, step: int, tree: Any) -> None:
        """Snapshot to host now; write in the background."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._thread = threading.Thread(
            target=lambda: (save_tree(host_tree, self._step_dir(step)),
                            self._gc()),
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        return restore_tree(template, self._step_dir(step), shardings)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
