"""Precision policy: which arithmetic, which widths, which container (paper §6).

The paper's headline configuration is ``dfxp`` with ``comp_width=10`` (all
computations: activations, weighted sums, and every gradient) and
``update_width=12`` (parameter storage — wide enough to accumulate many
small SGD contributions). ``fixed`` reproduces §4 (global radix point after
the ``fixed_int_bits``-th MSB), the float names reproduce §3.

``storage``:
  * ``sim``    — paper-faithful: values live in wide float containers and are
    merely *representable* in the target format (the paper's §7 simulation).
  * ``packed`` — beyond-paper production mode: parameters/momentum are stored
    as int8/int16 mantissas + per-group scales (real HBM savings); compute
    containers are ``compute_dtype``. Exactness: bfloat16 holds DFXP widths
    ≤ 9 exactly, float16 ≤ 12, float32 ≤ 25 (see formats.container_exact_bits).
"""
from __future__ import annotations

import dataclasses

from .formats import (
    BFLOAT16,
    FLOAT8_E4M3,
    FLOAT8_E5M2,
    FLOAT16,
    FLOAT32,
    DynamicFixedPoint,
    FixedPoint,
    Format,
    Observe,
    container_exact_bits,
)

_FLOATS = {
    "float32": FLOAT32,
    "float16": FLOAT16,
    "bfloat16": BFLOAT16,
    "float8_e4m3": FLOAT8_E4M3,
    "float8_e5m2": FLOAT8_E5M2,
}


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    arithmetic: str = "float32"      # float32|bfloat16|float16|float8_*|fixed|dfxp
    comp_width: int = 10             # paper: 10 (computations)
    update_width: int = 12           # paper: 12 (parameter updates)
    fixed_int_bits: int = 5          # paper Fig.1: radix after 5th MSB
    max_overflow_rate: float = 1e-4  # paper: 0.01%
    update_interval: int = 100       # controller cadence, in steps
    stochastic_rounding: bool = False   # beyond-paper (param updates only)
    quantize_momentum: bool = True
    storage: str = "sim"             # sim|packed
    compute_dtype: str = "float32"   # container dtype for activations/compute
    grad_compress_bits: int = 0      # 0=off; 8|16: DFXP DP all-reduce compression
    a2a_compress_bits: int = 0       # 0=off; 8|16: MoE all_to_all in int lanes
    fused_matmul: bool = False       # route DFXP QTape.dot through the fused
    #   Pallas qmatmul (fwd + dgrad + wgrad custom-VJP kernels; see
    #   repro.kernels.dispatch). Bit-identical to the jnp composite;
    #   off by default because interpret-mode Pallas (any non-TPU
    #   backend) trades speed for kernel-faithful execution.
    fused_decode: bool = False       # serve-side: run decode attention as
    #   the fused Pallas flash-decode kernel (repro.kernels.attn) directly
    #   on the KV pool's storage containers — packed pools dequantize
    #   int8/int16 mantissas in the tile loads instead of materializing
    #   f32 K/V per layer (codec.load), which is where the 4×/2× HBM-read
    #   win of the packed cache actually cashes out. CLI --fused-decode.
    prefill_chunk: int = 0           # serve-side: chunked prefill size C.
    #   0 = whole-prompt prefill (the bit-for-bit reference path, one jit
    #   per (group, prompt_len)). C > 0: ServeEngine admits any queued
    #   request into any free slot immediately and runs one C-token
    #   prefill chunk per engine step interleaved with decode — ONE jit
    #   for any prompt length (ragged tails masked in-kernel), chunk K/V
    #   quantized straight into the packed pool (codec.append_chunk) and
    #   history attended off the packed storage (flash-prefill kernel
    #   when fused_decode). Attention-family models only; MoE/SSM keep
    #   the whole-prompt path. CLI --prefill-chunk.
    page_size: int = 0               # serve-side: paged KV pool page size P.
    #   0 = slot-major pool (contiguous [B, W] rings). P > 0: the pool
    #   stores fixed-size pages with per-request block tables
    #   (repro.serve.paged) — per-PAGE DFXP exponents, refcounted
    #   prompt-prefix sharing with copy-on-write, page-granular
    #   quantize-on-write. Forces chunked prefill (C defaults to P);
    #   dense global-attention family only. CLI --page-size.

    def __post_init__(self):
        if self.prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0")
        if self.page_size < 0:
            raise ValueError("page_size must be >= 0")
        if self.arithmetic not in (*_FLOATS, "fixed", "dfxp", "observe"):
            raise ValueError(f"unknown arithmetic {self.arithmetic!r}")
        if self.storage not in ("sim", "packed"):
            raise ValueError(f"unknown storage {self.storage!r}")
        if self.storage == "packed" and self.arithmetic == "dfxp":
            exact = container_exact_bits(self.compute_dtype)
            if self.comp_width > exact:
                raise ValueError(
                    f"comp_width={self.comp_width} not exactly representable "
                    f"in {self.compute_dtype} containers (max {exact})")

    # -- format accessors ---------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self.arithmetic != "float32"

    @property
    def dynamic(self) -> bool:
        return self.arithmetic == "dfxp"

    @property
    def observing(self) -> bool:
        return self.arithmetic == "observe"

    def comp_format(self) -> Format:
        """Format for activations, weighted sums, and all gradients."""
        if self.arithmetic == "observe":
            return Observe()
        if self.arithmetic in _FLOATS:
            f = _FLOATS[self.arithmetic]
            return None if f.name == "float32" else f
        if self.arithmetic == "fixed":
            return FixedPoint(self.comp_width, self.fixed_int_bits)
        return DynamicFixedPoint(self.comp_width)

    def update_format(self) -> Format:
        """Format for parameter (and momentum) storage."""
        if self.arithmetic == "observe":
            return Observe()
        if self.arithmetic in _FLOATS:
            f = _FLOATS[self.arithmetic]
            return None if f.name == "float32" else f
        if self.arithmetic == "fixed":
            return FixedPoint(self.update_width, self.fixed_int_bits)
        return DynamicFixedPoint(self.update_width)


# Paper's headline policies (Table 3 rows).
SINGLE_FLOAT = PrecisionPolicy("float32")
HALF_FLOAT = PrecisionPolicy("float16")
FIXED_20 = PrecisionPolicy("fixed", comp_width=20, update_width=20)
DFXP_10_12 = PrecisionPolicy("dfxp", comp_width=10, update_width=12)
