"""Auto-loaded by any interpreter started with ``PYTHONPATH=src``.

Installs the jax API backfills (``repro._jax_compat``) before user code
runs, so scripts that use ``jax.set_mesh`` / ``jax.shard_map`` /
``jax.sharding.AxisType`` *before* importing ``repro`` — notably the
subprocess bodies in tests/test_dist.py — work on jax 0.4.x. Must never
break interpreter startup, hence the blanket except.
"""
try:
    import repro._jax_compat  # noqa: F401  (patches jax on import)
except Exception:
    pass
