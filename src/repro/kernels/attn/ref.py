"""Reference composite for the fused decode-attention kernel.

This is the numerics contract of :mod:`repro.kernels.attn`: a single-query
GQA attention over a (possibly DFXP-packed) KV ring buffer, written as
plain jnp on the full ``[B, ...]`` shapes.  The Pallas kernel's
interpret-mode path executes :func:`attend` *verbatim* on its loaded
tiles (one grid step, full-shape blocks, dequantize first), which is what
lets CPU tests assert **bit**-equality between the fused kernel and this
composite — the same guarantee the qmatmul family gives against its
``ste_quant + jnp.matmul`` composite.

Masking semantics match ``repro.models.layers.attention_decode``:

* ``pos < 0`` marks an empty ring slot (never attended);
* causal: the query at ``q_pos`` sees keys with ``pos <= q_pos``;
* ``window``: only keys with ``q_pos - pos < window`` (None = global).

The softmax is the flash form — masked lanes contribute an exact ``0.0``
(``jnp.where`` before and after the exp), the max is subtracted per
(batch, kv-head, group) row, and the normalizer divides the *output*
(``o / l``), which is the order the split-K kernel reproduces.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import exact_pow2

Array = jax.Array


def valid_mask(pos: Array, q_pos: Array, *, window: Optional[int],
               causal: bool) -> Array:
    """[B, W] bool: which ring slots the query at ``q_pos`` [B] may see."""
    d = q_pos[:, None] - pos
    valid = pos >= 0
    if causal:
        valid = valid & (d >= 0)
    if window:
        valid = valid & (d < window)
    return valid


def attend(qf: Array, kf: Array, vf: Array, pos: Array, q_pos: Array, *,
           scale: float, window: Optional[int] = None,
           causal: bool = True) -> Array:
    """Single-query GQA attention on dequantized (f32) operands.

    ``qf``: [B, K, G, hd] · ``kf``/``vf``: [B, W, K, hd] · ``pos``: [B, W]
    int32 · ``q_pos``: [B] int32.  Returns [B, K, G, hd] float32.
    """
    s = jnp.einsum("bkgh,bwkh->bkgw", qf, kf,
                   preferred_element_type=jnp.float32) * scale
    v4 = valid_mask(pos, q_pos, window=window, causal=causal)[:, None, None, :]
    s = jnp.where(v4, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(v4, jnp.exp(s - m), 0.0)
    el = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgw,bwkh->bkgh", p, vf,
                   preferred_element_type=jnp.float32)
    return o / jnp.maximum(el, 1e-30)


def chunk_attend(qf: Array, kf: Array, vf: Array, pos: Array, k_new: Array,
                 v_new: Array, p0: Array, n_valid: Array, *, scale: float,
                 window: Optional[int] = None, causal: bool = True) -> Array:
    """Chunked-prefill attention on dequantized (f32) operands.

    A chunk of ``C`` query positions starting at absolute position ``p0``
    attends (a) the already-written pool **history** — ring entries with
    ``0 <= pos < p0`` — and (b) its **own** chunk K/V causally, taken from
    the fresh f32 projections (never from the pool, so ring eviction by
    the chunk's own write can't hide in-window keys).  One joint flash
    softmax spans both score blocks, which is the order the split-K
    prefill kernel reproduces (history splits first, self block last).

    ``qf``: [B, C, K, G, hd] · ``kf``/``vf``: [B, W, K, hd] ·
    ``pos``: int32 [B, W] · ``k_new``/``v_new``: f32 [B, C, K, hd] ·
    ``p0``/``n_valid``: int32 [B] (``n_valid < C`` marks a ragged final
    chunk; rows past it are masked everywhere and their output is
    garbage-by-contract).  Returns f32 [B, C, K, G, hd].
    """
    B, C, K, G, hd = qf.shape
    W = kf.shape[1]
    cpos = jnp.arange(C, dtype=jnp.int32)
    q_pos = p0[:, None] + cpos[None, :]                    # [B, C]
    row_ok = cpos[None, :] < n_valid[:, None]              # [B, C]

    sh = jnp.einsum("bckgh,bwkh->bkgcw", qf, kf,
                    preferred_element_type=jnp.float32) * scale
    d = q_pos[:, :, None] - pos[:, None, :]                # [B, C, W]
    vh = (pos[:, None, :] >= 0) & (pos[:, None, :] < p0[:, None, None]) \
        & row_ok[:, :, None]
    if causal:
        vh = vh & (d >= 0)
    if window:
        vh = vh & (d < window)

    ss = jnp.einsum("bckgh,bjkh->bkgcj", qf, k_new,
                    preferred_element_type=jnp.float32) * scale
    dj = cpos[:, None] - cpos[None, :]                     # [C, C]
    vs = row_ok[:, :, None] & row_ok[:, None, :]
    if causal:
        vs = vs & (dj >= 0)[None]
    if window:
        vs = vs & (dj < window)[None]

    v4h = vh[:, None, None]                                # [B,1,1,C,W]
    v4s = vs[:, None, None]                                # [B,1,1,C,C]
    s = jnp.concatenate([jnp.where(v4h, sh, -1e30),
                         jnp.where(v4s, ss, -1e30)], axis=-1)
    m = jnp.max(s, axis=-1, keepdims=True)
    vcat = jnp.concatenate([jnp.broadcast_to(v4h, sh.shape),
                            jnp.broadcast_to(v4s, ss.shape)], axis=-1)
    p = jnp.where(vcat, jnp.exp(s - m), 0.0)
    el = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgcw,bwkh->bkgch", p[..., :W], vf,
                   preferred_element_type=jnp.float32) \
        + jnp.einsum("bkgcj,bjkh->bkgch", p[..., W:], v_new,
                     preferred_element_type=jnp.float32)
    o = o / jnp.maximum(el, 1e-30)
    return o.transpose(0, 3, 1, 2, 4)                      # [B, C, K, G, hd]


def dequant(m: Array, e: Array) -> Array:
    """[B, W, K, hd] mantissas × per-row exponents [B] → f32 values."""
    return m.astype(jnp.float32) * exact_pow2(e)[:, None, None, None]


def gather_pages(m: Array, e: Optional[Array], bt: Array,
                 width: Optional[int]) -> Array:
    """Block-table gather: paged storage → the slot-major wide layout.

    ``m``: [n_pages, P, K, hd] page arena (int mantissas when ``width``,
    raw floats otherwise) · ``e``: f32 [n_pages] per-page log2-steps ·
    ``bt``: int32 [B, nblocks] block table.  Returns f32
    [B, nblocks·P, K, hd] — logical row ``r`` is page ``bt[b, r // P]``
    offset ``r % P``, exactly the layout ``pos`` [B, nblocks·P] indexes,
    so :func:`attend`/:func:`chunk_attend` apply unchanged.
    """
    x = jnp.take(m, bt, axis=0).astype(jnp.float32)    # [B, nblocks, P, ...]
    if width is not None:
        x = x * exact_pow2(jnp.take(e, bt, axis=0))[..., None, None, None]
    B, nblocks, P = x.shape[:3]
    return x.reshape((B, nblocks * P) + x.shape[3:])


def paged_decode_attention_ref(q: Array, k: Array, v: Array, bt: Array,
                               pos: Array, q_pos: Array, *, k_exp=None,
                               v_exp=None, width: Optional[int] = None,
                               scale: float, window: Optional[int] = None,
                               causal: bool = True) -> Array:
    """Decode composite through the block-table gather.

    ``k``/``v`` are the [n_pages, P, K, hd] page arenas with per-**page**
    ``k_exp``/``v_exp`` [n_pages] (the
    :class:`repro.serve.paged.PagedKVCodec` layout, one layer); the rest
    matches :func:`decode_attention_ref`.
    """
    kf = gather_pages(k, k_exp, bt, width)
    vf = gather_pages(v, v_exp, bt, width)
    return attend(q.astype(jnp.float32), kf, vf, pos, q_pos, scale=scale,
                  window=window, causal=causal)


def paged_prefill_attention_ref(q: Array, k: Array, v: Array, bt: Array,
                                pos: Array, k_new: Array, v_new: Array,
                                p0: Array, n_valid: Array, *, k_exp=None,
                                v_exp=None, width: Optional[int] = None,
                                scale: float, window: Optional[int] = None,
                                causal: bool = True) -> Array:
    """Chunked-prefill composite through the block-table gather — the
    numerics contract of the paged flash-prefill kernel, in the
    :class:`repro.serve.paged.PagedKVCodec` entry layout (one layer)."""
    kf = gather_pages(k, k_exp, bt, width)
    vf = gather_pages(v, v_exp, bt, width)
    return chunk_attend(q.astype(jnp.float32), kf, vf, pos,
                        k_new.astype(jnp.float32), v_new.astype(jnp.float32),
                        p0, n_valid, scale=scale, window=window,
                        causal=causal)


def decode_attention_ref(q: Array, k: Array, v: Array, pos: Array,
                         q_pos: Array, *, k_exp=None, v_exp=None,
                         width: Optional[int] = None, scale: float,
                         window: Optional[int] = None,
                         causal: bool = True) -> Array:
    """The full composite: dequantize (when ``width``) then :func:`attend`.

    ``width=None`` takes ``k``/``v`` as raw float K/V (the f32-pool path);
    otherwise they are int8/int16 mantissas with ``k_exp``/``v_exp`` [B]
    log2-steps, exactly the :class:`repro.serve.kv_pool.PackedKVCodec`
    entry layout (one layer, leading layer dim stripped).
    """
    qf = q.astype(jnp.float32)
    if width is None:
        kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    else:
        kf, vf = dequant(k, k_exp), dequant(v, v_exp)
    return attend(qf, kf, vf, pos, q_pos, scale=scale, window=window,
                  causal=causal)


def prefill_attention_ref(q: Array, k: Array, v: Array, pos: Array,
                          k_new: Array, v_new: Array, p0: Array,
                          n_valid: Array, *, k_exp=None, v_exp=None,
                          width: Optional[int] = None, scale: float,
                          window: Optional[int] = None,
                          causal: bool = True) -> Array:
    """Chunked-prefill composite: dequantize (when ``width``) then
    :func:`chunk_attend` — the numerics contract of the flash-prefill
    kernel, in the :class:`repro.serve.kv_pool.PackedKVCodec` entry layout
    (one layer, leading layer dim stripped)."""
    qf = q.astype(jnp.float32)
    if width is None:
        kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    else:
        kf, vf = dequant(k, k_exp), dequant(v, v_exp)
    return chunk_attend(qf, kf, vf, pos, k_new.astype(jnp.float32),
                        v_new.astype(jnp.float32), p0, n_valid, scale=scale,
                        window=window, causal=causal)
