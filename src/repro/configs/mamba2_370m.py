"""mamba2-370m [ssm]: attention-free SSD. [arXiv:2405.21060]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm", num_layers=48, d_model=1024,
    num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=50432,  # 50280 padded to %256 for vocab TP
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm", num_layers=4, d_model=128,
    num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=512,
    ssm_state=16, ssm_headdim=32, ssm_chunk=16, tie_embeddings=True)

# attention-free: long_500k runs
CELLS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
