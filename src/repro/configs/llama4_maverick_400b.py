"""llama4-maverick-400b-a17b [moe]: 128 experts top-1, shared expert,
MoE every 2nd layer (interleaved dense FFN), early-fusion backbone.
[hf:meta-llama/Llama-4-Maverick-17B-128E]

Storage note: 400B params only fit the pod in packed (int16 DFXP) storage —
see DESIGN.md §2; the dry-run uses policy storage="packed".
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b", family="moe", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=8192,
    vocab_size=202048, num_experts=128, top_k=1, moe_d_ff=8192,
    moe_period=2, shared_expert=True, rope_theta=5e5, tie_embeddings=False)

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    num_experts=8, top_k=1, moe_d_ff=64, moe_period=2, shared_expert=True,
    tie_embeddings=False)

CELLS = ("train_4k", "prefill_32k", "decode_32k")
