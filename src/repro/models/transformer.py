"""Unified decoder/encoder-decoder LM covering all assigned architectures.

A model is a sequence of **stages**; each stage is a ``lax.scan`` over
``count`` repetitions of a *super-block* (an ordered tuple of sub-blocks).
This single mechanism expresses every assigned family without giving up
scan-over-layers (compact HLO, remat-friendly):

  * dense (llama3/qwen3/phi3/qwen2-vl):  stage = (attn, ffn) × L
  * gemma3 5:1 local:global:             super-block = 5×(local attn, ffn)
                                         + 1×(global attn, ffn), count=L//6
  * MoE (llama4 period 2, granite 1):    super-block interleaves ffn/moe
  * SSM (mamba2):                        stage = (mamba,) × L
  * hybrid (zamba2):                     super-block = 5×mamba + **shared**
                                         attn + shared ffn (weights stored
                                         once, closed over by the scan)
  * enc-dec (seamless):                  encoder stage (non-causal) +
                                         decoder stage with cross-attn

Sub-block window/theta are static per sub-block, so masks lower to compact
HLO. Quantization group names are derived statically from the same stage
structure (``group_shapes``), which is what sizes the DFXP ScaleState.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.core.tape import QTape
from repro.dist.context import DistCtx

from . import layers as L
from . import moe as M
from . import ssm as S

Array = jax.Array


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense|moe|ssm|hybrid|encdec
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024
    # attention variants
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope_sections: Tuple[int, ...] = ()
    window: int = 0                # >0: sliding window for local layers
    local_global_pattern: int = 0  # N: N local then 1 global (gemma3: 5)
    local_rope_theta: float = 1e4  # theta for local (windowed) layers
    embed_scale: bool = False      # multiply embeds by sqrt(d_model) (gemma)
    # ffn
    ffn_kind: str = "swiglu"       # swiglu|gelu|maxout
    maxout_k: int = 2
    # moe
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_period: int = 1            # MoE every k-th layer (llama4: 2)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # ssm / hybrid
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    hybrid_period: int = 0         # zamba2: shared attn+ffn every N mamba
    # enc-dec
    encoder_layers: int = 0
    # io
    input_mode: str = "tokens"     # tokens|embeds
    tie_embeddings: bool = True

    @property
    def attn_spec(self) -> L.AttnSpec:
        return L.AttnSpec(self.d_model, self.num_heads, self.num_kv_heads,
                          self.head_dim, qk_norm=self.qk_norm,
                          rope_theta=self.rope_theta,
                          mrope_sections=self.mrope_sections)

    @property
    def ssm_spec(self) -> S.SSMSpec:
        return S.SSMSpec(self.d_model, self.ssm_state, self.ssm_headdim,
                         self.ssm_expand, chunk=self.ssm_chunk)

    @property
    def moe_spec(self) -> M.MoESpec:
        return M.MoESpec(self.d_model, self.moe_d_ff or self.d_ff,
                         self.num_experts, self.top_k,
                         capacity_factor=self.capacity_factor,
                         shared_expert_d_ff=self.d_ff if self.shared_expert
                         else 0)


@dataclasses.dataclass(frozen=True)
class SubBlock:
    kind: str                      # attn|xattn|ffn|moe|mamba
    window: int = 0                # 0 = global
    shared: bool = False
    causal: bool = True
    rope_theta: float = 0.0        # 0 → cfg.rope_theta


@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    count: int
    blocks: Tuple[SubBlock, ...]
    decoder: bool = True           # participates in decode path


def build_stages(cfg: ModelConfig) -> Tuple[Stage, ...]:
    stages = []
    if cfg.encoder_layers:
        stages.append(Stage("enc", cfg.encoder_layers,
                            (SubBlock("attn", causal=False),
                             SubBlock("ffn")), decoder=False))

    Ld = cfg.num_layers
    if cfg.family == "ssm":
        stages.append(Stage("dec", Ld, (SubBlock("mamba"),)))
    elif cfg.family == "hybrid":
        p = cfg.hybrid_period or 6
        reps, rem = divmod(Ld, p)
        blocks = tuple(SubBlock("mamba") for _ in range(p)) + (
            SubBlock("attn", shared=True), SubBlock("ffn", shared=True))
        stages.append(Stage("dec", reps, blocks))
        if rem:
            stages.append(Stage("dec_tail", 1,
                                tuple(SubBlock("mamba") for _ in range(rem))))
    elif cfg.local_global_pattern:
        n = cfg.local_global_pattern
        reps, rem = divmod(Ld, n + 1)
        local = (SubBlock("attn", window=cfg.window,
                          rope_theta=cfg.local_rope_theta), SubBlock("ffn"))
        glob = (SubBlock("attn"), SubBlock("ffn"))
        stages.append(Stage("dec", reps, local * n + glob))
        if rem:
            stages.append(Stage("dec_tail", 1, local * rem))
    elif cfg.num_experts:
        p = cfg.moe_period
        reps, rem = divmod(Ld, p)
        blocks = []
        for i in range(p):
            blocks.append(SubBlock("attn"))
            blocks.append(SubBlock("moe" if i == p - 1 else "ffn"))
        stages.append(Stage("dec", reps, tuple(blocks)))
        assert rem == 0, "num_layers must divide moe_period"
    else:
        blocks = [SubBlock("attn", window=cfg.window)]
        if cfg.encoder_layers:
            blocks.append(SubBlock("xattn"))
        blocks.append(SubBlock("ffn"))
        stages.append(Stage("dec", Ld, tuple(blocks)))
    return tuple(stages)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, blk: SubBlock) -> dict:
    p = {"norm": jnp.ones((cfg.d_model,), jnp.float32)}
    if blk.kind in ("attn", "xattn"):
        spec = cfg.attn_spec
        if blk.rope_theta:
            spec = dataclasses.replace(spec, rope_theta=blk.rope_theta)
        p.update(L.init_attn(key, spec))
    elif blk.kind == "ffn":
        if cfg.ffn_kind == "swiglu":
            p.update(L.init_swiglu(key, cfg.d_model, cfg.d_ff))
        elif cfg.ffn_kind == "gelu":
            p.update(L.init_gelu_ffn(key, cfg.d_model, cfg.d_ff))
        else:
            p.update(L.init_maxout(key, cfg.d_model, cfg.d_ff, cfg.maxout_k))
    elif blk.kind == "moe":
        p.update(M.init_moe(key, cfg.moe_spec))
    elif blk.kind == "mamba":
        p.update(S.init_ssm(key, cfg.ssm_spec))
    else:
        raise ValueError(blk.kind)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    stages = build_stages(cfg)
    keys = jax.random.split(key, len(stages) + 3)
    params: dict = {"stages": {}}
    for si, stage in enumerate(stages):
        stacked, shared = {}, {}
        for i, blk in enumerate(stage.blocks):
            bkey = f"{i}:{blk.kind}"
            k = jax.random.fold_in(keys[si], i)
            if blk.shared:
                shared[bkey] = _init_block(k, cfg, blk)
            else:
                ks = jax.random.split(k, stage.count)
                stacked[bkey] = jax.vmap(
                    lambda kk: _init_block(kk, cfg, blk))(ks)
        params["stages"][stage.name] = {"stacked": stacked, "shared": shared}
    if cfg.input_mode == "tokens":
        params["embed"] = L.init_embed(keys[-3], cfg.vocab_size, cfg.d_model)
    if not (cfg.tie_embeddings and cfg.input_mode == "tokens"):
        params["head"] = L.init_dense(keys[-2], cfg.d_model, cfg.vocab_size,
                                      scale=0.02)
    params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.encoder_layers:
        params["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# quantization groups
# ---------------------------------------------------------------------------

_SITES = {
    "attn": (("wq", "wk", "wv", "wo"), ("qkv", "k", "v", "out", "res")),
    "xattn": (("wq", "wk", "wv", "wo"), ("qkv", "k", "v", "out", "res")),
    "ffn": {
        "swiglu": (("w_gate", "w_up", "w_down"), ("pre", "out", "res")),
        "gelu": (("w_in", "w_out"), ("pre", "out", "res")),
        "maxout": (("w",), ("out", "res")),
    },
    "moe": (("w_gate", "w_up", "w_down"),
            ("dispatch", "pre", "expert_out", "out", "res")),
    "mamba": (("in_proj", "out_proj"), ("x", "y", "out", "state", "res")),
}


def _block_sites(cfg: ModelConfig, blk: SubBlock):
    if blk.kind == "ffn":
        w, a = _SITES["ffn"][cfg.ffn_kind]
    else:
        w, a = _SITES[blk.kind]
    if blk.kind == "moe" and cfg.shared_expert:
        w = w + ("shared/w_gate", "shared/w_up", "shared/w_down")
        a = a + ("shared/pre", "shared/out")
    return w, a


def group_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    """All quantization scale groups and their shapes (() or (count,))."""
    groups: Dict[str, tuple] = {}
    for stage in build_stages(cfg):
        for i, blk in enumerate(stage.blocks):
            pfx = f"{stage.name}/{i}:{blk.kind}"
            shape = () if blk.shared else (stage.count,)
            w_sites, a_sites = _block_sites(cfg, blk)
            for s in w_sites:
                groups[f"w:{pfx}/{s}"] = shape
            for s in a_sites:
                groups[f"a:{pfx}/{s}"] = shape
                groups[f"g:{pfx}/{s}"] = shape
    if cfg.input_mode == "tokens":
        groups["w:emb/w"] = ()
    for g in ("a:emb/out", "g:emb/out", "w:head/w", "a:head/logits",
              "g:head/logits"):
        groups[g] = ()
    return groups


def _subdict(d: Dict[str, Array], keys) -> Dict[str, Array]:
    return {k: d[k] for k in keys if k in d}


def _stage_group_names(cfg, stage, shared: bool):
    names = []
    for i, blk in enumerate(stage.blocks):
        if blk.shared != shared:
            continue
        pfx = f"{stage.name}/{i}:{blk.kind}"
        w_sites, a_sites = _block_sites(cfg, blk)
        names += [f"w:{pfx}/{s}" for s in w_sites]
        for s in a_sites:
            names += [f"a:{pfx}/{s}", f"g:{pfx}/{s}"]
    return names


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _ring_cache(k: Array, v: Array, cap: int):
    """Pack full-sequence KV [B,S,K,hd] into a ring buffer of ``cap`` slots."""
    B, S = k.shape[:2]
    n_keep = min(S, cap)
    pos_keep = jnp.arange(S - n_keep, S)
    slots = pos_keep % cap
    shape = (B, cap) + k.shape[2:]
    ck = jnp.zeros(shape, k.dtype).at[:, slots].set(k[:, S - n_keep:])
    cv = jnp.zeros(shape, v.dtype).at[:, slots].set(v[:, S - n_keep:])
    cpos = jnp.full((B, cap), -1, jnp.int32).at[:, slots].set(
        jnp.broadcast_to(pos_keep, (B, n_keep)).astype(jnp.int32))
    return {"k": ck, "v": cv, "pos": cpos}


def _apply_block(cfg: ModelConfig, blk: SubBlock, pfx: str, bp, x, positions,
                 tape: QTape, dist: DistCtx, memory, mode: str,
                 cache_in=None, max_cache_len: int = 0, kv_codec=None,
                 n_valid=None, append_mask=None):
    """Apply one sub-block (pre-norm residual). Returns (x, cache_out)."""
    h = L.rmsnorm(x, bp["norm"])
    cache_out = None
    window = blk.window if blk.window > 0 else None
    if mode == "chunk" and blk.kind not in ("attn", "ffn"):
        # chunked prefill is attention-family only: MoE capacity and SSM
        # state couple a whole prompt (ServeEngine keeps those on the
        # whole-prompt path), and xattn needs an encoder pass
        raise ValueError(f"chunked prefill does not support {blk.kind!r}")
    if blk.kind in ("attn", "xattn"):
        spec = cfg.attn_spec
        if blk.rope_theta:
            spec = dataclasses.replace(spec, rope_theta=blk.rope_theta)
        if not blk.causal:
            spec = dataclasses.replace(spec, causal=False)
        kv_src = memory if blk.kind == "xattn" else None
        if mode == "train" or blk.kind == "xattn" and mode == "prefill":
            if dist.attn_seq_shard and dist.token_axes:
                # heads don't divide the TP degree (e.g. phi3 40H/10KV):
                # shard attention over the *sequence* instead of replicating
                from jax.sharding import PartitionSpec as _P
                h = jax.lax.with_sharding_constraint(
                    h, _P(dist.token_axes, "model", None))
            y = L.attention_train(bp, spec, h, positions, tape, pfx,
                                  window=window, kv_source=kv_src)
            if dist.attn_seq_shard and dist.token_axes:
                from jax.sharding import PartitionSpec as _P
                y = jax.lax.with_sharding_constraint(
                    y, _P(dist.token_axes, None, None))
            if blk.kind == "xattn" and mode == "prefill":
                # cross-attn KV is static over decode: cache it once
                Sk = memory.shape[1]
                k = tape.dot(f"{pfx}/wk", memory, bp["wk"]).reshape(
                    memory.shape[0], Sk, spec.num_kv_heads, spec.head_dim)
                v = tape.dot(f"{pfx}/wv", memory, bp["wv"]).reshape(
                    memory.shape[0], Sk, spec.num_kv_heads, spec.head_dim)
                cache_out = {"k": k, "v": v}
        elif mode == "prefill":
            y, (k, v) = L.attention_prefill(bp, spec, h, positions, tape,
                                            pfx, window=window)
            cap = min(window, max_cache_len) if window else max_cache_len
            cache_out = _ring_cache(k, v, cap)
        elif mode == "chunk":
            y, cache_out = L.attention_prefill_chunk(
                bp, spec, h, positions, cache_in, tape, pfx,
                n_valid=n_valid, window=window, dist=dist, codec=kv_codec)
        else:  # decode
            if blk.kind == "xattn":
                y = _xattn_decode(bp, spec, h, cache_in, tape, pfx)
                cache_out = cache_in
            else:
                y, cache_out = L.attention_decode(
                    bp, spec, h, positions, cache_in, tape, pfx,
                    window=window, dist=dist, codec=kv_codec,
                    append_mask=append_mask)
    elif blk.kind == "ffn":
        if cfg.ffn_kind == "swiglu":
            y = L.swiglu(bp, h, tape, pfx)
        elif cfg.ffn_kind == "gelu":
            y = L.gelu_ffn(bp, h, tape, pfx)
        else:
            y = L.maxout(bp, h, tape, pfx)
    elif blk.kind == "moe":
        y = M.moe_ffn(bp, cfg.moe_spec, h, tape, pfx, dist,
                      dropless=(mode == "decode"))
    elif blk.kind == "mamba":
        if mode == "decode":
            y, cache_out = S.ssm_decode(bp, cfg.ssm_spec, h, cache_in, tape,
                                        pfx)
        else:
            y, cache_out = S.ssm_forward(bp, cfg.ssm_spec, h, tape, pfx,
                                         return_cache=(mode == "prefill"))
    else:
        raise ValueError(blk.kind)
    x = x + y.astype(x.dtype)
    x = tape.act(f"{pfx}/res", x)
    return x, cache_out


def _xattn_decode(bp, spec, h, cache, tape, pfx):
    """Cross-attention during decode: static KV from the prefill cache."""
    B = h.shape[0]
    q = tape.dot(f"{pfx}/wq", h, bp["wq"]).reshape(
        B, 1, spec.num_heads, spec.head_dim)
    k, v = cache["k"], cache["v"]
    K, G = spec.num_kv_heads, spec.num_heads // spec.num_kv_heads
    qg = q.reshape(B, 1, K, G, spec.head_dim)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(spec.head_dim))
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, spec.q_dim).astype(h.dtype)
    y = tape.dot(f"{pfx}/wo", o, bp["wo"])
    return tape.act(f"{pfx}/out", y)


def _run_stage(cfg, policy, stage: Stage, sp, x, positions, scales, sinks,
               dist, memory, mode: str, cache=None, remat: str = "none",
               max_cache_len: int = 0, kv_codec=None, n_valid=None,
               append_mask=None):
    """Scan one stage. Returns (x, stats, cache_out)."""
    stacked_names = _stage_group_names(cfg, stage, shared=False)
    shared_names = _stage_group_names(cfg, stage, shared=True)
    sc_stacked = _subdict(scales, stacked_names)
    sk_stacked = _subdict(sinks, [n for n in stacked_names
                                  if n.startswith("g:")])
    sc_shared = _subdict(scales, shared_names)
    sk_shared = _subdict(sinks, [n for n in shared_names
                                 if n.startswith("g:")])

    def body(x, xs):
        p_st, sc_st, sk_st, cache_st = xs
        tape = QTape(policy, {**sc_st, **sc_shared}, {**sk_st, **sk_shared})
        cache_out = {}
        for i, blk in enumerate(stage.blocks):
            bkey = f"{i}:{blk.kind}"
            bp = sp["shared"][bkey] if blk.shared else p_st[bkey]
            ci = None if cache_st is None else cache_st.get(bkey)
            x, co = _apply_block(cfg, blk, f"{stage.name}/{bkey}", bp, x,
                                 positions, tape, dist, memory, mode, ci,
                                 max_cache_len=max_cache_len,
                                 kv_codec=kv_codec, n_valid=n_valid,
                                 append_mask=append_mask)
            if co is not None:
                cache_out[bkey] = co
        return x, (tape.stats, cache_out)

    if remat != "none" and mode == "train":
        pol = (jax.checkpoint_policies.checkpoint_dots if remat == "dots"
               else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=pol)

    xs = (sp["stacked"], sc_stacked, sk_stacked, cache)
    x, (stats, cache_out) = jax.lax.scan(body, x, xs, length=stage.count)
    # shared groups: one scale, stats summed over iterations
    stats = {n: (s.sum(0) if n in shared_names else s)
             for n, s in stats.items()}
    return x, stats, cache_out


def forward(cfg: ModelConfig, policy: PrecisionPolicy, params, batch,
            scales: Dict[str, Array], sinks: Dict[str, Array],
            dist: DistCtx = DistCtx(), *, mode: str = "train",
            remat: str = "none", max_cache_len: int = 0):
    """Full forward. Returns (logits, stats, cache|None).

    ``batch``: dict with ``tokens`` [B,S] or ``embeds`` [B,S,D]; optional
    ``positions`` ([B,S] or [3,B,S] for M-RoPE); encoder-decoder models add
    ``src_embeds`` [B,Ssrc,D].
    """
    tape = QTape(policy, scales, sinks)   # for embed/head sites
    stats: Dict[str, Array] = {}

    if cfg.input_mode == "tokens":
        x = L.embed(params["embed"], batch["tokens"], tape)
    else:
        x = tape.act("emb/out", batch["embeds"])
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    x = x.astype(jnp.dtype(policy.compute_dtype))

    B, Sq = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))

    # encoder (if any)
    memory = None
    stages = build_stages(cfg)
    if cfg.encoder_layers:
        src = batch["src_embeds"]
        mpos = jnp.broadcast_to(jnp.arange(src.shape[1]),
                                (src.shape[0], src.shape[1]))
        enc_stage = stages[0]
        memory, st, _ = _run_stage(cfg, policy, enc_stage,
                                   params["stages"]["enc"], src, mpos,
                                   scales, sinks, dist, None, "train",
                                   remat=remat)
        memory = L.rmsnorm(memory, params["enc_norm"])
        stats.update(st)
        stages = stages[1:]

    cache_all = {}
    block_mode = "train" if mode == "hidden" else mode
    for stage in stages:
        x, st, cache_out = _run_stage(cfg, policy, stage,
                                      params["stages"][stage.name], x,
                                      positions, scales, sinks, dist, memory,
                                      block_mode, remat=remat,
                                      max_cache_len=max_cache_len)
        stats.update(st)
        if cache_out:
            cache_all[stage.name] = cache_out

    if mode == "prefill":
        # decode only needs the last position: skip the full-seq head matmul
        x = x[:, -1:, :]
    x = L.rmsnorm(x, params["final_norm"])
    if mode == "hidden":
        # caller fuses head + loss (chunked CE): don't materialize logits
        stats.update(tape.stats)
        return x, stats, None
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        logits = L.lm_head(params["embed"], x, tape, tied=True)
    else:
        logits = L.lm_head(params["head"], x, tape, tied=False)

    stats.update(tape.stats)
    if mode == "prefill" and memory is not None:
        cache_all["enc_memory"] = memory
    return logits, stats, (cache_all or None)


def prefill(cfg: ModelConfig, policy, params, batch, scales, sinks,
            dist: DistCtx = DistCtx(), *, max_cache_len: int):
    """Prefill: returns (last-position logits, decode cache)."""
    logits, stats, cache = forward(cfg, policy, params, batch, scales, sinks,
                                   dist, mode="prefill",
                                   max_cache_len=max_cache_len)
    return logits[:, -1, :], stats, cache


def decode_step(cfg: ModelConfig, policy, params, cache, tokens_or_embeds,
                pos, scales, sinks, dist: DistCtx = DistCtx(),
                kv_codec=None, append_mask=None):
    """One decoding step. ``tokens_or_embeds``: [B] ids or [B,1,D] embeds;
    ``pos``: current position — a scalar int (lockstep decode) or a
    per-sequence ``[B]`` vector (continuous batching: every slot decodes
    at its own position). ``kv_codec``: optional KV-cache storage codec
    (see :class:`repro.models.layers.RawKVCodec`); the default is the
    float ring buffer. ``append_mask`` (bool [B], optional) drops the
    cache append for masked-off rows — slots mid-chunked-prefill decode
    garbage that must not be written. Returns (logits [B,V], stats,
    cache')."""
    tape = QTape(policy, scales, sinks)
    stats: Dict[str, Array] = {}
    if cfg.input_mode == "tokens":
        x = L.embed(params["embed"], tokens_or_embeds[:, None], tape)
    else:
        x = tape.act("emb/out", tokens_or_embeds)
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    x = x.astype(jnp.dtype(policy.compute_dtype))
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = (jnp.broadcast_to(pos, (B, 1)) if pos.ndim == 0
                 else pos.reshape(B, 1))

    memory = cache.get("enc_memory") if cfg.encoder_layers else None
    new_cache = dict(cache)
    for stage in build_stages(cfg):
        if not stage.decoder:
            continue
        x, st, cache_out = _run_stage(cfg, policy, stage,
                                      params["stages"][stage.name], x,
                                      positions, scales, sinks, dist, memory,
                                      "decode", cache=cache[stage.name],
                                      kv_codec=kv_codec,
                                      append_mask=append_mask)
        stats.update(st)
        new_cache[stage.name] = cache_out

    x = L.rmsnorm(x, params["final_norm"])
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        logits = L.lm_head(params["embed"], x, tape, tied=True)
    else:
        logits = L.lm_head(params["head"], x, tape, tied=False)
    stats.update(tape.stats)
    return logits[:, -1, :], stats, new_cache


def prefill_chunk_step(cfg: ModelConfig, policy, params, cache, tokens,
                       p0, n_valid, scales, sinks, dist: DistCtx = DistCtx(),
                       kv_codec=None):
    """One chunked-prefill step: ``C`` prompt positions against the cache.

    ``tokens``: [B, C] ids — positions ``p0 + i`` of the prompt, rows
    ``>= n_valid`` zero-padded (a ragged final chunk; masked in-kernel).
    ``cache``: a decode cache/pool (attention ring entries only — chunked
    prefill is attention-family only, see ``_apply_block``).  Each layer
    attends the chunk against its already-written history plus the
    chunk's own K/V causally, then writes the chunk K/V through
    ``kv_codec`` (packed pools quantize on write; ``p0 == 0`` resets and
    calibrates the slot).  Returns (last-valid-position logits [B, V],
    stats, cache') — the logits sample the request's first token when the
    chunk is final, exactly where whole-prompt ``prefill`` samples it.
    """
    if cfg.input_mode != "tokens":
        raise ValueError("chunked prefill serves token-in models")
    tape = QTape(policy, scales, sinks)
    stats: Dict[str, Array] = {}
    x = L.embed(params["embed"], tokens, tape)
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    x = x.astype(jnp.dtype(policy.compute_dtype))
    B, C = tokens.shape
    p0 = jnp.asarray(p0, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    positions = p0[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]

    new_cache = dict(cache)
    for stage in build_stages(cfg):
        if not stage.decoder:
            continue
        x, st, cache_out = _run_stage(cfg, policy, stage,
                                      params["stages"][stage.name], x,
                                      positions, scales, sinks, dist, None,
                                      "chunk", cache=cache[stage.name],
                                      kv_codec=kv_codec, n_valid=n_valid)
        stats.update(st)
        new_cache[stage.name] = cache_out

    # only the last valid position's logits matter (first sampled token)
    idx = jnp.clip(n_valid - 1, 0, C - 1)
    x = jnp.take_along_axis(
        x, jnp.broadcast_to(idx[:, None, None], (B, 1, x.shape[-1])), axis=1)
    x = L.rmsnorm(x, params["final_norm"])
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        logits = L.lm_head(params["embed"], x, tape, tied=True)
    else:
        logits = L.lm_head(params["head"], x, tape, tied=False)
    stats.update(tape.stats)
    return logits[:, -1, :], stats, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: int = 0, dtype=jnp.float32) -> dict:
    """Zero decode cache for ``batch`` sequences of capacity ``max_len``."""
    cache: dict = {}
    for stage in build_stages(cfg):
        if not stage.decoder:
            continue
        sc: dict = {}
        for i, blk in enumerate(stage.blocks):
            bkey = f"{i}:{blk.kind}"
            n = stage.count
            if blk.kind == "attn":
                cap = min(blk.window, max_len) if blk.window else max_len
                K, hd = cfg.num_kv_heads, cfg.head_dim
                sc[bkey] = {
                    "k": jnp.zeros((n, batch, cap, K, hd), dtype),
                    "v": jnp.zeros((n, batch, cap, K, hd), dtype),
                    "pos": jnp.full((n, batch, cap), -1, jnp.int32),
                }
            elif blk.kind == "xattn":
                K, hd = cfg.num_kv_heads, cfg.head_dim
                sc[bkey] = {
                    "k": jnp.zeros((n, batch, src_len, K, hd), dtype),
                    "v": jnp.zeros((n, batch, src_len, K, hd), dtype),
                }
            elif blk.kind == "mamba":
                s = cfg.ssm_spec
                sc[bkey] = {
                    "conv": jnp.zeros((n, batch, s.conv_kernel - 1,
                                       s.conv_dim), dtype),
                    "state": jnp.zeros((n, batch, s.heads, s.headdim,
                                        s.state), jnp.float32),
                }
        cache[stage.name] = sc
    if cfg.encoder_layers:
        cache["enc_memory"] = jnp.zeros((batch, src_len, cfg.d_model), dtype)
    return cache


def _ce(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def loss_fn(cfg, policy, params, batch, scales, sinks,
            dist: DistCtx = DistCtx(), remat: str = "none",
            ce_chunk: int = 0):
    """Mean cross-entropy; returns (loss, stats).

    ``ce_chunk``: if >0, the LM-head matmul + softmax-CE are computed over
    sequence chunks of this many positions inside a rematerialized scan, so
    the [tokens, vocab] logits tensor never materializes (decisive for 256k
    vocabularies at 4k×256 batches).
    """
    labels = batch["labels"]
    if not ce_chunk:
        logits, stats, _ = forward(cfg, policy, params, batch, scales, sinks,
                                   dist, mode="train", remat=remat)
        ll = _ce(logits, labels)
        mask = batch.get("loss_mask")
        if mask is None:
            loss = -ll.mean()
        else:
            loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return loss, stats

    hidden, stats, _ = forward(cfg, policy, params, batch, scales, sinks,
                               dist, mode="hidden", remat=remat)
    tape = QTape(policy, scales, sinks)
    tied = cfg.tie_embeddings and cfg.input_mode == "tokens"
    w = tape.weight("head/w", params["embed"] if tied else params["head"])
    B, S, D = hidden.shape
    assert S % ce_chunk == 0, (S, ce_chunk)
    nch = S // ce_chunk
    xc = hidden.reshape(B, nch, ce_chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nch, ce_chunk).transpose(1, 0, 2)
    fmt = policy.comp_format()
    head_sink = sinks.get("g:head/logits", jnp.zeros((3,), jnp.float32))

    def body(acc, xs):
        xch, lch = xs
        if tied:
            logits = jnp.einsum("bsd,vd->bsv", xch, w.astype(xch.dtype),
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("bsd,dv->bsv", xch, w.astype(xch.dtype),
                                preferred_element_type=jnp.float32)
        from repro.core.quant import q_stats, qbound
        logits = qbound(logits, fmt, fmt, scales.get("a:head/logits", 0.0),
                        scales.get("g:head/logits", 0.0), head_sink)
        st = q_stats(logits, fmt, scales.get("a:head/logits", 0.0))
        return acc + jnp.sum(_ce(logits, lch)), st

    body = jax.checkpoint(body)
    total, head_stats = jax.lax.scan(body, jnp.float32(0), (xc, lc))
    stats["a:head/logits"] = head_stats.sum(0)
    stats.update(tape.stats)
    return -total / (B * S), stats
